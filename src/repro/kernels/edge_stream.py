"""Pallas TPU kernel: fused edge pipeline (Burst Read -> Apply -> Shuffle ->
Reduce -> Burst Write), the whole of paper Fig. 4 step 1-6 as one kernel.

Layout contract (prepared by the caller / DSL back-end):
* edges are sorted by destination (the static shuffle routing);
* the source-side operand is pre-gathered into a stream (``src_vals``) —
  on TPU the hub-cache split makes this gather cheap: hot vertices hit a
  VMEM-resident prefix, cold ones are bulk HBM gathers;
* the kernel streams (src_vals, weights, dst, active) tiles HBM->VMEM
  (automatically double-buffered: the Burst Read + pipelining optimization),
  applies the edge operation, and reduces conflict-free into the
  VMEM-resident destination partition via a one-hot contraction.

Grid = (P, T) with clamped tile index maps exactly as in shuffle_reduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import _identity


def _kernel(
    tile_lo_ref, tile_hi_ref,
    sv_ref, w_ref, dst_ref, act_ref, out_ref,
    *, apply_op: str, reduce_op: str, u: int, et: int,
):
    p = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.full((1, u), _identity(reduce_op, out_ref.dtype))

    in_range = jnp.logical_and(t >= tile_lo_ref[p], t <= tile_hi_ref[p])

    @pl.when(in_range)
    def _accum():
        sv = sv_ref[0, :]
        w = w_ref[0, :]
        dst = dst_ref[0, :]
        act = act_ref[0, :]
        # -- Edge Operation (user apply function) --
        if apply_op == "add":
            upd = sv + w
        elif apply_op == "mul":
            upd = sv * w
        else:  # 'src'
            upd = sv
        ident = _identity(reduce_op, out_ref.dtype)
        upd = jnp.where(act, upd.astype(out_ref.dtype), ident)
        # -- Shuffle + RAW-free Reduce --
        local = dst - p * u
        lanes = jax.lax.broadcasted_iota(jnp.int32, (et, u), 1)
        onehot = local[:, None] == lanes
        if reduce_op == "+" and jnp.issubdtype(out_ref.dtype, jnp.floating):
            masked = jnp.where(onehot, upd[:, None], 0).astype(jnp.float32)
            out_ref[0, :] = out_ref[0, :] + jnp.sum(masked, axis=0).astype(out_ref.dtype)
        else:
            spread = jnp.where(onehot, upd[:, None], ident)
            if reduce_op == "+":
                out_ref[0, :] = out_ref[0, :] + jnp.sum(spread, axis=0)
            elif reduce_op == "min":
                out_ref[0, :] = jnp.minimum(out_ref[0, :], jnp.min(spread, axis=0))
            else:
                out_ref[0, :] = jnp.maximum(out_ref[0, :], jnp.max(spread, axis=0))


@functools.partial(
    jax.jit,
    static_argnames=("n_out", "apply_op", "reduce_op", "u", "et", "interpret"),
)
def edge_stream_call(
    src_vals: jnp.ndarray,
    weights: jnp.ndarray,
    dst: jnp.ndarray,
    active: jnp.ndarray,
    *,
    n_out: int,
    apply_op: str = "add",
    reduce_op: str = "min",
    u: int = 512,
    et: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    n = src_vals.shape[0]
    et = min(et, max(128, 1 << (max(1, n) - 1).bit_length()))
    u = min(u, max(128, 1 << (max(1, n_out) - 1).bit_length()))
    # sort by destination: the static shuffle routing
    perm = jnp.argsort(dst)
    sv, w, ds, ac = src_vals[perm], weights[perm], dst[perm].astype(jnp.int32), active[perm]
    n_pad = ((n + et - 1) // et) * et
    big = jnp.int32(2**31 - 1)

    def pad(x, v):
        if n_pad == n:
            return x
        return jnp.concatenate([x, jnp.full((n_pad - n,), v, x.dtype)])

    sv = pad(sv, 0)
    w = pad(w, 0)
    ds = pad(ds, big)
    ac = pad(ac, False)

    n_out_pad = ((n_out + u - 1) // u) * u
    n_tiles = n_pad // et
    n_parts = n_out_pad // u
    tile_of = ds // u
    first_in_tile = tile_of[::et]
    last_in_tile = jnp.minimum(tile_of, n_parts - 1)[et - 1 :: et]
    parts = jnp.arange(n_parts, dtype=jnp.int32)
    tile_lo = jnp.minimum(
        jnp.searchsorted(last_in_tile, parts, side="left").astype(jnp.int32), n_tiles - 1
    )
    tile_hi = jnp.clip(
        jnp.searchsorted(first_in_tile, parts, side="right").astype(jnp.int32) - 1,
        0,
        n_tiles - 1,
    )

    def im_in(p, t, lo, hi):
        return (0, jnp.clip(t, lo[p], hi[p]))

    def im_out(p, t, lo, hi):
        return (0, p)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_parts, n_tiles),
        in_specs=[
            pl.BlockSpec((1, et), im_in),
            pl.BlockSpec((1, et), im_in),
            pl.BlockSpec((1, et), im_in),
            pl.BlockSpec((1, et), im_in),
        ],
        out_specs=pl.BlockSpec((1, u), im_out),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, apply_op=apply_op, reduce_op=reduce_op, u=u, et=et),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, n_out_pad), src_vals.dtype),
        interpret=interpret,
    )(tile_lo, tile_hi, sv[None, :], w[None, :], ds[None, :], ac[None, :])
    return out[0, :n_out]
