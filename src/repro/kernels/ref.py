"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the semantics a kernel must reproduce;
tests sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _identity(op: str, dtype):
    dtype = jnp.dtype(dtype)
    if op == "+":
        return dtype.type(0)
    if op == "min":
        return jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer) else dtype.type(jnp.inf)
    if op == "max":
        return jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer) else dtype.type(-jnp.inf)
    raise ValueError(op)


def shuffle_reduce_ref(vals: jnp.ndarray, idx: jnp.ndarray, n_out: int, op: str) -> jnp.ndarray:
    """Scatter-reduce ``vals`` into ``n_out`` bins; identity elsewhere.

    Out-of-range indices are dropped (padding convention).
    """
    out = jnp.full((n_out,), _identity(op, vals.dtype))
    ok = idx < n_out
    safe_idx = jnp.where(ok, idx, 0)
    safe_vals = jnp.where(ok, vals, _identity(op, vals.dtype))
    if op == "+":
        return out.at[safe_idx].add(safe_vals)
    if op == "min":
        return out.at[safe_idx].min(safe_vals)
    if op == "max":
        return out.at[safe_idx].max(safe_vals)
    raise ValueError(op)


def edge_stream_ref(
    src_vals: jnp.ndarray,  # [E] gathered source-side operand (pre-gathered)
    weights: jnp.ndarray,  # [E] edge weights (or ones)
    dst: jnp.ndarray,  # [E] destination ids
    active: jnp.ndarray,  # [E] bool frontier mask
    n_out: int,
    apply_op: str,  # 'add' | 'mul' | 'src' (ignore weight)
    reduce_op: str,  # '+' | 'min' | 'max'
) -> jnp.ndarray:
    """Fused edge pipeline: apply(src_val, w) masked by frontier, reduced by dst."""
    if apply_op == "add":
        upd = src_vals + weights
    elif apply_op == "mul":
        upd = src_vals * weights
    elif apply_op == "src":
        upd = src_vals
    else:
        raise ValueError(apply_op)
    ident = _identity(reduce_op, upd.dtype)
    upd = jnp.where(active, upd, ident)
    return shuffle_reduce_ref(upd, dst, n_out, reduce_op)


def moe_gather_ref(
    tokens_sorted: jnp.ndarray,  # [T, D] tokens sorted by expert id
    group_offsets: jnp.ndarray,  # [E] start row of each expert's group
    group_sizes: jnp.ndarray,  # [E] tokens routed to each expert
    capacity: int,
) -> jnp.ndarray:
    """Capacity-binned gather: [E, C, D]; overflow dropped, underflow zero."""
    e = group_offsets.shape[0]
    d = tokens_sorted.shape[-1]
    rows = group_offsets[:, None] + jnp.arange(capacity)[None, :]  # [E, C]
    valid = jnp.arange(capacity)[None, :] < group_sizes[:, None]
    safe = jnp.clip(rows, 0, tokens_sorted.shape[0] - 1)
    out = tokens_sorted[safe.reshape(-1)].reshape(e, capacity, d)
    return jnp.where(valid[..., None], out, 0)


def moe_scatter_ref(
    expert_out: jnp.ndarray,  # [E, C, D]
    group_offsets: jnp.ndarray,  # [E]
    group_sizes: jnp.ndarray,  # [E]
    n_tokens: int,
) -> jnp.ndarray:
    """Inverse of moe_gather_ref: back to [T, D] sorted-token order."""
    e, c, d = expert_out.shape
    rows = group_offsets[:, None] + jnp.arange(c)[None, :]
    valid = jnp.arange(c)[None, :] < group_sizes[:, None]
    flat_rows = jnp.where(valid, rows, n_tokens).reshape(-1)
    out = jnp.zeros((n_tokens + 1, d), expert_out.dtype)
    out = out.at[flat_rows].add(expert_out.reshape(-1, d))
    return out[:n_tokens]


def flash_attention_ref(
    q: jnp.ndarray,  # [B, H, Lq, Dh]
    k: jnp.ndarray,  # [B, Hkv, Lk, Dh]
    v: jnp.ndarray,  # [B, Hkv, Lk, Dh]
    causal: bool = True,
    window: int = 0,  # 0 = full; >0 = sliding window
) -> jnp.ndarray:
    b, h, lq, dh = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    lk = k.shape[2]
    qi = jnp.arange(lq)[:, None] + (lk - lq)  # align causal offset for decode
    ki = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
