"""Pallas TPU kernel: MoE token dispatch — the paper's shuffle engine
applied to expert routing.

MoE dispatch *is* a graph-shuffle problem: tokens are update tuples keyed
by expert id; conflict-free capacity binning is destination-partitioned
reduction. The routing (argsort by expert) happens once outside; this
kernel performs the capacity-binned gather with **block-aligned group
offsets carried via scalar prefetch**, so on real TPUs the index map is a
static DMA schedule (a Megablocks-style layout, expressed with the paper's
machinery).

Contract:
* ``tokens_sorted``: [T, D] tokens sorted by expert id (padded rows zero);
* ``group_offsets``: [E] start row per expert, **multiples of block_c**;
* ``group_sizes``: [E] live token count per expert (<= capacity);
* output: [E, C, D] with zero padding beyond each group size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(off_ref, size_ref, tok_ref, out_ref, *, block_c: int, d: int):
    e = pl.program_id(0)
    c = pl.program_id(1)
    base = c * block_c
    count = size_ref[e] - base  # live rows in this block
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_c, d), 0)
    live = rows < count
    out_ref[0, :, :] = jnp.where(live, tok_ref[:, :], 0)


@functools.partial(jax.jit, static_argnames=("capacity", "block_c", "interpret"))
def moe_gather_call(
    tokens_sorted: jnp.ndarray,
    group_offsets: jnp.ndarray,
    group_sizes: jnp.ndarray,
    capacity: int,
    *,
    block_c: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    t, d = tokens_sorted.shape
    e = group_offsets.shape[0]
    block_c = min(block_c, capacity)
    assert capacity % block_c == 0
    n_blocks = capacity // block_c
    # tokens must be padded so any offset+capacity window is in range
    t_pad = ((t + capacity + block_c - 1) // block_c) * block_c
    if t_pad > t:
        tokens_sorted = jnp.concatenate(
            [tokens_sorted, jnp.zeros((t_pad - t, d), tokens_sorted.dtype)]
        )

    def im_tok(e_i, c_i, off, size):
        return (off[e_i] // block_c + c_i, 0)

    def im_out(e_i, c_i, off, size):
        return (e_i, c_i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(e, n_blocks),
        in_specs=[pl.BlockSpec((block_c, d), im_tok)],
        out_specs=pl.BlockSpec((1, block_c, d), im_out),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, block_c=block_c, d=d),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e, capacity, d), tokens_sorted.dtype),
        interpret=interpret,
    )(
        group_offsets.astype(jnp.int32),
        group_sizes.astype(jnp.int32),
        tokens_sorted,
    )
    return out
