"""Pallas TPU kernel: binned conflict-free scatter-reduce ("shuffle").

The TPU-native re-formulation of the paper's data-shuffling network
(Fig. 7(c)): instead of routing updates through a hardware shuffle into
banked URAM, updates are **sorted by destination once** (the routing
decision, done by the caller) and the kernel reduces each destination
partition in VMEM:

* grid = (P, T): P output partitions x T input tiles;
* the output block (one partition of width ``u``) stays VMEM-resident for
  the whole inner ``t`` loop — the URAM accumulator analogue;
* input tiles are streamed HBM->VMEM; with sorted input, a partition only
  overlaps a contiguous tile range ``[tile_lo[p], tile_hi[p]]``. The tile
  index map **clamps** to that range (scalar-prefetched), so out-of-range
  grid steps re-reference the same block (no DMA) and skip compute via
  ``pl.when`` — the streaming cost is O(N), not O(P*N);
* within a tile, the reduction is conflict-free: an explicit one-hot
  contraction — ``onehot.T @ vals`` on the MXU for float sums, a masked
  broadcast reduce on the VPU for min/max/int — replacing the FPGA's
  RAW-resolver + banked reduce.

Validated against ``ref.shuffle_reduce_ref`` in interpret mode (CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

NEG = {"min": "max", "max": "min"}


def _identity(op: str, dtype) -> jnp.ndarray:
    dtype = jnp.dtype(dtype)
    if op == "+":
        return jnp.asarray(0, dtype)
    if op == "min":
        return jnp.asarray(
            jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer) else jnp.inf, dtype
        )
    if op == "max":
        return jnp.asarray(
            jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer) else -jnp.inf, dtype
        )
    raise ValueError(op)


def _kernel(tile_lo_ref, tile_hi_ref, idx_ref, val_ref, out_ref, *, op: str, u: int, et: int):
    p = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.full((1, u), _identity(op, out_ref.dtype))

    in_range = jnp.logical_and(t >= tile_lo_ref[p], t <= tile_hi_ref[p])

    @pl.when(in_range)
    def _accum():
        idx = idx_ref[0, :]  # [et] global destination ids (sorted)
        vals = val_ref[0, :]  # [et]
        local = idx - p * u
        lanes = jax.lax.broadcasted_iota(jnp.int32, (et, u), 1)
        onehot = local[:, None] == lanes  # [et, u]
        if op == "+" and jnp.issubdtype(out_ref.dtype, jnp.floating):
            # MXU path: one-hot contraction
            contrib = jnp.dot(
                onehot.astype(out_ref.dtype)[:, :].T, vals.astype(out_ref.dtype),
                preferred_element_type=jnp.float32,
            ).astype(out_ref.dtype)
            out_ref[0, :] = out_ref[0, :] + contrib
        else:
            ident = _identity(op, out_ref.dtype)
            spread = jnp.where(onehot, vals[:, None].astype(out_ref.dtype), ident)
            if op == "+":
                contrib = jnp.sum(spread, axis=0)
                out_ref[0, :] = out_ref[0, :] + contrib
            elif op == "min":
                contrib = jnp.min(spread, axis=0)
                out_ref[0, :] = jnp.minimum(out_ref[0, :], contrib)
            else:
                contrib = jnp.max(spread, axis=0)
                out_ref[0, :] = jnp.maximum(out_ref[0, :], contrib)


@functools.partial(
    jax.jit, static_argnames=("n_out", "op", "u", "et", "interpret")
)
def shuffle_reduce_sorted(
    vals: jnp.ndarray,
    idx_sorted: jnp.ndarray,
    *,
    n_out: int,
    op: str = "+",
    u: int = 512,
    et: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    """Reduce sorted (idx, val) update streams into ``n_out`` bins.

    Inputs must be padded so ``len % et == 0`` and invalid lanes must carry
    an out-of-range index (>= n_out) with identity values.
    Returns an array of length ``n_out_padded`` (multiple of ``u``) whose
    untouched entries hold the reduction identity; callers slice to n_out.
    """
    n = vals.shape[0]
    assert n % et == 0, "pad the update stream to a tile multiple"
    n_out_pad = ((n_out + u - 1) // u) * u
    n_tiles = n // et
    n_parts = n_out_pad // u

    # scalar prefetch: first/last tile overlapping each partition
    tile_of = idx_sorted // u  # partition of each update
    first_in_tile = tile_of[:: et]  # [T] partition of each tile's first lane
    tmp = jnp.minimum(tile_of, n_parts - 1)
    last_in_tile = tmp[et - 1 :: et]
    parts = jnp.arange(n_parts, dtype=jnp.int32)
    # tile t overlaps partition p iff first_in_tile[t] <= p <= last_in_tile[t]
    tile_lo = jnp.searchsorted(last_in_tile, parts, side="left").astype(jnp.int32)
    tile_hi = (
        jnp.searchsorted(first_in_tile, parts, side="right").astype(jnp.int32) - 1
    )
    tile_lo = jnp.minimum(tile_lo, n_tiles - 1)
    tile_hi = jnp.clip(tile_hi, 0, n_tiles - 1)

    def idx_map_in(p, t, lo_ref, hi_ref):
        return (0, jnp.clip(t, lo_ref[p], hi_ref[p]))

    def idx_map_out(p, t, lo_ref, hi_ref):
        return (0, p)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_parts, n_tiles),
        in_specs=[
            pl.BlockSpec((1, et), idx_map_in),
            pl.BlockSpec((1, et), idx_map_in),
        ],
        out_specs=pl.BlockSpec((1, u), idx_map_out),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, op=op, u=u, et=et),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, n_out_pad), vals.dtype),
        interpret=interpret,
    )(tile_lo, tile_hi, idx_sorted[None, :], vals[None, :])
    return out[0]
