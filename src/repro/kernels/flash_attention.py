"""Pallas TPU kernel: blocked online-softmax attention (FlashAttention).

Beyond-paper optimization for the LM stack: the assigned architectures'
prefill path is attention-FLOP dominated at 32k context; a blocked online
softmax keeps the working set in VMEM (Bq x Dh, Bk x Dh, Bq x Bk tiles)
instead of materializing the [L, L] score matrix in HBM.

Supports causal masking, sliding windows (h2o-danube / zamba2 long
context), and GQA (kv heads broadcast outside the kernel).

Grid = (B*H, num_q_blocks, num_k_blocks); the running (m, l, acc) state
lives in VMEM scratch and persists across the k-block inner loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, block_q: int, block_k: int, causal: bool, window: int, lq: int, lk: int, scale: float,
):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions (decode alignment: query i sits at lk - lq + i)
    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + (lk - lq)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    run = True
    s = jnp.dot(
        q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    ) * scale  # [bq, bk]
    mask = k_pos < lk
    if causal:
        mask = jnp.logical_and(mask, k_pos <= q_pos)
    if window > 0:
        mask = jnp.logical_and(mask, k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_ref[:, 0] = m_cur

    @pl.when(kb == nk - 1)
    def _fini():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention_call(
    q: jnp.ndarray,  # [B, H, Lq, Dh]
    k: jnp.ndarray,  # [B, Hkv, Lk, Dh]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, lq, dh = q.shape
    hkv = k.shape[1]
    if hkv != h:  # GQA: broadcast kv heads (outside the kernel)
        k = jnp.repeat(k, h // hkv, axis=1)
        v = jnp.repeat(v, h // hkv, axis=1)
    lk = k.shape[2]
    block_q = min(block_q, max(8, 1 << (lq - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (lk - 1).bit_length()))
    lq_pad = ((lq + block_q - 1) // block_q) * block_q
    lk_pad = ((lk + block_k - 1) // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, lq_pad - lq), (0, 0))).reshape(b * h, lq_pad, dh)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, lk_pad - lk), (0, 0))).reshape(b * h, lk_pad, dh)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, lk_pad - lk), (0, 0))).reshape(b * h, lk_pad, dh)
    grid = (b * h, lq_pad // block_q, lk_pad // block_k)
    scale = 1.0 / float(dh) ** 0.5

    out = pl.pallas_call(
        functools.partial(
            _kernel, block_q=block_q, block_k=block_k, causal=causal,
            window=window, lq=lq, lk=lk, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, block_k, dh), lambda bh, qb, kb: (bh, kb, 0)),
            pl.BlockSpec((1, block_k, dh), lambda bh, qb, kb: (bh, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, lq_pad, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(b, h, lq_pad, dh)[:, :, :lq, :]
