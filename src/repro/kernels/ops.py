"""Jit'd public wrappers around the Pallas kernels.

These handle padding/sorting conventions so callers (the DSL back-end, the
MoE layer) see clean semantics; the underlying kernels keep hardware-shaped
contracts (tile multiples, sorted streams).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _pad_to(x: jnp.ndarray, n: int, value) -> jnp.ndarray:
    pad = n - x.shape[0]
    if pad <= 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], value, x.dtype)])


@functools.partial(jax.jit, static_argnames=("n_out", "op", "interpret", "u", "et"))
def shuffle_reduce(
    vals: jnp.ndarray,
    idx: jnp.ndarray,
    n_out: int,
    op: str = "+",
    *,
    interpret: bool = True,
    u: int = 512,
    et: int = 1024,
) -> jnp.ndarray:
    """Scatter-reduce (unsorted) updates into ``n_out`` bins via the Pallas
    shuffle kernel. Matches ``ref.shuffle_reduce_ref`` exactly."""
    from .shuffle_reduce import shuffle_reduce_sorted

    n = vals.shape[0]
    et = min(et, max(128, 1 << (max(1, n) - 1).bit_length()))
    u = min(u, max(128, 1 << (max(1, n_out) - 1).bit_length()))
    perm = jnp.argsort(idx)  # the shuffle-routing decision
    idx_s = idx[perm].astype(jnp.int32)
    vals_s = vals[perm]
    n_pad = ((n + et - 1) // et) * et
    from .ref import _identity

    idx_s = _pad_to(idx_s, n_pad, jnp.int32(2**31 - 1))
    vals_s = _pad_to(vals_s, n_pad, _identity(op, vals.dtype))
    out = shuffle_reduce_sorted(
        vals_s, idx_s, n_out=n_out, op=op, u=u, et=et, interpret=interpret
    )
    return out[:n_out]


@functools.partial(jax.jit, static_argnames=("n_out", "op", "interpret", "u", "et"))
def shuffle_reduce_batched(
    vals: jnp.ndarray,
    idx: jnp.ndarray,
    n_out: int,
    op: str = "+",
    *,
    interpret: bool = True,
    u: int = 512,
    et: int = 1024,
) -> jnp.ndarray:
    """Batched scatter-reduce: ``[K, N]`` update lanes into ``[K, n_out]``.

    One Pallas launch serves the whole batch: each lane's destinations are
    offset into a private bin range (``idx + k * n_out``) and the flattened
    ``[K * N]`` stream reduces into ``K * n_out`` bins — the multi-query
    analogue of the shuffle network, with the batch axis materialized as
    extra output partitions instead of extra launches. ``idx`` may be
    shared (``[N]``, e.g. a fixed dst array) or per-lane (``[K, N]``).
    Row ``k`` of the result equals ``shuffle_reduce(vals[k], idx[k], n_out,
    op)`` — bit-identical for min/max and integer reductions; float sums
    can differ in the last ulp where the flattened stream's tile boundaries
    regroup the additions.
    """
    k, n = vals.shape
    idx = jnp.broadcast_to(idx, (k, n)) if idx.ndim == 1 else idx
    offsets = (jnp.arange(k, dtype=jnp.int32) * n_out)[:, None]
    flat_idx = (idx.astype(jnp.int32) + offsets).reshape(-1)
    out = shuffle_reduce(
        vals.reshape(-1), flat_idx, k * n_out, op, interpret=interpret, u=u, et=et
    )
    return out.reshape(k, n_out)


@functools.partial(jax.jit, static_argnames=("n_out", "apply_op", "reduce_op", "interpret"))
def edge_stream_batched(
    src_vals: jnp.ndarray,
    weights: jnp.ndarray,
    dst: jnp.ndarray,
    active: jnp.ndarray,
    n_out: int,
    apply_op: str = "add",
    reduce_op: str = "min",
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched fused edge pipeline: ``[K, E]`` gathered operands in ONE kernel.

    Same bin-offset flattening as :func:`shuffle_reduce_batched`: the K
    per-query edge streams concatenate into one sorted stream whose
    destination ids index ``K * n_out`` partitions, so the whole batch
    costs one gather->apply->shuffle->reduce launch. ``weights`` / ``dst``
    / ``active`` may each be shared (``[E]``) or per-lane (``[K, E]``).
    Row ``k`` equals ``edge_stream(src_vals[k], ..., n_out, ...)`` —
    bit-identical for min/max and integer reductions; float sums can
    differ in the last ulp where tile boundaries regroup the additions.
    """
    k, n = src_vals.shape
    weights = jnp.broadcast_to(weights, (k, n)) if weights.ndim == 1 else weights
    dst = jnp.broadcast_to(dst, (k, n)) if dst.ndim == 1 else dst
    active = jnp.broadcast_to(active, (k, n)) if active.ndim == 1 else active
    offsets = (jnp.arange(k, dtype=jnp.int32) * n_out)[:, None]
    flat_dst = (dst.astype(jnp.int32) + offsets).reshape(-1)
    out = edge_stream(
        src_vals.reshape(-1), weights.reshape(-1), flat_dst, active.reshape(-1),
        k * n_out, apply_op, reduce_op, interpret=interpret,
    )
    return out.reshape(k, n_out)


@functools.partial(jax.jit, static_argnames=("n_out", "apply_op", "reduce_op", "interpret"))
def edge_stream(
    src_vals: jnp.ndarray,
    weights: jnp.ndarray,
    dst: jnp.ndarray,
    active: jnp.ndarray,
    n_out: int,
    apply_op: str = "add",
    reduce_op: str = "min",
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused gather->apply->shuffle->reduce edge pipeline (paper Fig. 4)."""
    from .edge_stream import edge_stream_call

    return edge_stream_call(
        src_vals, weights, dst, active, n_out=n_out, apply_op=apply_op,
        reduce_op=reduce_op, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def moe_gather(
    tokens_sorted: jnp.ndarray,
    group_offsets: jnp.ndarray,
    group_sizes: jnp.ndarray,
    capacity: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Capacity-binned expert gather via the Pallas dispatch kernel."""
    from .moe_dispatch import moe_gather_call

    return moe_gather_call(
        tokens_sorted, group_offsets, group_sizes, capacity, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Blocked online-softmax attention (beyond-paper LM hot-spot kernel)."""
    from .flash_attention import flash_attention_call

    return flash_attention_call(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
