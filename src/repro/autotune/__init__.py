"""repro.autotune: profile-guided design-space exploration over Targets.

Graphitron's back-end exposes algorithm-independent hardware knobs —
burst/caching memory access, conflict-free shuffling, frontier
compaction, partition sizing — whose best combination varies per
algorithm and graph shape. On FPGAs picking that combination is design-
space exploration; this module is its software twin over
:class:`~repro.core.target.Target`:

    program = repro.compile(src)
    report  = repro.autotune.AutoTuner().tune(program, graph,
                                              params={"root": 0})
    acc     = program.lower(report.config.target, graph=graph)

The search is **analysis-pruned enumeration followed by measured
trials**:

* *Pruning* consults the static-analysis layer before any measurement:
  GT101-racy programs can never disable ``shuffle`` (the engine forces
  it back on, so ``shuffle=False`` candidates are dead duplicates), and
  pipelines whose edge kernels all carry a ``DENSE`` direction verdict
  skip ``compact_frontier`` variants (compaction never fires without a
  sparse frontier). ``pallas`` is pinned to the base target — routing
  through interpreted Pallas is a correctness axis, not a tuning axis.
* *Cost-model warm start* orders the surviving candidates by a static
  estimate derived from ``accelerator.report()`` per-kernel FLOPs/bytes
  (``None`` estimates from backends without XLA cost analysis degrade
  to lane-count fallbacks — a missing estimate never crashes a trial).
* *Measured trials* lower each candidate, bind it to the probe graph,
  and take the best-of-``reps`` objective: the sum of ``launch:<kernel>``
  span aggregates from :mod:`repro.telemetry` (wall time as fallback
  when tracing yields no launch spans). A candidate whose first
  repetition already exceeds ``margin`` x the incumbent is *dominated*
  and dropped without finishing its repetitions.

The winning :class:`TunedConfig` is keyed on (MIR fingerprint x
geometric shape bucket) and persisted in a :class:`TuningCache` living
alongside the artifact store (``<artifact_dir>/tuning/<key>.json``), so

* ``program.lower(..., tuned=True)`` transparently swaps in the tuned
  Target on a cache hit — a pure lookup, zero re-search;
* the serving tier (:class:`~repro.serving.GraphService`) resolves every
  submission's Target through the same cache and counts ``tuned_hits``
  per program in ``service.stats()``;
* ``Accelerator.save`` stamps the config into the artifact manifest, so
  a fresh process that loads the artifact knows it runs a tuned Target.

``python -m repro.autotune`` is the offline CLI;
``python -m repro.launch.serve --graph bfs --autotune`` tunes online
before serving.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
import weakref
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

from ..core import mir
from ..core.accelerator import Accelerator, GraphShape
from ..core.target import Target
from .. import telemetry as tel

__all__ = [
    "AutoTuner",
    "TunedConfig",
    "TuneReport",
    "TuningCache",
    "autotune",
    "default_tuning_dir",
    "shape_bucket",
    "tuning_key",
]

#: Target knobs the tuner searches (boolean grid) — the paper's
#: algorithm-independent memory-access optimizations (§III-C3).
SEARCHED_KNOBS: Tuple[str, ...] = (
    "burst", "cache", "shuffle", "compact_frontier",
)

#: Objective identifier recorded in every TunedConfig: the per-run sum of
#: ``launch:<kernel>`` span totals from repro.telemetry.
OBJECTIVE = "launch_total_s"


def default_tuning_dir() -> str:
    """The TuningCache's on-disk home: ``<artifact store>/tuning``.

    Nesting under the artifact store means one CI cache entry
    (``~/.cache/repro-artifacts``) persists both artifacts and tuned
    configs across runs.
    """
    from ..serving.registry import default_artifact_dir

    return os.path.join(default_artifact_dir(), "tuning")


def tuning_dir_for(store_dir: Optional[str]) -> Optional[str]:
    """Tuning-cache directory colocated with an artifact store dir."""
    return os.path.join(store_dir, "tuning") if store_dir else None


_MIR_FP_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def program_mir_fingerprint(program) -> str:
    """The MIR-only content hash tuned configs are keyed on.

    Options-independent on purpose: the knobs being tuned live on Target,
    not CompileOptions, so the text and embedded twins of one algorithm
    (and every options ablation of it) share tuned configs. Memoized per
    Program object — the serving tier consults it on every submission.
    """
    try:
        fp = _MIR_FP_CACHE.get(program)
    except TypeError:  # unhashable/unweakrefable stand-in (tests)
        return mir.fingerprint(program.module)
    if fp is None:
        fp = mir.fingerprint(program.module)
        _MIR_FP_CACHE[program] = fp
    return fp


def shape_bucket(graph=None, shape: Optional[GraphShape] = None) -> GraphShape:
    """The geometric shape bucket a tuned config is keyed on.

    Graphs key on their *logical* counts (padding-invariant: a graph and
    its padded twin tune once); explicit shapes key on their counts
    directly. Both go through :meth:`GraphShape.bucket_for`, so similar
    sizes alias one tuned config.
    """
    if graph is not None:
        return GraphShape.bucket_for(
            int(graph.n_vertices_logical), int(graph.n_edges_logical),
            weighted=bool(graph.weighted),
        )
    if shape is None:
        raise ValueError("shape_bucket needs graph= or shape=")
    return GraphShape.bucket_for(
        shape.n_vertices, shape.n_edges, weighted=shape.weighted
    )


def tuning_key(mir_fingerprint: str, bucket: GraphShape,
               kind: str = "local") -> str:
    """Content key of one tuned config: MIR x shape bucket x backend kind."""
    h = hashlib.sha256()
    h.update(mir_fingerprint.encode("ascii"))
    h.update(b"\x00")
    h.update(repr(bucket).encode("utf-8"))
    h.update(b"\x00")
    h.update(kind.encode("ascii"))
    return h.hexdigest()


@dataclass(frozen=True)
class TunedConfig:
    """The winner of one search: a Target plus the evidence behind it."""

    mir_fingerprint: str
    bucket: GraphShape
    target: Target
    objective_s: float          # best measured objective of the winner
    baseline_s: float           # same objective under Target.baseline()
    trials: int                 # measured candidates in the producing search
    objective: str = OBJECTIVE

    @property
    def speedup(self) -> float:
        return self.baseline_s / max(self.objective_s, 1e-12)

    @property
    def key(self) -> str:
        return tuning_key(self.mir_fingerprint, self.bucket, self.target.kind)

    def to_dict(self) -> dict:
        return {
            "mir_fingerprint": self.mir_fingerprint,
            "bucket": self.bucket.to_dict(),
            "target": self.target.to_dict(),
            "objective_s": self.objective_s,
            "baseline_s": self.baseline_s,
            "trials": self.trials,
            "objective": self.objective,
        }

    @staticmethod
    def from_dict(d: dict) -> "TunedConfig":
        return TunedConfig(
            mir_fingerprint=str(d["mir_fingerprint"]),
            bucket=GraphShape(**d["bucket"]),
            target=Target.from_dict(d["target"]),
            objective_s=float(d["objective_s"]),
            baseline_s=float(d["baseline_s"]),
            trials=int(d["trials"]),
            objective=str(d.get("objective", OBJECTIVE)),
        )

    def describe(self) -> str:
        return (
            f"tuned[{self.mir_fingerprint[:12]} x "
            f"{self.bucket.n_vertices}v/{self.bucket.n_edges}e] "
            f"{self.target.describe()} — {self.objective}="
            f"{self.objective_s * 1e3:.2f}ms, {self.speedup:.2f}x over "
            f"baseline ({self.trials} trials)"
        )


class TuningCache:
    """Persistent (MIR x bucket x kind) -> :class:`TunedConfig` store.

    A thread-safe in-memory map over per-key JSON files in ``store_dir``
    (``None`` = memory-only). One file per key keeps writes atomic-enough
    for concurrent tuners (last writer wins, both winners are measured-
    valid) and lets CI persist the directory with the artifact cache.
    ``hits``/``misses``/``stores`` counters feed the ci_bench gate.
    """

    def __init__(self, store_dir: Optional[str] = None) -> None:
        self.store_dir = store_dir
        self._lock = threading.Lock()
        self._mem: Dict[str, TunedConfig] = {}
        self.hits = 0
        self.misses = 0
        self.stores = 0

    def _path(self, key: str) -> Optional[str]:
        if not self.store_dir:
            return None
        return os.path.join(self.store_dir, f"{key[:24]}.json")

    def get(self, mir_fingerprint: str, bucket: GraphShape,
            kind: str = "local") -> Optional[TunedConfig]:
        key = tuning_key(mir_fingerprint, bucket, kind)
        with self._lock:
            cfg = self._mem.get(key)
        if cfg is None:
            path = self._path(key)
            if path and os.path.isfile(path):
                # corrupt/foreign file: a miss, never a crash — the tuner
                # simply searches again and overwrites it
                try:
                    with open(path) as f:
                        cfg = TunedConfig.from_dict(json.load(f))
                except (OSError, ValueError, KeyError, TypeError):
                    cfg = None
                if cfg is not None and cfg.key != key:
                    cfg = None  # renamed/moved file: content disagrees
                if cfg is not None:
                    with self._lock:
                        self._mem[key] = cfg
        with self._lock:
            if cfg is None:
                self.misses += 1
            else:
                self.hits += 1
        return cfg

    def put(self, cfg: TunedConfig) -> None:
        key = cfg.key
        with self._lock:
            self._mem[key] = cfg
            self.stores += 1
        path = self._path(key)
        if path:
            # unwritable store degrades to memory-only, never to a failure
            try:
                os.makedirs(self.store_dir, exist_ok=True)
                tmp = f"{path}.tmp.{os.getpid()}"
                with open(tmp, "w") as f:
                    json.dump(cfg.to_dict(), f, indent=2, sort_keys=True)
                    f.write("\n")
                os.replace(tmp, path)
            except OSError:
                pass

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "entries": len(self._mem),
                "hits": self.hits,
                "misses": self.misses,
                "stores": self.stores,
            }

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def __repr__(self) -> str:
        return (
            f"TuningCache(store={self.store_dir!r}, "
            f"entries={len(self)}, hits={self.hits}, misses={self.misses})"
        )


@dataclass
class TuneReport:
    """What one ``tune()`` call did: the config plus search accounting."""

    config: TunedConfig
    trials: int                 # candidates measured by THIS call (0 = hit)
    cache_hit: bool
    candidates: int             # candidates after pruning (pre-cap)
    pruned: Tuple[str, ...] = ()      # human-readable prune decisions
    measurements: List[Dict[str, Any]] = field(default_factory=list)
    #: the winner's already-lowered Accelerator (stamped with the config;
    #: ready to ``save``); None on a cache hit — lower via
    #: ``program.lower(report.config.target, ...)`` instead
    accelerator: Optional[Accelerator] = None

    def describe(self) -> str:
        how = "cache hit, zero search" if self.cache_hit else (
            f"{self.trials} measured trial(s) over {self.candidates} "
            f"candidate(s)"
        )
        lines = [f"{self.config.describe()}", f"  search: {how}"]
        for p in self.pruned:
            lines.append(f"  pruned: {p}")
        for m in self.measurements:
            mark = "*" if m.get("winner") else (
                "x" if m.get("dominated") else " ")
            lines.append(
                f"  {mark} {m['target']}: "
                f"{m['objective_s'] * 1e3:.2f}ms"
                + (" (dominated)" if m.get("dominated") else "")
            )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# analysis-driven pruning helpers
# ---------------------------------------------------------------------------


def _is_racy(module: mir.Module) -> bool:
    from ..analysis import determinism_certificate

    return determinism_certificate(module) == "racy"


def _kernels_flat(module: mir.Module):
    """Every kernel including pipeline stages (direction lives per stage)."""
    for k in module.kernels.values():
        if isinstance(k, mir.PipelineKernel):
            yield k
            for s in k.stages:
                yield s
        else:
            yield k


def _frontier_relevant(module: mir.Module) -> bool:
    """True when some edge kernel could take the compacted-frontier path.

    A kernel with no frontier annotation never compacts; a ``DENSE``
    direction verdict means the pass proved the frontier loop-invariant
    and the engine always streams the full edge list. Only ``SPARSE`` /
    undecided (``AUTO``) frontier kernels make ``compact_frontier``
    observable.
    """
    for k in _kernels_flat(module):
        if getattr(k, "frontier", None) is None:
            continue
        direction = getattr(k, "direction", mir.Direction.AUTO)
        if direction is not mir.Direction.DENSE:
            return True
    return False


def _has_edge_kernel(module: mir.Module) -> bool:
    return any(
        k.kind is mir.KernelKind.EDGE for k in _kernels_flat(module)
    )


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


class AutoTuner:
    """Searches the Target knob space for one (program, shape bucket).

    Parameters
    ----------
    cache
        The :class:`TuningCache` consulted before and written after a
        search. Defaults to a cache over :func:`default_tuning_dir`.
    reps
        Best-of-``reps`` measured repetitions per surviving candidate.
    margin
        Early-termination factor: a candidate whose *first* repetition
        exceeds ``margin`` x the incumbent best is dominated — its
        remaining repetitions are skipped.
    max_candidates
        Cap on measured candidates; the cost-model ranking decides which
        make the cut (the base target always does).
    """

    def __init__(self, cache: Optional[TuningCache] = None, *,
                 reps: int = 3, margin: float = 1.5,
                 max_candidates: int = 12) -> None:
        if reps < 1:
            raise ValueError("reps must be >= 1")
        if margin <= 1.0:
            raise ValueError("margin must be > 1.0")
        if max_candidates < 1:
            raise ValueError("max_candidates must be >= 1")
        self.cache = cache if cache is not None else TuningCache(
            default_tuning_dir()
        )
        self.reps = reps
        self.margin = margin
        self.max_candidates = max_candidates

    # -- candidate enumeration ------------------------------------------------
    def candidates(self, program, base: Target) -> Tuple[List[Target], List[str]]:
        """Analysis-pruned knob grid around ``base``.

        Returns ``(targets, prune_notes)``. The grid enumerates the
        boolean memory-access knobs; knobs proven irrelevant (or
        forbidden) by static analysis are pinned to their forced value
        instead of doubling the grid.
        """
        module = program.module
        pruned: List[str] = []
        axes: Dict[str, Tuple[Any, ...]] = {}
        for knob in SEARCHED_KNOBS:
            axes[knob] = (True, False)
        if _is_racy(module):
            # the engine forces shuffle back on for racy programs
            # (determinism guard): shuffle=False lowers to the same
            # executable — dead duplicate candidates
            axes["shuffle"] = (True,)
            pruned.append(
                "shuffle pinned on: GT101-racy program (engine forces "
                "deterministic shuffle)"
            )
        if not _frontier_relevant(module):
            axes["compact_frontier"] = (getattr(base, "compact_frontier"),)
            pruned.append(
                "compact_frontier variants skipped: no SPARSE/AUTO frontier "
                "kernel (DENSE verdicts stream the full edge list)"
            )
        if not _has_edge_kernel(module):
            axes["burst"] = (base.burst,)
            axes["cache"] = (base.cache,)
            pruned.append(
                "burst/cache variants skipped: no edge kernel (vertex "
                "streams are already sequential)"
            )
        # pallas is a routing/correctness axis, not a tuning axis: pinned
        out: List[Target] = []
        names = list(axes)
        def rec(i: int, acc: Dict[str, Any]) -> None:
            if i == len(names):
                out.append(replace(base, **acc))
                return
            for v in axes[names[i]]:
                acc[names[i]] = v
                rec(i + 1, acc)
            acc.pop(names[i], None)
        rec(0, {})
        # dedupe while keeping enumeration order (pinning can alias)
        seen = set()
        uniq = []
        for t in out:
            if t not in seen:
                seen.add(t)
                uniq.append(t)
        return uniq, pruned

    # -- cost-model warm start ------------------------------------------------
    @staticmethod
    def _cost_score(candidate: Target, plans) -> float:
        """Static cost estimate used only to *order* measured trials.

        Seeds from the base lowering's per-kernel report. ``None``
        estimates (backends without XLA cost analysis) degrade to the
        flops field's lane-count fallback — ordering quality drops, but
        nothing crashes (the satellite contract of
        ``accelerator.report()``).
        """
        score = 0.0
        for plan in plans:
            unit = plan.bytes_accessed
            if unit is None:
                unit = plan.flops
            if unit is None:
                unit = 1.0
            factor = 1.0
            is_edge = plan.kind in ("edge", "pipeline")
            if is_edge:
                if not candidate.burst:
                    # unpartitioned random-order streaming: the dominant
                    # term — every gather walks DRAM out of order
                    factor *= 1.35
                if not candidate.cache:
                    factor *= 1.10   # no hub-vertex gather cache
                if not candidate.shuffle:
                    factor *= 1.15   # random scatter vs binned reduction
                if candidate.compact_frontier and plan.direction != "dense":
                    factor *= 0.95   # sparse frontiers skip inactive edges
            score += float(unit) * factor
        return score

    # -- measurement ----------------------------------------------------------
    @staticmethod
    def _objective_from_trace(trace: Optional[Dict[str, Any]],
                              wall_s: float) -> float:
        """Sum of ``launch:<kernel>`` span totals, else the wall time."""
        spans = (trace or {}).get("spans") or {}
        total = sum(
            v.get("total_s", 0.0)
            for name, v in spans.items() if name.startswith("launch:")
        )
        return total if total > 0.0 else wall_s

    def _measure(self, program, target: Target, shape: GraphShape, graph,
                 params: Dict[str, Any],
                 stop_after_s: Optional[float]) -> Tuple[float, bool, Accelerator]:
        """Best-of-reps objective for one candidate.

        Returns ``(objective_s, dominated, accelerator)``; ``dominated``
        means the first repetition already exceeded ``stop_after_s`` and
        the remaining repetitions were skipped.
        """
        acc = Accelerator(program, target, shape)
        session = acc.bind(graph)
        try:
            session.run(**params)  # warm-up: jit/dispatch out of the trials
            best = float("inf")
            for rep in range(self.reps):
                t0 = time.perf_counter()
                res = session.run(**params)
                wall = time.perf_counter() - t0
                best = min(best, self._objective_from_trace(
                    getattr(res, "trace", None), wall
                ))
                if rep == 0 and stop_after_s is not None \
                        and best > stop_after_s:
                    return best, True, acc
            return best, False, acc
        finally:
            session.close()

    # -- the search -----------------------------------------------------------
    def tune(self, program, graph, *, params: Optional[Dict[str, Any]] = None,
             target: Optional[Target] = None,
             force: bool = False) -> TuneReport:
        """Resolve (search or recall) the tuned Target for this program
        on this graph's shape bucket.

        ``params`` are the probe query's run-time parameters (required
        parameters of the program must be supplied — e.g. ``{"root": 0}``
        for BFS). ``target`` seeds the search (kind, mesh, pinned knobs);
        defaults to the Target implied by the program's options.
        ``force=True`` re-searches even on a cache hit.
        """
        if target is None:
            target = program.options.resolve_target()
        mir_fp = program_mir_fingerprint(program)
        bucket = shape_bucket(graph=graph)
        if not force:
            cached = self.cache.get(mir_fp, bucket, target.kind)
            if cached is not None:
                return TuneReport(
                    config=cached, trials=0, cache_hit=True, candidates=0,
                )
        params = program.validate_params(dict(params or {}))
        shape = GraphShape.of(graph)
        cands, pruned = self.candidates(program, target)
        sp = tel.get().span(
            "autotune", fingerprint=mir_fp[:16],
            bucket=f"{bucket.n_vertices}v/{bucket.n_edges}e",
            candidates=len(cands),
        ) if tel.enabled() else tel.NULL_SPAN
        with sp:
            report = self._search(
                program, graph, params, target, shape, mir_fp, bucket,
                cands, pruned,
            )
            sp.set(trials=report.trials)
        return report

    def _search(self, program, graph, params, base: Target,
                shape: GraphShape, mir_fp: str, bucket: GraphShape,
                cands: List[Target], pruned: List[str]) -> TuneReport:
        # trials need launch-span objectives: enable tracing for the
        # search, restore the caller's state after (an already-enabled
        # tracer is left untouched — enable() is idempotent)
        was_enabled = tel.enabled()
        if not was_enabled:
            tel.enable()
        try:
            # cost-model warm start: lower the base target once, rank the
            # rest by the static estimate seeded from its report
            measurements: List[Dict[str, Any]] = []
            best_s, _, best_acc = self._measure(
                program, base, shape, graph, params, None
            )
            best_target = base
            trials = 1
            measurements.append({
                "target": base.describe(), "objective_s": best_s,
                "dominated": False,
            })
            plans = best_acc.report().kernels
            rest = [t for t in cands if t != base]
            rest.sort(key=lambda t: self._cost_score(t, plans))
            rest = rest[: max(0, self.max_candidates - 1)]
            for cand in rest:
                obj_s, dominated, acc = self._measure(
                    program, cand, shape, graph, params,
                    stop_after_s=best_s * self.margin,
                )
                trials += 1
                measurements.append({
                    "target": cand.describe(), "objective_s": obj_s,
                    "dominated": dominated,
                })
                if not dominated and obj_s < best_s:
                    best_s, best_target, best_acc = obj_s, cand, acc
            # the baseline referee: measured when not already among the
            # trials, so every TunedConfig records a like-for-like speedup
            baseline = replace(
                Target.baseline(), kind=base.kind, n_devices=base.n_devices,
                axis=base.axis, interpret=base.interpret,
            )
            baseline_s = next(
                (m["objective_s"] for m, t in zip(measurements, [base] + rest)
                 if t == baseline and not m["dominated"]),
                None,
            )
            if baseline_s is None:
                baseline_s, _, base_acc = self._measure(
                    program, baseline, shape, graph, params, None
                )
                trials += 1
                measurements.append({
                    "target": baseline.describe(),
                    "objective_s": baseline_s, "dominated": False,
                })
                # the referee competes too: "tuned" must never be slower
                # than the all-optimizations-off baseline it is judged
                # against
                if baseline_s < best_s:
                    best_s, best_target, best_acc = (
                        baseline_s, baseline, base_acc
                    )
            for m in measurements:
                m["winner"] = m["target"] == best_target.describe()
            cfg = TunedConfig(
                mir_fingerprint=mir_fp, bucket=bucket, target=best_target,
                objective_s=best_s, baseline_s=baseline_s, trials=trials,
            )
            self.cache.put(cfg)
            best_acc.tuned = cfg.to_dict()
            return TuneReport(
                config=cfg, trials=trials, cache_hit=False,
                candidates=len(cands), pruned=tuple(pruned),
                measurements=measurements, accelerator=best_acc,
            )
        finally:
            if not was_enabled:
                tel.disable()


def autotune(program, graph, *, params: Optional[Dict[str, Any]] = None,
             cache: Optional[TuningCache] = None,
             target: Optional[Target] = None,
             force: bool = False, **tuner_opts) -> TuneReport:
    """One-call convenience: ``AutoTuner(cache, **opts).tune(...)``."""
    return AutoTuner(cache, **tuner_opts).tune(
        program, graph, params=params, target=target, force=force
    )
