"""Offline autotuning CLI: search once, serve tuned forever.

    PYTHONPATH=src python -m repro.autotune --algo bfs \
        --vertices 2000 --edges 16000 --param root=0

    PYTHONPATH=src python -m repro.autotune path/to/program.gt \
        --param root=0 --store /var/cache/repro-artifacts

Compiles the program (a built-in algorithm name via ``--algo`` or a
``.gt`` file path), generates a synthetic power-law probe graph of the
requested bucket, runs the :class:`~repro.autotune.AutoTuner` search,
and persists the winning :class:`~repro.autotune.TunedConfig` into the
TuningCache under the artifact store — after which
``program.lower(..., tuned=True)``, ``repro.run``, and
``repro.serve()`` pick the tuned Target with zero re-search.
"""
from __future__ import annotations

import argparse
import json
import sys


def _parse_param(text: str):
    name, _, raw = text.partition("=")
    if not _:
        raise argparse.ArgumentTypeError(
            f"--param expects name=value, got {text!r}"
        )
    for conv in (int, float):
        try:
            return name, conv(raw)
        except ValueError:
            continue
    if raw.lower() in ("true", "false"):
        return name, raw.lower() == "true"
    raise argparse.ArgumentTypeError(
        f"--param {name}: value {raw!r} is not an int/float/bool"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.autotune", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("source", nargs="?", default=None,
                    help=".gt program file to tune (or use --algo)")
    ap.add_argument("--algo", default=None,
                    help="built-in algorithm name (bfs, pagerank, sssp, ...)")
    ap.add_argument("--vertices", type=int, default=2000)
    ap.add_argument("--edges", type=int, default=16000)
    ap.add_argument("--weighted", action="store_true",
                    help="probe with a weighted graph (sssp-class programs)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--param", action="append", type=_parse_param,
                    default=[], metavar="NAME=VALUE",
                    help="probe-query run-time parameter (repeatable)")
    ap.add_argument("--store", default=None,
                    help="artifact store dir; the TuningCache lives in "
                         "<store>/tuning (default: $REPRO_ARTIFACT_DIR / "
                         "~/.cache/repro-artifacts)")
    ap.add_argument("--reps", type=int, default=3,
                    help="best-of-N repetitions per candidate")
    ap.add_argument("--max-candidates", type=int, default=12)
    ap.add_argument("--force", action="store_true",
                    help="re-search even when the cache already holds a "
                         "config for this (program, bucket)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the TuneReport as JSON")
    args = ap.parse_args(argv)

    from repro.core.program import compile_program
    from repro.graph import generators
    from repro.serving.service import NAMED_ALGORITHMS

    from . import AutoTuner, TuningCache, default_tuning_dir, tuning_dir_for

    if (args.source is None) == (args.algo is None):
        ap.error("pass exactly one of a .gt file path or --algo NAME")
    if args.algo is not None:
        if args.algo not in NAMED_ALGORITHMS:
            ap.error(f"unknown --algo {args.algo!r}; built-ins: "
                     f"{', '.join(sorted(NAMED_ALGORITHMS))}")
        src = NAMED_ALGORITHMS[args.algo]
        weighted = args.weighted or args.algo in ("sssp", "cgaw")
    else:
        try:
            with open(args.source) as f:
                src = f.read()
        except OSError as e:
            ap.error(f"cannot read {args.source}: {e}")
        weighted = args.weighted

    program = compile_program(src)
    graph = generators.power_law(
        args.vertices, args.edges, seed=args.seed, weighted=weighted
    )
    cache = TuningCache(
        tuning_dir_for(args.store) if args.store else default_tuning_dir()
    )
    tuner = AutoTuner(cache, reps=args.reps,
                      max_candidates=args.max_candidates)
    report = tuner.tune(program, graph, params=dict(args.param),
                        force=args.force)
    if args.as_json:
        print(json.dumps({
            "config": report.config.to_dict(),
            "trials": report.trials,
            "cache_hit": report.cache_hit,
            "candidates": report.candidates,
            "pruned": list(report.pruned),
            "measurements": report.measurements,
            "cache": cache.stats(),
            "store": cache.store_dir,
        }, indent=2, sort_keys=True))
    else:
        print(report.describe())
        print(f"cache: {cache!r}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
