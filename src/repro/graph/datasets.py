"""Table II dataset registry.

The paper's benchmarks (Table II) are synthesized to their published
statistics because this environment has no network access:

    rmat-19-32 (R19)  |V|=524K |E|=16.8M  deg=32    synthetic (Kronecker)
    HiggsTwitter (HT) |V|=457K |E|=14.9M  deg=32.5  social (power law)
    wiki-topcats (TC) |V|=1.8M |E|=28.5M  deg=15.9  web (power law)
    Amazon2003 (AM)   |V|=403K |E|=3.4M   deg=8.4   social (power law)
    pokec (PK)        |V|=1.6M |E|=30.6M  deg=18.8  social (power law)

``scale`` shrinks |V| and |E| proportionally (CPU-friendly benchmarking);
``scale=1.0`` reproduces the full published sizes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from .generators import power_law, rmat
from .storage import GraphData


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    short: str
    n_vertices: int
    n_edges: int
    kind: str  # 'rmat' | 'power_law'


TABLE_II = {
    "R19": DatasetSpec("rmat-19-32", "R19", 524_288, 16_800_000, "rmat"),
    "HT": DatasetSpec("HiggsTwitter", "HT", 457_000, 14_900_000, "power_law"),
    "TC": DatasetSpec("wiki-topcats", "TC", 1_800_000, 28_500_000, "power_law"),
    "AM": DatasetSpec("Amazon2003", "AM", 403_000, 3_400_000, "power_law"),
    "PK": DatasetSpec("pokec-relationships", "PK", 1_600_000, 30_600_000, "power_law"),
}


def make_dataset(short: str, scale: float = 1.0, weighted: bool = False, seed: int = 0) -> GraphData:
    spec = TABLE_II[short]
    n_v = max(64, int(spec.n_vertices * scale))
    n_e = max(256, int(spec.n_edges * scale))
    if spec.kind == "rmat":
        # choose RMAT scale/edge-factor approximating the target sizes
        s = max(6, (n_v - 1).bit_length())
        ef = max(1, round(n_e / (1 << s)))
        return rmat(s, ef, seed=seed, weighted=weighted)
    return power_law(n_v, n_e, seed=seed, weighted=weighted)


def available() -> Dict[str, DatasetSpec]:
    return dict(TABLE_II)
