from .storage import GraphData, PartitionedEdges
from . import generators, datasets

__all__ = ["GraphData", "PartitionedEdges", "generators", "datasets"]
