from .storage import GraphData, GraphDelta, GraphUpdateError, PartitionedEdges
from . import generators, datasets

__all__ = [
    "GraphData",
    "GraphDelta",
    "GraphUpdateError",
    "PartitionedEdges",
    "generators",
    "datasets",
]
