"""Graph generators: RMAT (Kronecker), uniform, and small fixtures.

No network access is available, so the Table II datasets are synthesized to
the published (|V|, |E|, avg-degree, skew) statistics (see datasets.py).
RMAT follows Leskovec et al. (Kronecker graphs), the same generator behind
rmat-19-32 in the paper.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .storage import GraphData


def rmat(
    scale: int,
    edge_factor: int,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    weighted: bool = False,
    max_weight: int = 64,
) -> GraphData:
    """RMAT generator (Graph500 parameters by default)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    d = 1.0 - a - b - c
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    # vectorized bit-by-bit Kronecker recursion
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities, with noise to avoid exact self-similarity
        go_right = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        go_down = (r >= a) & (r < a + b) | (r >= a + b + c)
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    w = rng.integers(1, max_weight, m).astype(np.float32) if weighted else None
    return GraphData(n, src.astype(np.int32), dst.astype(np.int32), w)


def uniform_random(
    n_vertices: int,
    n_edges: int,
    seed: int = 0,
    weighted: bool = False,
    max_weight: int = 64,
) -> GraphData:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges).astype(np.int32)
    dst = rng.integers(0, n_vertices, n_edges).astype(np.int32)
    w = rng.integers(1, max_weight, n_edges).astype(np.float32) if weighted else None
    return GraphData(n_vertices, src, dst, w)


def power_law(
    n_vertices: int,
    n_edges: int,
    exponent: float = 2.1,
    seed: int = 0,
    weighted: bool = False,
    max_weight: int = 64,
) -> GraphData:
    """Power-law (social-network-like) graph via weighted vertex sampling."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_vertices + 1, dtype=np.float64)
    p = ranks ** (-1.0 / (exponent - 1.0))
    p /= p.sum()
    src = rng.choice(n_vertices, n_edges, p=p).astype(np.int32)
    dst = rng.choice(n_vertices, n_edges, p=p).astype(np.int32)
    perm = rng.permutation(n_vertices).astype(np.int32)  # de-correlate id/degree
    w = rng.integers(1, max_weight, n_edges).astype(np.float32) if weighted else None
    return GraphData(n_vertices, perm[src], perm[dst], w)


def chain(n: int, weighted: bool = False) -> GraphData:
    src = np.arange(n - 1, dtype=np.int32)
    dst = src + 1
    w = np.ones(n - 1, np.float32) if weighted else None
    return GraphData(n, src, dst, w)


def deep_chain(n: int, multiplicity: int = 1000,
               weighted: bool = False) -> GraphData:
    """A diameter-``n`` chain with ``multiplicity`` parallel edges per hop
    (both directions).

    The frontier-compaction stress fixture: BFS walks ``n`` levels whose
    frontiers are single vertices, while full-edge streaming pays the
    whole ``2*(n-1)*multiplicity`` edge list at every level — the regime
    where the direction optimization structurally pays (paper Fig. 2),
    and the autotuner's gated workload.
    """
    f = np.arange(n - 1, dtype=np.int32)
    src = np.concatenate([np.repeat(f, multiplicity),
                          np.repeat(f + 1, multiplicity)])
    dst = np.concatenate([np.repeat(f + 1, multiplicity),
                          np.repeat(f, multiplicity)])
    w = np.ones(src.shape[0], np.float32) if weighted else None
    return GraphData(n, src, dst, w)


def star(n: int, weighted: bool = False) -> GraphData:
    """Hub 0 points at everyone — the hub-cache stress fixture."""
    src = np.zeros(n - 1, dtype=np.int32)
    dst = np.arange(1, n, dtype=np.int32)
    w = np.ones(n - 1, np.float32) if weighted else None
    return GraphData(n, src, dst, w)


def grid2d(side: int, weighted: bool = False) -> GraphData:
    idx = np.arange(side * side).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()])
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()])
    e = np.concatenate([right, down], axis=1)
    w = np.ones(e.shape[1], np.float32) if weighted else None
    return GraphData(side * side, e[0].astype(np.int32), e[1].astype(np.int32), w)


def load_edge_list(path: str, weighted: Optional[bool] = None) -> GraphData:
    """SNAP-style whitespace edge list loader: ``src dst [weight]`` lines."""
    rows = []
    with open(path) as f:
        for ln in f:
            ln = ln.strip()
            if not ln or ln.startswith(("#", "%")):
                continue
            parts = ln.split()
            rows.append([float(x) for x in parts[:3]])
    arr = np.asarray(rows)
    src = arr[:, 0].astype(np.int32)
    dst = arr[:, 1].astype(np.int32)
    has_w = arr.shape[1] >= 3 if weighted is None else weighted
    w = arr[:, 2].astype(np.float32) if (has_w and arr.shape[1] >= 3) else None
    n = int(max(src.max(), dst.max())) + 1 if len(src) else 0
    return GraphData(n, src, dst, w)
