"""Graph storage substrate: COO / CSR, partitioning, hub detection.

This is the memory layout layer of the back-end framework (paper Fig. 4):

* **EdgeList (COO)** feeds edge-centric kernels ("Burst Read" of edges).
* **CSR** feeds vertex-centric kernels (``v.getNeighbors()``).
* **dst-range partitioning** sizes each destination slice to VMEM (the
  paper sizes partitions to URAM, §III-D) with ascending-src order inside
  each partition.
* **hub relabeling** maps the highest-degree vertices to the lowest ids so
  a dense prefix of every property vector acts as the hub cache (paper
  Fig. 7(b)).
* **dst-sorted permutation** drives the conflict-free shuffle reduction
  (paper Fig. 7(c)): with a static graph the shuffle network's routing is
  precomputed as a permutation, and the reduce becomes a sorted segment
  reduction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property
from typing import Optional, Tuple

import numpy as np

# int32 indptr covers edge counts below 2^31; the device ABI (and AOT shape
# signatures) standardize every CSR/CSC array on int32, so larger graphs
# must be sharded rather than silently widened to int64
MAX_INT32_EDGES = 2**31


def _indptr_from_degrees(degrees: np.ndarray, n_edges: int) -> np.ndarray:
    """int32 CSR/CSC indptr from a degree vector, with an overflow guard.

    Keeping indptr int32 (like indices/edge_perm) keeps device buffers and
    AOT shape signatures stable; E >= 2^31 cannot be represented and fails
    loudly here instead of wrapping.
    """
    if n_edges >= MAX_INT32_EDGES:
        raise OverflowError(
            f"graph has {n_edges} edges; int32 indptr covers < 2^31 "
            f"({MAX_INT32_EDGES}). Shard the graph (distributed backend) "
            f"instead of widening the device ABI."
        )
    indptr = np.zeros(degrees.shape[0] + 1, dtype=np.int64)
    np.cumsum(degrees, out=indptr[1:])
    return indptr.astype(np.int32)


class GraphUpdateError(RuntimeError):
    """A :class:`GraphDelta` cannot be applied inside the current bucket."""


def _edge_pairs(edges) -> np.ndarray:
    """Coerce an edge collection to an int32 [K, 2] (src, dst) array."""
    if edges is None:
        return np.empty((0, 2), dtype=np.int32)
    arr = np.asarray(edges, dtype=np.int32)
    if arr.size == 0:
        return arr.reshape(0, 2)
    if arr.ndim != 2 or arr.shape[1] != 2:
        raise ValueError(f"edges must be [K, 2] (src, dst) pairs, got shape {arr.shape}")
    return arr


@dataclass(frozen=True)
class GraphDelta:
    """A batch of edge mutations applied atomically by ``apply_updates``.

    ``added_edges`` / ``removed_edges`` are [K, 2] (src, dst) pairs (any
    array-like; coerced to int32). ``added_weights`` optionally carries one
    weight per added edge; weighted graphs default missing weights to 1.
    """

    added_edges: Optional[np.ndarray] = None  # int32 [K, 2]
    removed_edges: Optional[np.ndarray] = None  # int32 [K, 2]
    added_weights: Optional[np.ndarray] = None  # [K] or None

    def __post_init__(self):
        object.__setattr__(self, "added_edges", _edge_pairs(self.added_edges))
        object.__setattr__(self, "removed_edges", _edge_pairs(self.removed_edges))
        if self.added_weights is not None:
            w = np.asarray(self.added_weights)
            if w.shape != (len(self.added_edges),):
                raise ValueError(
                    f"added_weights shape {w.shape} does not match "
                    f"{len(self.added_edges)} added edges"
                )
            object.__setattr__(self, "added_weights", w)

    @property
    def n_added(self) -> int:
        return int(self.added_edges.shape[0])

    @property
    def n_removed(self) -> int:
        return int(self.removed_edges.shape[0])

    @property
    def additions_only(self) -> bool:
        return self.n_removed == 0

    def endpoints(self) -> np.ndarray:
        """Unique vertex ids touched by the delta (incremental seeds)."""
        return np.unique(
            np.concatenate([self.added_edges.ravel(), self.removed_edges.ravel()])
        )


@dataclass
class GraphData:
    """A graph with precomputed access-optimization metadata.

    Graphs are immutable for every static workflow; the streaming path
    (:mod:`repro.streaming`) mutates one **in place** through
    :meth:`apply_updates`, which recycles ``pad_to`` padding slack as an
    edge free-list so the physical shape — and therefore the
    :class:`~repro.core.accelerator.GraphShape` bucket — never changes.

    ``n_vertices`` / ``n_edges`` are the *physical* (possibly padded)
    counts that size device buffers; ``n_vertices_logical`` /
    ``n_edges_logical`` are the real graph's counts. Globally-normalized
    algorithms (``vertices.size()`` — PageRank's 1/|V| teleport mass) read
    the logical counts, so padded and unpadded runs agree.
    """

    n_vertices: int
    src: np.ndarray  # int32 [E]
    dst: np.ndarray  # int32 [E]
    weights: Optional[np.ndarray] = None  # float32/int32 [E] or None
    n_vertices_logical: Optional[int] = None  # real |V| (defaults to physical)
    n_edges_logical: Optional[int] = None  # real |E| (defaults to physical)
    # bumped by every in-place mutation (apply_updates / compact) so callers
    # holding a reference can detect staleness without hashing arrays
    version: int = field(default=0, init=False, repr=False, compare=False)

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        if self.weights is not None:
            self.weights = np.asarray(self.weights)
        if self.src.shape != self.dst.shape:
            raise ValueError("src/dst shape mismatch")
        if self.n_vertices_logical is None:
            self.n_vertices_logical = self.n_vertices
        if self.n_edges_logical is None:
            self.n_edges_logical = self.n_edges
        if not 0 <= self.n_vertices_logical <= self.n_vertices:
            raise ValueError(
                f"n_vertices_logical={self.n_vertices_logical} outside "
                f"[0, {self.n_vertices}]"
            )
        if not 0 <= self.n_edges_logical <= self.n_edges:
            raise ValueError(
                f"n_edges_logical={self.n_edges_logical} outside [0, {self.n_edges}]"
            )

    # -- basic properties ---------------------------------------------------
    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def weighted(self) -> bool:
        return self.weights is not None

    @cached_property
    def out_degree(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n_vertices).astype(np.int32)

    @cached_property
    def in_degree(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n_vertices).astype(np.int32)

    # -- CSR (out-edges) ------------------------------------------------------
    @cached_property
    def csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(indptr[V+1], indices[E], edge_perm[E]): out-adjacency, all int32.

        ``edge_perm`` maps CSR slot -> original edge id, so edge weights /
        edge properties can be gathered for neighbor iteration.
        """
        order = np.argsort(self.src, kind="stable").astype(np.int32)
        return (
            _indptr_from_degrees(self.out_degree, self.n_edges),
            self.dst[order],
            order,
        )

    @cached_property
    def csc(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(indptr, indices, edge_perm): in-adjacency (pull), all int32."""
        order = np.argsort(self.dst, kind="stable").astype(np.int32)
        return (
            _indptr_from_degrees(self.in_degree, self.n_edges),
            self.src[order],
            order,
        )

    @cached_property
    def row_ids(self) -> np.ndarray:
        """CSR row id per CSR slot: vertex owning each out-edge."""
        indptr, _, _ = self.csr
        return np.repeat(
            np.arange(self.n_vertices, dtype=np.int32),
            np.diff(indptr).astype(np.int64),
        )

    # -- shuffle metadata (paper Fig. 7(c)) ------------------------------------
    @cached_property
    def dst_sort_perm(self) -> np.ndarray:
        """Permutation sorting edges by destination (stable).

        The static-graph analogue of the on-the-fly shuffle network: the
        routing decision is precomputed once, and the runtime reduce is a
        sorted segment reduction (conflict-free by construction).
        """
        return np.argsort(self.dst, kind="stable").astype(np.int32)

    # -- hub cache metadata (paper Fig. 7(b)) ----------------------------------
    @cached_property
    def degree_rank(self) -> np.ndarray:
        """Vertices ordered by (in+out) degree, descending — hubs first."""
        return np.argsort(-(self.out_degree.astype(np.int64) + self.in_degree)).astype(
            np.int32
        )

    def relabel_by_degree(self) -> Tuple["GraphData", np.ndarray]:
        """Return (relabeled graph, old->new map) with hubs at ids [0, K).

        Property vectors of the relabeled graph keep hub entries in a dense
        prefix, which is the software analogue of pinning hub vertices in
        URAM/VMEM: gathers for high-degree vertices hit one small block.
        """
        old2new = np.empty(self.n_vertices, dtype=np.int32)
        old2new[self.degree_rank] = np.arange(self.n_vertices, dtype=np.int32)
        g = GraphData(
            self.n_vertices,
            old2new[self.src],
            old2new[self.dst],
            None if self.weights is None else self.weights.copy(),
            n_vertices_logical=self.n_vertices_logical,
            n_edges_logical=self.n_edges_logical,
        )
        return g, old2new

    # -- dst-range partitioning (paper §III-D) -------------------------------
    def partition_by_dst(self, n_partitions: int) -> "PartitionedEdges":
        """Split edges into ``n_partitions`` contiguous dst ranges.

        Inside each partition edges are ordered by ascending ``src``
        (paper: "organizes edges (src, dst) into subgraphs with ascending
        src values within each subpartition") so source-property reads
        stream near-sequentially while the destination slice stays resident.
        """
        n_partitions = max(1, min(n_partitions, self.n_vertices))
        bounds = np.linspace(0, self.n_vertices, n_partitions + 1).astype(np.int64)
        part_of_edge = np.searchsorted(bounds[1:], self.dst, side="right")
        order = np.lexsort((self.src, part_of_edge)).astype(np.int32)
        counts = np.bincount(part_of_edge, minlength=n_partitions)
        offsets = np.zeros(n_partitions + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        return PartitionedEdges(
            graph=self,
            n_partitions=n_partitions,
            vertex_bounds=bounds,
            edge_order=order,
            edge_offsets=offsets,
        )

    # -- convenience ----------------------------------------------------------
    def with_unit_weights(self) -> "GraphData":
        if self.weighted:
            return self
        return GraphData(
            self.n_vertices,
            self.src,
            self.dst,
            np.ones(self.n_edges, np.float32),
            n_vertices_logical=self.n_vertices_logical,
            n_edges_logical=self.n_edges_logical,
        )

    def pad_to(self, n_vertices: int, n_edges: int) -> "GraphData":
        """Pad to a shape bucket: isolated vertices + padding self-loops.

        The accelerator artifact path (:meth:`repro.Program.lower`) compiles
        against a :class:`~repro.core.accelerator.GraphShape` bucket; graphs
        below the bucket are padded up so they share one lowering. Padding
        edges are self-loops on the LAST padding vertex, so no real vertex's
        degree or neighborhood changes.

        The padded graph carries the original counts as
        ``n_vertices_logical`` / ``n_edges_logical``, and ``size()`` (host
        and kernel) reads the logical counts — so globally-normalized
        algorithms (PageRank's 1/|V| teleport mass, PPR) agree between
        padded and unpadded runs. Padding self-loops double as the edge
        free-list that :meth:`apply_updates` consumes, which is why a
        padding edge must never touch a real vertex.
        """
        pad_v = n_vertices - self.n_vertices
        pad_e = n_edges - self.n_edges
        if pad_v < 0 or pad_e < 0:
            raise ValueError(
                f"pad_to target (|V|={n_vertices}, |E|={n_edges}) is smaller "
                f"than the graph (|V|={self.n_vertices}, |E|={self.n_edges})"
            )
        if pad_v == 0 and pad_e == 0:
            return self
        if pad_e > 0 and pad_v == 0:
            raise ValueError(
                "padding edges need at least one padding vertex to carry the "
                "self-loops (a self-loop on a real vertex would change its "
                "degree); pad n_vertices by >= 1 too"
            )
        loop = np.full(pad_e, n_vertices - 1, dtype=np.int32)
        src = np.concatenate([self.src, loop])
        dst = np.concatenate([self.dst, loop])
        w = None
        if self.weights is not None:
            w = np.concatenate([
                self.weights,
                np.ones(pad_e, dtype=self.weights.dtype),
            ])
        return GraphData(
            n_vertices,
            src,
            dst,
            w,
            n_vertices_logical=self.n_vertices_logical,
            n_edges_logical=self.n_edges_logical,
        )

    # -- streaming updates (repro.streaming) ----------------------------------
    def _invalidate_caches(self) -> None:
        """Drop every cached derived structure after an in-place mutation."""
        for name in ("out_degree", "in_degree", "csr", "csc", "row_ids",
                     "dst_sort_perm", "degree_rank"):
            self.__dict__.pop(name, None)

    def _free_slot_mask(self) -> np.ndarray:
        """Free edge slots: padding self-loops on non-logical vertices."""
        return (self.src == self.dst) & (self.src >= self.n_vertices_logical)

    def apply_updates(self, delta: GraphDelta, *, compact: bool = False) -> "GraphData":
        """Apply an edge delta IN PLACE, reusing padding slack as slots.

        Removed edges are tombstoned — rewritten into padding self-loops on
        the last (padding) vertex, returning their slot to the free list.
        Added edges consume free slots. The physical (|V|, |E|) — and with
        it the :class:`~repro.core.accelerator.GraphShape` bucket — never
        changes, so an update against a bound
        :class:`~repro.core.accelerator.Accelerator` is a shape-check-only
        rebind: no re-lowering, no recompilation.

        The mutation is all-or-nothing: feasibility (removals present,
        enough free slots, endpoints in the logical range) is checked
        before any array is touched, and a :class:`GraphUpdateError` means
        the graph is unchanged — re-pad into a larger bucket (see
        ``GraphShape.bucket_for``) and retry. Expects the ``pad_to``
        padding layout (call on the original graph, never a relabeled one).
        """
        add, rem = delta.added_edges, delta.removed_edges
        lv, le = self.n_vertices_logical, self.n_edges_logical
        for kind, e in (("added", add), ("removed", rem)):
            if e.size and (int(e.min()) < 0 or int(e.max()) >= lv):
                raise GraphUpdateError(
                    f"{kind} edges reference vertex ids outside the logical "
                    f"range [0, {lv}); growing the vertex set needs a re-pad "
                    f"into a larger bucket"
                )
        free_mask = self._free_slot_mask()
        n_free = int(free_mask.sum())
        if n_free != self.n_edges - le:
            raise GraphUpdateError(
                f"padding-slot invariant violated: expected {self.n_edges - le} "
                f"free self-loop slots, found {n_free} (apply_updates needs "
                f"the pad_to layout of the original, unrelabeled graph)"
            )
        # resolve removals to physical slots BEFORE mutating anything, so a
        # failed lookup or overflow leaves the graph untouched
        tomb = np.empty(0, dtype=np.int64)
        if len(rem):
            keys = self.src.astype(np.int64) * self.n_vertices + self.dst
            keys[free_mask] = -1  # free slots are not removable edges
            order = np.argsort(keys, kind="stable")
            skeys = keys[order]
            rkeys = rem[:, 0].astype(np.int64) * self.n_vertices + rem[:, 1]
            uniq, counts = np.unique(rkeys, return_counts=True)
            picks = []
            for k, c in zip(uniq, counts):
                lo = int(np.searchsorted(skeys, k, "left"))
                hi = int(np.searchsorted(skeys, k, "right"))
                if hi - lo < int(c):
                    u, v = divmod(int(k), self.n_vertices)
                    raise GraphUpdateError(
                        f"cannot remove edge ({u}, {v}): {int(c)} removal(s) "
                        f"requested but only {hi - lo} present"
                    )
                picks.append(order[lo:lo + int(c)])
            tomb = np.concatenate(picks)
            if self.n_vertices == lv:
                raise GraphUpdateError(
                    "removals need at least one padding vertex to carry the "
                    "tombstone self-loops; pad_to a larger bucket first"
                )
        if n_free + len(tomb) < len(add):
            need_e = le - len(rem) + len(add)
            raise GraphUpdateError(
                f"delta needs {len(add)} free edge slots but only "
                f"{n_free + len(tomb)} are available in this bucket; re-pad "
                f"to GraphShape.bucket_for({lv}, {need_e}) and re-bind"
            )
        pad_vertex = self.n_vertices - 1
        if len(tomb):
            self.src[tomb] = pad_vertex
            self.dst[tomb] = pad_vertex
            if self.weights is not None:
                self.weights[tomb] = 1
        if len(add):
            free = np.flatnonzero(self._free_slot_mask())
            slots = free[: len(add)]
            self.src[slots] = add[:, 0]
            self.dst[slots] = add[:, 1]
            if self.weights is not None:
                if delta.added_weights is not None:
                    self.weights[slots] = np.asarray(
                        delta.added_weights, dtype=self.weights.dtype
                    )
                else:
                    self.weights[slots] = 1
        self.n_edges_logical = le - len(rem) + len(add)
        self.version += 1
        self._invalidate_caches()
        if compact:
            self.compact()
        return self

    def compact(self) -> "GraphData":
        """Stable-partition real edges ahead of free slots, in place.

        Semantically a no-op (the edge multiset is unchanged), but after
        many tombstone/append cycles it restores the "real edges first,
        padding last" layout ``pad_to`` produced, keeping processing order
        close to the freshly-padded graph's.
        """
        free_mask = self._free_slot_mask()
        if not free_mask.any():
            return self
        order = np.argsort(free_mask, kind="stable")  # real edges first
        self.src = self.src[order]
        self.dst = self.dst[order]
        if self.weights is not None:
            self.weights = self.weights[order]
        self.version += 1
        self._invalidate_caches()
        return self


@dataclass
class PartitionedEdges:
    """dst-range partitioned edge list (the URAM/VMEM sizing unit)."""

    graph: GraphData
    n_partitions: int
    vertex_bounds: np.ndarray  # [P+1] dst-range boundaries
    edge_order: np.ndarray  # [E] permutation: partitioned order -> edge id
    edge_offsets: np.ndarray  # [P+1] edge range per partition

    def partition_edges(self, p: int) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
        sl = slice(self.edge_offsets[p], self.edge_offsets[p + 1])
        ids = self.edge_order[sl]
        w = None if self.graph.weights is None else self.graph.weights[ids]
        return self.graph.src[ids], self.graph.dst[ids], w

    @property
    def max_partition_vertices(self) -> int:
        return int(np.max(np.diff(self.vertex_bounds)))
