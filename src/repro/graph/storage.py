"""Graph storage substrate: COO / CSR, partitioning, hub detection.

This is the memory layout layer of the back-end framework (paper Fig. 4):

* **EdgeList (COO)** feeds edge-centric kernels ("Burst Read" of edges).
* **CSR** feeds vertex-centric kernels (``v.getNeighbors()``).
* **dst-range partitioning** sizes each destination slice to VMEM (the
  paper sizes partitions to URAM, §III-D) with ascending-src order inside
  each partition.
* **hub relabeling** maps the highest-degree vertices to the lowest ids so
  a dense prefix of every property vector acts as the hub cache (paper
  Fig. 7(b)).
* **dst-sorted permutation** drives the conflict-free shuffle reduction
  (paper Fig. 7(c)): with a static graph the shuffle network's routing is
  precomputed as a permutation, and the reduce becomes a sorted segment
  reduction.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Optional, Tuple

import numpy as np

# int32 indptr covers edge counts below 2^31; the device ABI (and AOT shape
# signatures) standardize every CSR/CSC array on int32, so larger graphs
# must be sharded rather than silently widened to int64
MAX_INT32_EDGES = 2**31


def _indptr_from_degrees(degrees: np.ndarray, n_edges: int) -> np.ndarray:
    """int32 CSR/CSC indptr from a degree vector, with an overflow guard.

    Keeping indptr int32 (like indices/edge_perm) keeps device buffers and
    AOT shape signatures stable; E >= 2^31 cannot be represented and fails
    loudly here instead of wrapping.
    """
    if n_edges >= MAX_INT32_EDGES:
        raise OverflowError(
            f"graph has {n_edges} edges; int32 indptr covers < 2^31 "
            f"({MAX_INT32_EDGES}). Shard the graph (distributed backend) "
            f"instead of widening the device ABI."
        )
    indptr = np.zeros(degrees.shape[0] + 1, dtype=np.int64)
    np.cumsum(degrees, out=indptr[1:])
    return indptr.astype(np.int32)


@dataclass
class GraphData:
    """An immutable graph with precomputed access-optimization metadata."""

    n_vertices: int
    src: np.ndarray  # int32 [E]
    dst: np.ndarray  # int32 [E]
    weights: Optional[np.ndarray] = None  # float32/int32 [E] or None

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        if self.weights is not None:
            self.weights = np.asarray(self.weights)
        if self.src.shape != self.dst.shape:
            raise ValueError("src/dst shape mismatch")

    # -- basic properties ---------------------------------------------------
    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def weighted(self) -> bool:
        return self.weights is not None

    @cached_property
    def out_degree(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n_vertices).astype(np.int32)

    @cached_property
    def in_degree(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n_vertices).astype(np.int32)

    # -- CSR (out-edges) ------------------------------------------------------
    @cached_property
    def csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(indptr[V+1], indices[E], edge_perm[E]): out-adjacency, all int32.

        ``edge_perm`` maps CSR slot -> original edge id, so edge weights /
        edge properties can be gathered for neighbor iteration.
        """
        order = np.argsort(self.src, kind="stable").astype(np.int32)
        return (
            _indptr_from_degrees(self.out_degree, self.n_edges),
            self.dst[order],
            order,
        )

    @cached_property
    def csc(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(indptr, indices, edge_perm): in-adjacency (pull), all int32."""
        order = np.argsort(self.dst, kind="stable").astype(np.int32)
        return (
            _indptr_from_degrees(self.in_degree, self.n_edges),
            self.src[order],
            order,
        )

    @cached_property
    def row_ids(self) -> np.ndarray:
        """CSR row id per CSR slot: vertex owning each out-edge."""
        indptr, _, _ = self.csr
        return np.repeat(
            np.arange(self.n_vertices, dtype=np.int32),
            np.diff(indptr).astype(np.int64),
        )

    # -- shuffle metadata (paper Fig. 7(c)) ------------------------------------
    @cached_property
    def dst_sort_perm(self) -> np.ndarray:
        """Permutation sorting edges by destination (stable).

        The static-graph analogue of the on-the-fly shuffle network: the
        routing decision is precomputed once, and the runtime reduce is a
        sorted segment reduction (conflict-free by construction).
        """
        return np.argsort(self.dst, kind="stable").astype(np.int32)

    # -- hub cache metadata (paper Fig. 7(b)) ----------------------------------
    @cached_property
    def degree_rank(self) -> np.ndarray:
        """Vertices ordered by (in+out) degree, descending — hubs first."""
        return np.argsort(-(self.out_degree.astype(np.int64) + self.in_degree)).astype(
            np.int32
        )

    def relabel_by_degree(self) -> Tuple["GraphData", np.ndarray]:
        """Return (relabeled graph, old->new map) with hubs at ids [0, K).

        Property vectors of the relabeled graph keep hub entries in a dense
        prefix, which is the software analogue of pinning hub vertices in
        URAM/VMEM: gathers for high-degree vertices hit one small block.
        """
        old2new = np.empty(self.n_vertices, dtype=np.int32)
        old2new[self.degree_rank] = np.arange(self.n_vertices, dtype=np.int32)
        g = GraphData(
            self.n_vertices,
            old2new[self.src],
            old2new[self.dst],
            None if self.weights is None else self.weights.copy(),
        )
        return g, old2new

    # -- dst-range partitioning (paper §III-D) -------------------------------
    def partition_by_dst(self, n_partitions: int) -> "PartitionedEdges":
        """Split edges into ``n_partitions`` contiguous dst ranges.

        Inside each partition edges are ordered by ascending ``src``
        (paper: "organizes edges (src, dst) into subgraphs with ascending
        src values within each subpartition") so source-property reads
        stream near-sequentially while the destination slice stays resident.
        """
        n_partitions = max(1, min(n_partitions, self.n_vertices))
        bounds = np.linspace(0, self.n_vertices, n_partitions + 1).astype(np.int64)
        part_of_edge = np.searchsorted(bounds[1:], self.dst, side="right")
        order = np.lexsort((self.src, part_of_edge)).astype(np.int32)
        counts = np.bincount(part_of_edge, minlength=n_partitions)
        offsets = np.zeros(n_partitions + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        return PartitionedEdges(
            graph=self,
            n_partitions=n_partitions,
            vertex_bounds=bounds,
            edge_order=order,
            edge_offsets=offsets,
        )

    # -- convenience ----------------------------------------------------------
    def with_unit_weights(self) -> "GraphData":
        if self.weighted:
            return self
        return GraphData(self.n_vertices, self.src, self.dst, np.ones(self.n_edges, np.float32))

    def pad_to(self, n_vertices: int, n_edges: int) -> "GraphData":
        """Pad to a shape bucket: isolated vertices + padding self-loops.

        The accelerator artifact path (:meth:`repro.Program.lower`) compiles
        against a :class:`~repro.core.accelerator.GraphShape` bucket; graphs
        below the bucket are padded up so they share one lowering. Padding
        edges are self-loops on the LAST padding vertex, so no real vertex's
        degree or neighborhood changes.

        The result IS a different graph, though: algorithms whose semantics
        depend on global aggregates — ``vertices.size()`` normalization
        (PageRank's 1/|V| teleport mass, PPR), whole-vertexset reductions —
        observe the padded |V|/|E| and their per-vertex numbers shift
        accordingly. Locally-defined results (BFS levels, SSSP distances,
        WCC labels, k-core, degrees) are unchanged on the real id range.
        Always compare padded runs against padded runs; the equivalence
        guarantee of the Accelerator path is "same padded graph, same
        results", never "padded equals unpadded".
        """
        pad_v = n_vertices - self.n_vertices
        pad_e = n_edges - self.n_edges
        if pad_v < 0 or pad_e < 0:
            raise ValueError(
                f"pad_to target (|V|={n_vertices}, |E|={n_edges}) is smaller "
                f"than the graph (|V|={self.n_vertices}, |E|={self.n_edges})"
            )
        if pad_v == 0 and pad_e == 0:
            return self
        if pad_e > 0 and pad_v == 0:
            raise ValueError(
                "padding edges need at least one padding vertex to carry the "
                "self-loops (a self-loop on a real vertex would change its "
                "degree); pad n_vertices by >= 1 too"
            )
        loop = np.full(pad_e, n_vertices - 1, dtype=np.int32)
        src = np.concatenate([self.src, loop])
        dst = np.concatenate([self.dst, loop])
        w = None
        if self.weights is not None:
            w = np.concatenate([
                self.weights,
                np.ones(pad_e, dtype=self.weights.dtype),
            ])
        return GraphData(n_vertices, src, dst, w)


@dataclass
class PartitionedEdges:
    """dst-range partitioned edge list (the URAM/VMEM sizing unit)."""

    graph: GraphData
    n_partitions: int
    vertex_bounds: np.ndarray  # [P+1] dst-range boundaries
    edge_order: np.ndarray  # [E] permutation: partitioned order -> edge id
    edge_offsets: np.ndarray  # [P+1] edge range per partition

    def partition_edges(self, p: int) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
        sl = slice(self.edge_offsets[p], self.edge_offsets[p + 1])
        ids = self.edge_order[sl]
        w = None if self.graph.weights is None else self.graph.weights[ids]
        return self.graph.src[ids], self.graph.dst[ids], w

    @property
    def max_partition_vertices(self) -> int:
        return int(np.max(np.diff(self.vertex_bounds)))
