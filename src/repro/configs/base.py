"""Architecture config schema + input-shape registry.

Every assigned architecture provides a ``CONFIG`` (exact published numbers)
in its own module; ``registry.get(name)`` loads it. ``SHAPES`` defines the
assigned input-shape set; ``cells()`` enumerates the (arch x shape) dry-run
grid with the documented skips (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | audio | hybrid | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention features
    qk_norm: bool = False
    sliding_window: int = 0  # 0 = full attention
    causal: bool = True
    rope_theta: float = 10000.0
    mrope: bool = False  # M-RoPE (qwen2-vl)
    # MLA (deepseek-v2 family)
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0  # 0 -> head_dim
    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    moe_capacity_factor: float = 1.25  # Switch-style drop capacity
    # SSM / hybrid / xLSTM
    ssm: bool = False
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_heads: int = 0
    attn_every: int = 0  # hybrid: shared attention block every N ssm blocks
    xlstm: bool = False
    slstm_every: int = 0  # sLSTM block every N (rest mLSTM)
    # MLP style: gated (SwiGLU, 3 matrices) vs plain (GELU, 2 matrices)
    gated_mlp: bool = True
    # modality frontend stub
    frontend: str = "none"  # none | audio | vision
    has_decoder: bool = True  # False: encoder-only (no decode shapes)
    subquadratic: bool = False  # eligible for long_500k
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    source: str = ""  # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim or self.resolved_head_dim

    def scaled(self, **overrides) -> "ArchConfig":
        """A reduced copy for smoke tests (same family/features)."""
        return dataclasses.replace(self, **overrides)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.xlstm:
            # mLSTM: qkv + gates + out
            d_in = d * self.ssm_expand
            per_layer = d * d_in * 4 + d_in * d + 2 * d
            return emb + self.n_layers * per_layer
        if self.ssm:
            d_in = d * self.ssm_expand
            ssm_layer = d * (2 * d_in) + d_in * self.ssm_conv + d_in * d + 3 * d_in
            n_attn = (self.n_layers // self.attn_every) if self.attn_every else 0
            attn_layer = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d \
                + 3 * d * self.d_ff
            # zamba2-style shared attention block: ONE set of weights
            return emb + self.n_layers * ssm_layer + (attn_layer if n_attn else 0)
        # attention
        if self.mla:
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * (hd + self.rope_head_dim)
                + d * (self.kv_lora_rank + self.rope_head_dim)
                + self.kv_lora_rank * self.n_heads * (hd + self.resolved_v_head_dim)
                + self.n_heads * self.resolved_v_head_dim * d
            )
        else:
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
        # mlp
        nm = 3 if self.gated_mlp else 2
        if self.moe:
            moe_layers = self.n_layers - self.first_dense_layers
            dense_mlp = nm * d * self.d_ff
            expert_mlp = nm * d * self.moe_d_ff
            mlp_total = (
                self.first_dense_layers * dense_mlp
                + moe_layers * (self.n_experts + self.n_shared_experts) * expert_mlp
                + moe_layers * d * self.n_experts  # router
            )
            return emb + self.n_layers * attn + mlp_total
        mlp = nm * d * self.d_ff
        return emb + self.n_layers * (attn + mlp)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared only)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        moe_layers = self.n_layers - self.first_dense_layers
        expert_mlp = 3 * d * self.moe_d_ff
        all_experts = moe_layers * self.n_experts * expert_mlp
        active_experts = moe_layers * self.top_k * expert_mlp
        return full - all_experts + active_experts


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "granite-20b",
    "h2o-danube-3-4b",
    "deepseek-coder-33b",
    "qwen3-0.6b",
    "deepseek-v2-236b",
    "kimi-k2-1t-a32b",
    "hubert-xlarge",
    "zamba2-2.7b",
    "xlstm-125m",
    "qwen2-vl-2b",
]


def shape_supported(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """(supported, reason-if-skipped) for one (arch, shape) cell."""
    s = SHAPES[shape]
    if s.kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch is O(L^2) at 500k; skipped per spec"
    return True, ""


def cells(arch_ids: Optional[List[str]] = None) -> List[Tuple[str, str, bool, str]]:
    """All (arch, shape, supported, reason) cells in the assignment grid."""
    from .registry import get_config

    out = []
    for a in arch_ids or ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = shape_supported(cfg, s)
            out.append((a, s, ok, why))
    return out
