"""xlstm-125m — xLSTM (mLSTM + sLSTM blocks, 7:1 ratio).

[arXiv:2405.04517; unverified] 12L d_model=768 4H (kv=4) d_ff=0 vocab=50304.
Blocks are mLSTM (matrix memory, parallel train form) with an sLSTM every
4th layer (lax.scan recurrence). Constant-state decode -> runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    xlstm=True,
    ssm_expand=2,
    slstm_every=4,
    subquadratic=True,
    source="arXiv:2405.04517; unverified",
)
