"""kimi-k2-1t-a32b — Kimi K2, trillion-param MoE (paper-table numbers).

[arXiv:2501.kimi2; unverified] 61L d_model=7168 64H (GQA kv=8)
d_ff(expert)=2048 vocab=163840, MoE 384 routed top-8 + 1 shared; first layer
dense (d_ff=18432 per the public config.json).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=18432,  # dense first layer
    vocab_size=163840,
    head_dim=128,
    moe=True,
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_dense_layers=1,
    source="arXiv:2501.kimi2; unverified",
)
