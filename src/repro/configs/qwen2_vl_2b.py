"""qwen2-vl-2b — Qwen2-VL 2B backbone (M-RoPE; vision frontend stubbed).

[arXiv:2409.12191; hf] 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936. input_specs() provides precomputed patch embeddings; the
backbone applies M-RoPE (temporal/height/width rotary sections).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    mrope=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    frontend="vision",
    source="arXiv:2409.12191; hf",
)
