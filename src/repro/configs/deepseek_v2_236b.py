"""deepseek-v2-236b — DeepSeek-V2 MoE with Multi-head Latent Attention.

[arXiv:2405.04434; hf] 60L d_model=5120 128H d_ff(expert)=1536 vocab=102400,
MoE 160 routed top-6 + 2 shared, MLA kv_lora=512, q_lora=1536,
rope_head_dim=64, nope head_dim=128, v_head_dim=128; first layer dense
(d_ff=12288 per the HF config).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,  # MLA: per-head decompression (kv heads == heads)
    d_ff=12288,  # dense first layer
    vocab_size=102400,
    head_dim=128,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    moe=True,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    source="arXiv:2405.04434; hf",
)
