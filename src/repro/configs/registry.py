"""Architecture registry: --arch <id> -> ArchConfig."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import ARCH_IDS, ArchConfig

_MOD = {
    "granite-20b": "granite_20b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen3-0.6b": "qwen3_0_6b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "hubert-xlarge": "hubert_xlarge",
    "zamba2-2.7b": "zamba2_2_7b",
    "xlstm-125m": "xlstm_125m",
    "qwen2-vl-2b": "qwen2_vl_2b",
}


def get_config(name: str) -> ArchConfig:
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MOD)}")
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(name)
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
    if cfg.mla:
        kw.update(kv_lora_rank=64, q_lora_rank=96, rope_head_dim=16, head_dim=32, v_head_dim=32)
    if cfg.moe:
        kw.update(n_experts=8, top_k=2, moe_d_ff=128,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.ssm:
        kw.update(ssm_state=16, ssm_heads=4, attn_every=cfg.attn_every and 2)
        kw.update(n_layers=4)
    if cfg.xlstm:
        kw.update(n_layers=4, slstm_every=cfg.slstm_every and 4)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    return cfg.scaled(**kw)
