from .base import ArchConfig, ShapeSpec, SHAPES, ARCH_IDS, cells, shape_supported
from .registry import get_config, all_configs, smoke_config

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "ARCH_IDS", "cells", "shape_supported", "get_config", "all_configs", "smoke_config"]
