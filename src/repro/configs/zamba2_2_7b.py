"""zamba2-2.7b — Zamba2 hybrid: Mamba2 backbone + ONE shared attention
block invoked every 6 SSM blocks (weight reuse is the Zamba trick).

[arXiv:2411.15242; hf] 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64. At 500k decode the shared attention runs a 4096 sliding
window (documented deviation; full attention would be O(L^2)).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm=True,
    ssm_state=64,
    ssm_expand=2,
    ssm_heads=40,  # d_inner=5120, headdim=128
    attn_every=6,
    sliding_window=4096,
    subquadratic=True,
    source="arXiv:2411.15242; hf",
)
