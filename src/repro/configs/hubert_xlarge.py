"""hubert-xlarge — HuBERT X-Large audio encoder (encoder-only).

[arXiv:2106.07447; unverified] 48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504 (cluster targets). Encoder-only: no decode shapes. The audio
frontend (conv feature extractor) is a STUB: input_specs() provides
precomputed frame embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    gated_mlp=False,  # standard transformer-encoder MLP
    causal=False,
    has_decoder=False,
    frontend="audio",
    source="arXiv:2106.07447; unverified",
)
