"""h2o-danube-3-4b — H2O.ai Danube3 (llama+mistral mix, sliding window).

[arXiv:2401.16818; unverified] 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000. SWA window 4096 (mistral-style) -> sub-quadratic; runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    sliding_window=4096,
    subquadratic=True,
    source="arXiv:2401.16818; unverified",
)
