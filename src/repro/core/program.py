"""Compile-once / bind-many front door of the toolchain.

The paper's workflow is: author an algorithm once, let the compiler pick
the execution strategy (pipelining, shuffling, memory layout) per target.
This module is the Python surface of that promise:

    program = repro.compile(src, options)        # compile once (cached)
    session = program.bind(graph)                # bind to one graph+backend
    result  = session.run(root=3, iters=20)      # parameterized execution

* :func:`compile` is keyed by a **content hash** of (source, options), so
  identical programs share one compiled artifact no matter how many string
  objects carry them, and distinct programs can never collide (the old
  ``id(src)``-keyed cache could alias unrelated sources after GC). Because
  ``CompileOptions.passes`` and ``scalar_bindings`` are part of the hashed
  options, pass-pipeline ablations and compile-time specializations get
  their own cache entries; the options-independent *analyzed* module is
  cached once per source, and the MIR pass pipeline
  (:mod:`repro.core.passes`) specializes a copy of it per option set.
* Every host scalar declared in the program (``const root: int = 0;``)
  becomes a declared **run-time parameter** of the :class:`Program`.
  Scalars declared *without* an initializer are required at ``run()``.
* :meth:`Program.bind` places the artifact onto an execution backend
  ("local" single-device engine or "distributed" multi-device engine) and
  returns a reusable :class:`~repro.core.session.Session`.
"""
from __future__ import annotations

import hashlib
import numbers
import threading
from dataclasses import dataclass
from typing import Any, Dict, Optional, TYPE_CHECKING

from . import mir, passes, semantic
from .options import CompileOptions
from .parser import parse

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from ..graph.storage import GraphData
    from .session import Session, SessionPool


class ProgramError(Exception):
    """Raised for bad compile/bind/run usage at the public API layer."""


@dataclass(frozen=True)
class ParamSpec:
    """One declared run-time parameter (a host scalar of the program)."""

    name: str
    scalar: str  # 'int' | 'float' | 'bool'
    required: bool  # declared without an initializer

    def describe(self) -> str:
        kind = "required" if self.required else "optional"
        return f"{self.name}: {self.scalar} ({kind})"


def _coerce_param(spec: ParamSpec, value: Any):
    """Validate + coerce one user-supplied parameter to its declared type."""
    try:
        if spec.scalar == "bool":
            if isinstance(value, (bool,)) or value in (0, 1):
                return bool(value)
        elif spec.scalar == "int":
            if isinstance(value, bool):
                return int(value)
            if isinstance(value, numbers.Integral):
                return int(value)
            if isinstance(value, numbers.Real) and float(value).is_integer():
                return int(value)
        elif spec.scalar == "float":
            if isinstance(value, numbers.Real) and not isinstance(value, bool):
                return float(value)
    except (TypeError, ValueError):
        pass  # e.g. multi-element arrays: ambiguous comparisons -> mismatch
    raise ProgramError(
        f"parameter {spec.name!r} expects {spec.scalar}, got "
        f"{type(value).__name__} ({value!r})"
    )


def source_fingerprint(src: str, options: CompileOptions) -> str:
    """Content hash keying the program cache: source text + options."""
    h = hashlib.sha256()
    h.update(src.encode("utf-8"))
    h.update(b"\x00")
    h.update(repr(options).encode("utf-8"))
    return h.hexdigest()


class Program:
    """A compiled Graphitron artifact, independent of any graph.

    Holds the analyzed MIR module, the compile options it was built with,
    and the declared run-time parameters. Bind it to as many graphs and
    backends as you like; each :meth:`bind` returns an isolated
    :class:`~repro.core.session.Session`.
    """

    def __init__(self, module: mir.Module, options: CompileOptions,
                 fingerprint: str, source: str):
        self.module = module
        self.options = options
        self.fingerprint = fingerprint
        self.source = source
        self.params: Dict[str, ParamSpec] = {
            s.name: ParamSpec(s.name, s.scalar, required=s.init is None)
            for s in module.scalars.values()
        }

    # -- introspection ------------------------------------------------------
    def describe(self) -> str:
        """Textual MIR dump (the analogue of the generated-OpenCL listing)."""
        return self.module.describe()

    def __repr__(self) -> str:
        return (
            f"Program({self.fingerprint[:12]}, kernels={sorted(self.module.kernels)}, "
            f"params=[{', '.join(p.describe() for p in self.params.values())}])"
        )

    # -- parameter validation ----------------------------------------------
    def validate_params(self, overrides: Dict[str, Any]) -> Dict[str, Any]:
        """Check run() kwargs against the declared parameters.

        Unknown names, missing required parameters, and type mismatches all
        raise :class:`ProgramError` with an actionable message.
        """
        unknown = sorted(set(overrides) - set(self.params))
        if unknown:
            declared = ", ".join(p.describe() for p in self.params.values()) or "<none>"
            raise ProgramError(
                f"unknown run-time parameter(s) {unknown}; this program declares: "
                f"{declared}. Declare a host scalar (`const name: int = 0;`) to "
                f"add a parameter."
            )
        out: Dict[str, Any] = {}
        for name, spec in self.params.items():
            if name in overrides:
                out[name] = _coerce_param(spec, overrides[name])
            elif spec.required:
                raise ProgramError(
                    f"missing required parameter {name!r} (declared without an "
                    f"initializer); pass {name}=<{spec.scalar}> to run()"
                )
        return out

    # -- binding ------------------------------------------------------------
    def bind(self, graph: "GraphData", backend: str = "local", *,
             argv: Optional[list] = None, **backend_opts) -> "Session":
        """Place this program onto ``graph`` using the named backend.

        The returned :class:`Session` owns the lowered kernels and device
        state and is reusable across many parameterized runs.
        """
        from .session import Session

        return Session(self, graph, backend=backend, argv=argv, **backend_opts)

    def pool(self, graph: "GraphData", size: int = 2, backend: str = "local", *,
             argv: Optional[list] = None, **backend_opts) -> "SessionPool":
        """Convenience: a :class:`SessionPool` of ``size`` sessions bound to
        ``graph`` for batch/async query serving."""
        from .session import SessionPool

        return SessionPool(self, graph, backend=backend, size=size, argv=argv,
                           **backend_opts)


# ---------------------------------------------------------------------------
# content-hashed program cache
# ---------------------------------------------------------------------------

# keyed by source_fingerprint(src, options) — the hash already folds the
# options repr in, so the string alone discriminates every (src, opts) pair
_PROGRAM_CACHE: Dict[str, Program] = {}
# the analyzed MIR module is options-independent: cache it on the source
# hash alone so ablation sweeps over options don't re-run the front-end
_MODULE_CACHE: Dict[str, mir.Module] = {}
_CACHE_LOCK = threading.Lock()


def compile_program(src: str, options: Optional[CompileOptions] = None) -> Program:
    """Compile DSL source into a :class:`Program` (cached).

    The cache key is a content hash of (source, options): the same text
    always returns the same artifact, different options recompile.
    """
    if not isinstance(src, str):
        raise ProgramError(f"expected DSL source text, got {type(src).__name__}")
    opts = options if options is not None else CompileOptions()
    key = source_fingerprint(src, opts)
    src_key = hashlib.sha256(src.encode("utf-8")).hexdigest()
    with _CACHE_LOCK:
        prog = _PROGRAM_CACHE.get(key)
        module = _MODULE_CACHE.get(src_key)
    if prog is not None:
        return prog
    if module is None:
        module = semantic.analyze(parse(src))
        with _CACHE_LOCK:
            # another thread may have raced us; keep the first base module
            module = _MODULE_CACHE.setdefault(src_key, module)
    # the MIR optimization pipeline (CompileOptions.passes) specializes the
    # options-independent base module per option set; it works on a copy,
    # so the cached base stays pristine for other option sets
    optimized = passes.run_pipeline(module, opts)
    prog = Program(optimized, opts, key, src)
    with _CACHE_LOCK:
        prog = _PROGRAM_CACHE.setdefault(key, prog)
    return prog


# `repro.compile(src, options)` reads naturally at call sites; the builtin
# is still reachable as `builtins.compile`.
compile = compile_program


def clear_program_cache() -> None:
    """Drop all cached programs and modules (test isolation / memory)."""
    with _CACHE_LOCK:
        _PROGRAM_CACHE.clear()
        _MODULE_CACHE.clear()


def program_cache_size() -> int:
    with _CACHE_LOCK:
        return len(_PROGRAM_CACHE)
