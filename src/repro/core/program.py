"""Compile-once / bind-many front door of the toolchain.

The paper's workflow is: author an algorithm once, let the compiler pick
the execution strategy (pipelining, shuffling, memory layout) per target.
This module is the Python surface of that promise, and it accepts **two
front-ends for one compiler**:

* **Text**: a ``.gt`` source string in the paper's Fig. 1 syntax, lexed
  and parsed by :mod:`repro.core.parser`.
* **Embedded**: a :class:`repro.frontend.GraphProgram` built in Python —
  typed property/scalar handles plus ``@vertex_kernel`` / ``@edge_kernel``
  decorated functions whose bodies are lowered from the Python AST.

Both meet at the same MIR and flow through the same passes → lowering
pipeline::

    program = repro.compile(src_or_graphprogram, options)   # compile once
    session = program.bind(graph)                # bind to one graph+backend
    result  = session.run(root=3, iters=20)      # parameterized execution

* :func:`compile` is keyed by a **content hash of the canonical serialized
  MIR** (:func:`repro.core.mir.canonical_serialize`) combined with the
  compile options. Keying on the MIR — not the surface text — means an
  embedded program and its textual equivalent resolve to *one* cache
  entry, as do two text sources differing only in comments/whitespace.
  Because ``CompileOptions.passes`` and ``scalar_bindings`` are part of
  the hashed options, pass-pipeline ablations and compile-time
  specializations get their own cache entries; the options-independent
  *analyzed* module is cached once per MIR fingerprint, and the MIR pass
  pipeline (:mod:`repro.core.passes`) specializes a copy of it per option
  set.
* Front-end failures surface as :class:`ProgramError` with a precise
  location: text sources report the 1-based line/column plus a caret
  excerpt of the offending source line; embedded programs report the
  Python file and line number of the offending decorated function.
* Every host scalar declared in the program (``const root: int = 0;`` /
  ``GraphProgram.scalar("root", int, init=0)``) becomes a declared
  **run-time parameter** of the :class:`Program`. Scalars declared
  *without* an initializer are required at ``run()``.
* :meth:`Program.bind` places the artifact onto an execution backend
  ("local" single-device engine or "distributed" multi-device engine) and
  returns a reusable :class:`~repro.core.session.Session`.

Migration between the two front-ends is mechanical; see the
"two front-ends, one compiler" table in ROADMAP.md.
"""
from __future__ import annotations

import contextlib
import hashlib
import numbers
import threading
from collections import OrderedDict, namedtuple
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, TYPE_CHECKING

from . import mir, passes, semantic
from .lexer import LexError
from .options import CompileOptions
from .parser import ParseError, parse
from .. import telemetry as tel

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from ..frontend import GraphProgram
    from ..graph.storage import GraphData
    from .accelerator import Accelerator, GraphShape
    from .session import BatchSession, Session, SessionPool
    from .target import Target


class ProgramError(Exception):
    """Raised for bad compile/bind/run usage at the public API layer.

    Compile-time front-end failures carry a source location: ``line`` and
    ``col`` (1-based, 0 = unknown) point into the ``.gt`` text for the
    text front-end, or into the decorated function's Python file (named in
    the message) for the embedded front-end.
    """

    def __init__(self, msg: str, line: int = 0, col: int = 0):
        super().__init__(msg)
        self.line = line
        self.col = col


def _excerpt(src: str, line: int, col: int) -> str:
    """A diagnostic excerpt: the offending source line plus a caret."""
    lines = src.splitlines()
    if not (1 <= line <= len(lines)):
        return ""
    text = lines[line - 1]
    out = f"\n  {line} | {text}"
    if col >= 1:
        out += "\n  " + " " * len(str(line)) + " | " + " " * (col - 1) + "^"
    return out


def _front_end_error(exc: Exception, src: str) -> ProgramError:
    """Wrap a lex/parse/semantic failure in a located ProgramError."""
    line = getattr(exc, "line", 0) or 0
    col = getattr(exc, "col", 0) or 0
    return ProgramError(f"{exc}{_excerpt(src, line, col)}", line, col)


@dataclass(frozen=True)
class ParamSpec:
    """One declared run-time parameter (a host scalar of the program)."""

    name: str
    scalar: str  # 'int' | 'float' | 'bool'
    required: bool  # declared without an initializer

    def describe(self) -> str:
        kind = "required" if self.required else "optional"
        return f"{self.name}: {self.scalar} ({kind})"


def _coerce_param(spec: ParamSpec, value: Any):
    """Validate + coerce one user-supplied parameter to its declared type."""
    # multi-element arrays raise on the ambiguous comparisons -> mismatch
    with contextlib.suppress(TypeError, ValueError):
        if spec.scalar == "bool":
            if isinstance(value, (bool,)) or value in (0, 1):
                return bool(value)
        elif spec.scalar == "int":
            if isinstance(value, bool):
                return int(value)
            if isinstance(value, numbers.Integral):
                return int(value)
            if isinstance(value, numbers.Real) and float(value).is_integer():
                return int(value)
        elif (spec.scalar == "float" and isinstance(value, numbers.Real)
              and not isinstance(value, bool)):
            return float(value)
    raise ProgramError(
        f"parameter {spec.name!r} expects {spec.scalar}, got "
        f"{type(value).__name__} ({value!r})"
    )


def source_fingerprint(src: str, options: CompileOptions) -> str:
    """Content hash of (raw source text, options).

    Kept for compatibility; the program cache itself is keyed on
    :func:`program_fingerprint` (the canonical *MIR* hash) so the embedded
    and text front-ends share entries.
    """
    h = hashlib.sha256()
    h.update(src.encode("utf-8"))
    h.update(b"\x00")
    h.update(repr(options).encode("utf-8"))
    return h.hexdigest()


def program_fingerprint(mir_key: str, options: CompileOptions) -> str:
    """Cache key of a compiled Program: canonical MIR hash + options."""
    h = hashlib.sha256()
    h.update(mir_key.encode("ascii"))
    h.update(b"\x00")
    h.update(repr(options).encode("utf-8"))
    return h.hexdigest()


class Program:
    """A compiled Graphitron artifact, independent of any graph.

    Holds the analyzed MIR module, the compile options it was built with,
    and the declared run-time parameters. Bind it to as many graphs and
    backends as you like; each :meth:`bind` returns an isolated
    :class:`~repro.core.session.Session`.

    ``source`` is always ``.gt`` text: for embedded programs it is the
    :meth:`~repro.frontend.GraphProgram.to_source` emission, so every
    compiled artifact can be re-ingested by the text front-end.
    """

    def __init__(self, module: mir.Module, options: CompileOptions,
                 fingerprint: str, source: str):
        self.module = module
        self.options = options
        self.fingerprint = fingerprint
        self.source = source
        self.params: Dict[str, ParamSpec] = {
            s.name: ParamSpec(s.name, s.scalar, required=s.init is None)
            for s in module.scalars.values()
        }

    # -- introspection ------------------------------------------------------
    def describe(self) -> str:
        """Textual MIR dump (the analogue of the generated-OpenCL listing)."""
        return self.module.describe()

    def diagnostics(self, shape=None):
        """Static-analysis findings over this program's (optimized) module.

        Returns an :class:`repro.analysis.AnalysisResult`. The shape-free
        result is computed once and cached on the Program; pass a
        :class:`~repro.core.accelerator.GraphShape` to additionally run the
        dtype/overflow analyses (GT5xx, computed fresh per shape).

        Provenance note: the text and embedded front-ends share one cached
        module per MIR fingerprint, so line numbers here belong to
        whichever twin was analyzed first. For provenance guaranteed to
        match a specific source, call ``repro.analyze(src)`` on that
        source directly.
        """
        from ..analysis import analyze

        if shape is not None:
            return analyze(self, shape=shape)
        cached = getattr(self, "_analysis", None)
        if cached is None:
            cached = analyze(self)
            self._analysis = cached
        return cached

    def __repr__(self) -> str:
        return (
            f"Program({self.fingerprint[:12]}, kernels={sorted(self.module.kernels)}, "
            f"params=[{', '.join(p.describe() for p in self.params.values())}])"
        )

    # -- parameter validation ----------------------------------------------
    def validate_params(self, overrides: Dict[str, Any]) -> Dict[str, Any]:
        """Check run() kwargs against the declared parameters.

        Unknown names, missing required parameters, and type mismatches all
        raise :class:`ProgramError` with an actionable message.
        """
        unknown = sorted(set(overrides) - set(self.params))
        if unknown:
            declared = ", ".join(p.describe() for p in self.params.values()) or "<none>"
            raise ProgramError(
                f"unknown run-time parameter(s) {unknown}; this program declares: "
                f"{declared}. Declare a host scalar (`const name: int = 0;`) to "
                f"add a parameter."
            )
        out: Dict[str, Any] = {}
        for name, spec in self.params.items():
            if name in overrides:
                out[name] = _coerce_param(spec, overrides[name])
            elif spec.required:
                raise ProgramError(
                    f"missing required parameter {name!r} (declared without an "
                    f"initializer); pass {name}=<{spec.scalar}> to run()"
                )
        return out

    # -- lowering (Accelerator artifacts) ------------------------------------
    def lower(self, target: "Optional[Target]" = None,
              shape: "Optional[GraphShape]" = None, *,
              graph: "Optional[GraphData]" = None,
              bucket: bool = False, tuned: bool = False,
              tuning_cache=None) -> "Accelerator":
        """AOT-lower this program for a (target, shape bucket).

        The returned :class:`~repro.core.accelerator.Accelerator` has every
        kernel compiled against the bucket's buffer shapes — graph bindings
        are runtime arguments, so ``accelerator.bind(g)`` is a shape check
        only and any number of same-bucket graphs share the lowering. Pass
        either an explicit ``shape=GraphShape(n_vertices=..., n_edges=...,
        weighted=...)`` or ``graph=`` to take the bucket from a concrete
        graph. ``target`` defaults to the Target implied by this program's
        CompileOptions (legacy substrate kwargs included).

        ``bucket=True`` (with ``graph=``) rounds the graph's logical counts
        up to a shared geometric bucket (:meth:`GraphShape.bucket_for`)
        instead of taking its exact physical shape — graphs of similar size
        then reuse one lowering, and the headroom doubles as streaming
        update slack. The caller binds ``graph.pad_to(shape.n_vertices,
        shape.n_edges)``, not the unpadded graph (``bind`` checks shapes
        exactly).

        ``tuned=True`` consults the :mod:`repro.autotune` TuningCache for
        this program's (MIR fingerprint x shape bucket) and, on a hit,
        lowers with the tuned Target instead of the default — a pure
        lookup with **zero search trials** (run ``python -m
        repro.autotune`` or :func:`repro.autotune.autotune` offline to
        populate the cache). On a miss the given/default target is used
        unchanged. ``tuning_cache`` overrides the default cache location
        (``<artifact store>/tuning``).
        """
        from .accelerator import Accelerator, GraphShape
        from .target import Target

        if shape is None:
            if graph is None:
                raise ProgramError(
                    "Program.lower needs a shape bucket: pass "
                    "shape=GraphShape(...) or graph=<GraphData>"
                )
            if bucket:
                shape = GraphShape.bucket_for(
                    graph.n_vertices_logical, graph.n_edges_logical,
                    weighted=graph.weighted,
                )
            else:
                shape = GraphShape.of(graph)
        if target is None:
            target = Target.from_options(self.options)
        tuned_stamp = None
        if tuned:
            from ..autotune import (
                TuningCache, default_tuning_dir, program_mir_fingerprint,
                shape_bucket,
            )

            cache = tuning_cache if tuning_cache is not None else \
                TuningCache(default_tuning_dir())
            cfg = cache.get(
                program_mir_fingerprint(self),
                shape_bucket(graph=graph, shape=shape),
                kind=target.kind,
            )
            if cfg is not None:
                target = cfg.target
                tuned_stamp = cfg.to_dict()
        return Accelerator(self, target, shape, _tuned=tuned_stamp)

    # -- binding ------------------------------------------------------------
    def bind(self, graph: "GraphData", backend: str = "local", *,
             argv: Optional[list] = None, **backend_opts) -> "Session":
        """Place this program onto ``graph`` using the named backend.

        The returned :class:`Session` owns the lowered kernels and device
        state and is reusable across many parameterized runs. (For
        compile-once / deploy-many serving, prefer
        ``program.lower(target, shape).bind(graph)`` — the Accelerator
        pays kernel compilation once per shape bucket, offline.)
        """
        from .session import Session

        return Session(self, graph, backend=backend, argv=argv, **backend_opts)

    def pool(self, graph: "GraphData", size: int = 2, backend: str = "local", *,
             argv: Optional[list] = None, **backend_opts) -> "SessionPool":
        """Convenience: a :class:`SessionPool` of ``size`` sessions bound to
        ``graph`` for batch/async query serving."""
        from .session import SessionPool

        return SessionPool(self, graph, backend=backend, size=size, argv=argv,
                           **backend_opts)

    def bind_batch(self, graph: "GraphData", backend: str = "local", *,
                   argv: Optional[list] = None, max_batch: Optional[int] = None,
                   msbfs: bool = True, **backend_opts) -> "BatchSession":
        """Place this program onto ``graph`` for batched multi-query runs.

        The returned :class:`~repro.core.session.BatchSession` answers a
        whole list of parameter bindings per execution — state carries a
        leading batch axis, host control flow runs with per-query active
        masks, and BFS-like frontier programs take the bit-packed
        multi-source path — with results bit-identical to sequential
        :meth:`bind` + ``run`` calls. See also ``Session.run_many``, which
        reroutes batch-eligible lists here automatically.
        """
        from .session import BatchSession

        return BatchSession(self, graph, backend=backend, argv=argv,
                            max_batch=max_batch, msbfs=msbfs, **backend_opts)


# ---------------------------------------------------------------------------
# content-hashed program cache (bounded LRU)
# ---------------------------------------------------------------------------


class _LRU:
    """A small LRU map with functools-style counters.

    NOT internally locked — all access goes through ``_CACHE_LOCK`` below
    (the caches cross-reference each other, so one lock is simplest).
    """

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._od: "OrderedDict[str, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key):
        if key is None or key not in self._od:
            self.misses += 1
            return None
        self._od.move_to_end(key)
        self.hits += 1
        return self._od[key]

    def setdefault(self, key, value):
        cur = self._od.get(key)
        if cur is not None:
            self._od.move_to_end(key)
            return cur
        self._od[key] = value
        self._evict()
        return value

    def put(self, key, value):
        self._od[key] = value
        self._od.move_to_end(key)
        self._evict()

    def _evict(self):
        while len(self._od) > self.maxsize:
            self._od.popitem(last=False)
            self.evictions += 1

    def resize(self, maxsize: int):
        self.maxsize = maxsize
        self._evict()

    def clear(self):
        self._od.clear()
        self.hits = self.misses = self.evictions = 0

    def __len__(self):
        return len(self._od)

    def __contains__(self, key):
        return key in self._od


#: Default Program cache bound: many-tenant serving compiles many distinct
#: programs over one process lifetime; an unbounded dict is a slow leak.
DEFAULT_PROGRAM_CACHE_SIZE = 64

# keyed by program_fingerprint(mir_key, options): the canonical MIR hash
# folds in every semantic detail of the program while being front-end
# independent, so `compile(text)` and `compile(embedded_twin)` alias
_PROGRAM_CACHE = _LRU(DEFAULT_PROGRAM_CACHE_SIZE)
# the analyzed MIR module is options-independent: cache it on the MIR
# fingerprint alone so ablation sweeps over options don't re-run analysis
_MODULE_CACHE = _LRU(DEFAULT_PROGRAM_CACHE_SIZE)
# memo: sha256(raw text) -> MIR fingerprint, so recompiling the same text
# string skips the lexer/parser/analyzer entirely
_TEXT_KEYS = _LRU(DEFAULT_PROGRAM_CACHE_SIZE)
_CACHE_LOCK = threading.Lock()

ProgramCacheInfo = namedtuple(
    "ProgramCacheInfo", ["hits", "misses", "evictions", "maxsize", "currsize"]
)


def program_cache_info() -> ProgramCacheInfo:
    """functools-style counters of the compiled-Program LRU cache."""
    with _CACHE_LOCK:
        c = _PROGRAM_CACHE
        return ProgramCacheInfo(c.hits, c.misses, c.evictions, c.maxsize, len(c))


def set_program_cache_limit(maxsize: int) -> None:
    """Resize the Program cache (module/text memos track the same bound)."""
    if maxsize < 1:
        raise ValueError("program cache size must be >= 1")
    with _CACHE_LOCK:
        _PROGRAM_CACHE.resize(maxsize)
        _MODULE_CACHE.resize(maxsize)
        _TEXT_KEYS.resize(maxsize)


def _analyze_text(src: str) -> Tuple[mir.Module, str]:
    """Text front-end: source -> (analyzed module, MIR fingerprint)."""
    src_key = hashlib.sha256(src.encode("utf-8")).hexdigest()
    with _CACHE_LOCK:
        mir_key = _TEXT_KEYS.get(src_key)
        module = _MODULE_CACHE.get(mir_key) if mir_key else None
    if module is not None:
        return module, mir_key
    try:
        fir_prog = parse(src)
    except (LexError, ParseError) as e:
        raise _front_end_error(e, src) from e
    try:
        module = semantic.analyze(fir_prog)
    except semantic.SemanticError as e:
        raise _front_end_error(e, src) from e
    mir_key = mir.fingerprint(module)
    with _CACHE_LOCK:
        # another thread may have raced us; keep the first base module
        module = _MODULE_CACHE.setdefault(mir_key, module)
        _TEXT_KEYS.put(src_key, mir_key)
    return module, mir_key


def _analyze_embedded(gp: "GraphProgram") -> Tuple[mir.Module, str, str]:
    """Embedded front-end: GraphProgram -> (module, MIR key, .gt source).

    The (MIR key, source) pair is memoized on the GraphProgram itself
    (``_identity``, invalidated by new declarations), so repeated compiles
    of the same builder skip to_fir/analyze/dump — the embedded analogue
    of the text path's ``_TEXT_KEYS`` memo.
    """
    ident = getattr(gp, "_identity", None)
    if ident is not None:
        mir_key, source_text = ident
        with _CACHE_LOCK:
            module = _MODULE_CACHE.get(mir_key)
        if module is not None:
            return module, mir_key, source_text
    from ..frontend.lowering import FrontendError  # deferred: no cycle at load

    try:
        fir_prog = gp.to_fir()
        source_text = gp.to_source()
    except FrontendError as e:
        raise ProgramError(f"embedded program {gp.name!r}: {e}") from e
    try:
        module = semantic.analyze(fir_prog)
    except semantic.SemanticError as e:
        line = getattr(e, "line", 0) or 0
        raise ProgramError(
            f"embedded program {gp.name!r}: {e}"
            + (f" (Python source line {line})" if line else ""),
            line,
        ) from e
    mir_key = mir.fingerprint(module)
    with _CACHE_LOCK:
        module = _MODULE_CACHE.setdefault(mir_key, module)
    with contextlib.suppress(AttributeError):  # exotic duck types
        gp._identity = (mir_key, source_text)
    return module, mir_key, source_text


def compile_program(
    src: "str | GraphProgram", options: Optional[CompileOptions] = None,
    *, strict: bool = False,
) -> Program:
    """Compile DSL source — text or embedded — into a :class:`Program`.

    ``src`` is either a ``.gt`` source string or a
    :class:`repro.frontend.GraphProgram`. The cache key is a content hash
    of the canonical serialized MIR plus the options: the same program
    always returns the same artifact no matter which front-end authored
    it, and different options recompile.

    ``strict=True`` additionally runs the static-analysis framework
    (:mod:`repro.analysis`) over the source: error-level diagnostics
    (e.g. GT101 scatter races) raise :class:`ProgramError` with full
    provenance, warnings collect silently on the returned Program
    (``program.diagnostics()``). Strictness is not part of the cache key —
    it gates raising, not the compiled artifact.
    """
    tr = tel.get()
    if not tr.enabled:
        return _compile_impl(src, options, strict, tel.NULL_SPAN)
    with tr.span("compile") as sp:
        return _compile_impl(src, options, strict, sp)


def _compile_impl(src, options, strict, sp) -> Program:
    if isinstance(src, str):
        sp.set(frontend="text")
        module, mir_key = _analyze_text(src)
        source_text = src
    elif hasattr(src, "to_fir") and hasattr(src, "to_source"):
        sp.set(frontend="embedded")
        module, mir_key, source_text = _analyze_embedded(src)
    else:
        raise ProgramError(
            f"expected DSL source text or a GraphProgram, got {type(src).__name__}"
        )
    opts = options if options is not None else CompileOptions()
    key = program_fingerprint(mir_key, opts)
    sp.set(fingerprint=key[:16])
    with _CACHE_LOCK:
        prog = _PROGRAM_CACHE.get(key)
    if prog is not None:
        sp.set(cache_hit=True)
        if strict:
            _check_strict(src, opts)
        return prog
    sp.set(cache_hit=False)
    # the MIR optimization pipeline (CompileOptions.passes) specializes the
    # options-independent base module per option set; it works on a copy,
    # so the cached base stays pristine for other option sets
    optimized = passes.run_pipeline(module, opts)
    prog = Program(optimized, opts, key, source_text)
    with _CACHE_LOCK:
        prog = _PROGRAM_CACHE.setdefault(key, prog)
    if strict:
        _check_strict(src, opts)
    return prog


def _check_strict(src, opts: CompileOptions) -> None:
    """Raise ProgramError on error-level analysis findings.

    Re-runs the front-end via ``repro.analyze`` so the provenance in the
    raised message is faithful to THIS input (caret excerpts for text,
    Python file:lineno for embedded) — the shared module cache may hold
    the other twin's line numbers.
    """
    from ..analysis import analyze as _analyze

    result = _analyze(src, options=opts)
    if result.errors:
        first = result.errors[0]
        detail = "\n".join(d.format() for d in result.errors)
        raise ProgramError(
            f"strict compile rejected the program "
            f"({len(result.errors)} error-level diagnostic(s)):\n{detail}",
            first.line, first.col,
        )


# `repro.compile(src, options)` reads naturally at call sites; the builtin
# is still reachable as `builtins.compile`.
compile = compile_program


def clear_program_cache() -> None:
    """Drop all cached programs and modules (test isolation / memory)."""
    with _CACHE_LOCK:
        _PROGRAM_CACHE.clear()
        _MODULE_CACHE.clear()
        _TEXT_KEYS.clear()


def program_cache_size() -> int:
    with _CACHE_LOCK:
        return len(_PROGRAM_CACHE)
