"""Accelerator artifacts: AOT-lowered, serializable compile products.

Graphitron's output is not an in-process interpreter but a *generated
accelerator*: the back-end lowers the algorithm against a hardware
description once, and the resulting artifact is deployed and rebound to
new graphs (paper §IV; the ThunderGP-style template flow ships
precompiled bitstreams rebound per graph). This module is that stage
split for the JAX substrate — the pipeline becomes

    program     = repro.compile(src, options)        # front-end + passes
    accelerator = program.lower(target, shape)       # AOT back-end, offline
    session     = accelerator.bind(graph)            # shape check only

* :class:`GraphShape` is the **shape bucket** an accelerator is lowered
  against: ``(n_vertices, n_edges, weighted)``. Every device buffer and
  graph-binding array has a shape fully determined by the bucket, so one
  lowering serves every graph in it — use :meth:`GraphShape.bucketed` and
  :meth:`repro.graph.storage.GraphData.pad_to` to coarsen buckets.
* :class:`KernelLibrary` holds the shape-generic lowered kernels (graph
  bindings are traced *arguments*, see
  :func:`repro.core.backend.lower_kernel_generic`) plus their AOT-compiled
  executables (``jax.jit(...).lower(specs).compile()``). The library is
  shared by every Session bound from one Accelerator: rebinds and process
  warm-starts never pay jit compilation again.
* :class:`Accelerator` is the deployable artifact: ``report()`` is the
  moral equivalent of an HLS resource report (per-kernel launch plan,
  FLOPs/bytes estimates, live-buffer peak), ``save(path)`` /
  :func:`load_accelerator` persist it (canonical MIR + target + pass
  report always; compiled executables where the backend supports
  serialization, transparent re-lower fallback otherwise).

Distributed targets lower lazily at bind (shard_map supersteps close over
the device mesh), but carry the same artifact metadata, report, and
persistence — ``load_accelerator`` still skips the front-end and pass
pipeline.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import pickle
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple, TYPE_CHECKING

import jax
import jax.numpy as jnp

from . import backend, mir
from .backend import DTYPES, WEIGHT_KEY
from .options import CompileOptions
from .target import Target
from .. import telemetry as tel

if TYPE_CHECKING:  # pragma: no cover - import cycle guards
    from ..graph.storage import GraphData
    from .program import Program
    from .session import BatchSession, Session, SessionPool

# format 2: logical_counts joined GB_ARRAY_KEYS (size() reads unpadded
# counts), changing the AOT executable signature — format-1 artifacts are
# rejected and re-lowered
ARTIFACT_FORMAT = 2
MANIFEST_NAME = "manifest.json"


class AcceleratorError(Exception):
    """Raised for shape/target mismatches and stale/corrupt artifacts."""


def accelerator_fingerprint(program_fingerprint: str, target: Target,
                            shape: "GraphShape") -> str:
    """Content identity of a lowered accelerator (program x target x shape).

    Computable without lowering — artifact stores key their directories on
    it, so a stale or foreign artifact simply lives at a different path.
    """
    h = hashlib.sha256()
    h.update(program_fingerprint.encode("ascii"))
    h.update(repr(target).encode("utf-8"))
    h.update(repr(shape).encode("utf-8"))
    return h.hexdigest()


@dataclass(frozen=True)
class GraphShape:
    """The shape bucket an Accelerator is lowered against.

    Two graphs with the same ``(n_vertices, n_edges, weighted)`` triple
    produce identically-shaped device buffers and graph-binding arrays, so
    they share one AOT lowering. Pad graphs up to a common bucket with
    :meth:`GraphData.pad_to` when their raw shapes differ.
    """

    n_vertices: int
    n_edges: int
    weighted: bool = False

    def __post_init__(self):
        if self.n_vertices < 1 or self.n_edges < 1:
            raise ValueError("GraphShape needs n_vertices >= 1 and n_edges >= 1")

    @staticmethod
    def of(graph: "GraphData") -> "GraphShape":
        return GraphShape(int(graph.n_vertices), int(graph.n_edges),
                          bool(graph.weighted))

    def bucketed(self, v_round: int = 1024, e_round: int = 4096) -> "GraphShape":
        """Round the shape up to multiples — a coarser bucket so more
        graphs alias one lowering (pad graphs with ``GraphData.pad_to``).

        Padding changes |V|/|E|, which globally-normalized algorithms
        (PageRank-class) observe — see the ``GraphData.pad_to`` docstring
        for the exact transparency contract before bucketing those.
        """

        def up(n, m):
            return ((n + m - 1) // m) * m

        return GraphShape(up(self.n_vertices, v_round),
                          up(self.n_edges, e_round), self.weighted)

    @classmethod
    def bucket_for(cls, n_vertices: int, n_edges: int, weighted: bool = False,
                   *, headroom: float = 0.125, ratio: float = 1.25,
                   v_base: int = 1024, e_base: int = 4096) -> "GraphShape":
        """Geometric shape bucket for a (possibly growing) logical graph.

        Linear rounding (:meth:`bucketed`) re-buckets every ``e_round``
        added edges — a stream of small deltas would churn lowerings.
        Geometric rounding grows buckets by ``ratio`` steps above a base,
        after adding ``headroom`` slack, so the number of distinct buckets
        (= lowerings) over any growth trajectory is logarithmic, and every
        fresh bucket arrives with free padding slots for
        :meth:`GraphData.apply_updates` to consume. Deterministic integer
        iteration — no float-log boundary jitter.
        """
        if n_vertices < 1 or n_edges < 1:
            raise ValueError("bucket_for needs n_vertices >= 1 and n_edges >= 1")

        def up(n: int, base: int) -> int:
            n = n + (n * int(headroom * 1024)) // 1024  # integer headroom
            b = base
            while b < n:
                b = max(b + 1, int(b * ratio))
            return b

        bv, be = up(n_vertices, v_base), up(n_edges, e_base)
        if be > n_edges and bv <= n_vertices:
            bv = max(bv + 1, int(bv * ratio))  # padded edges need a pad vertex
        return cls(bv, be, weighted)

    def accepts(self, graph: "GraphData") -> bool:
        return GraphShape.of(graph) == self

    def check_bucket(self, graph: "GraphData") -> None:
        """Raise unless ``graph`` can bind an accelerator of this bucket.

        Exact |V|/|E| match; a weighted graph may bind an unweighted bucket
        (the program never reads weights), but a weighted bucket promises
        weights the graph must have. The single source of truth for every
        bind-time check (Accelerator and KernelLibrary both delegate here).
        """
        got = GraphShape.of(graph)
        ok = (got.n_vertices == self.n_vertices
              and got.n_edges == self.n_edges
              and (got.weighted or not self.weighted))
        if not ok:
            raise AcceleratorError(
                f"graph shape ({got.describe()}) does not match the "
                f"accelerator's bucket ({self.describe()}); pad the graph "
                f"with GraphData.pad_to(...) or lower a new bucket"
            )

    def to_dict(self) -> dict:
        return {"n_vertices": self.n_vertices, "n_edges": self.n_edges,
                "weighted": self.weighted}

    def describe(self) -> str:
        return (f"|V|={self.n_vertices} |E|={self.n_edges} "
                f"{'weighted' if self.weighted else 'unweighted'}")


# ---------------------------------------------------------------------------
# AOT input signatures
# ---------------------------------------------------------------------------


def _state_specs(module: mir.Module, shape: GraphShape) -> Dict[str, Any]:
    """ShapeDtypeStruct tree of the full device state for a shape bucket."""
    specs: Dict[str, Any] = {}
    for p in module.properties.values():
        n = shape.n_edges if p.is_edge else shape.n_vertices
        specs[p.name] = jax.ShapeDtypeStruct((n,), DTYPES[p.scalar])
    if module.graph.weighted:
        wdt = DTYPES[module.graph.weight_scalar or "float"]
        specs[WEIGHT_KEY] = jax.ShapeDtypeStruct((shape.n_edges,), wdt)
    return specs


def _scalar_specs(module: mir.Module, kern) -> Dict[str, Any]:
    return {
        s: jax.ShapeDtypeStruct((), DTYPES[module.scalars[s].scalar])
        for s in sorted(kern.scalar_reads)
    }


# ---------------------------------------------------------------------------
# kernel library: shape-generic lowered kernels shared across binds
# ---------------------------------------------------------------------------


class KernelLibrary:
    """Shape-generic lowered kernels + AOT executables for one bucket.

    One library backs every Session bound from one Accelerator. All jit
    caches (full stream, compacted subsets per pad bucket, the frontier
    builder) live on shared function objects with graph bindings as traced
    arguments — so N same-bucket graphs, and every rebind after the first,
    share one compilation. ``warm_keys`` is the first-touch registry the
    engines consult for the compile/run time split: AOT-compiled kernels
    are born warm.
    """

    def __init__(self, module: mir.Module, target: Target, shape: GraphShape):
        self.module = module
        self.target = target
        self.shape = shape
        self.warm_keys: set = set()
        self._frontier_build = None
        self._generic: Dict[str, backend.GenericLoweredKernel] = {}
        for name, kern in module.kernels.items():
            self._generic[name] = backend.lower_kernel_generic(
                module, kern, shape.n_vertices, shape.n_edges, target
            )

    # -- validation ----------------------------------------------------------
    def check_graph(self, graph: "GraphData") -> None:
        self.shape.check_bucket(graph)

    # -- AOT compilation -----------------------------------------------------
    def compile_all(self, blobs: Optional[Dict[str, Any]] = None) -> Tuple["KernelPlan", ...]:
        """AOT-compile every kernel's full-stream executable.

        ``blobs`` maps kernel name -> a serialized executable payload from
        a saved artifact; entries that deserialize are loaded instead of
        recompiled, anything else transparently re-lowers.
        """
        gb_specs = backend.gb_array_specs(self.shape.n_vertices, self.shape.n_edges)
        state_specs = _state_specs(self.module, self.shape)
        plans = []
        for name, g in self._generic.items():
            kern = self.module.kernels[name]
            scal_specs = _scalar_specs(self.module, kern)
            t0 = time.perf_counter()
            mode = "aot"
            compiled = None
            blob = (blobs or {}).get(name)
            if blob is not None:
                compiled = _deserialize_executable(blob)
                if compiled is not None:
                    mode = "aot-loaded"
            if compiled is None:
                compiled = g.jit_full.lower(
                    gb_specs, state_specs, scal_specs
                ).compile()
            g.compiled_full = compiled
            self.warm_keys.add(("full", name))
            plans.append(_kernel_plan(
                self.module, kern, compiled, mode,
                compile_time_s=time.perf_counter() - t0,
                shape=self.shape,
            ))
        return tuple(plans)

    # -- engine adapters -----------------------------------------------------
    def kernel_for(self, name: str, gb: Dict[str, Any]) -> backend.LoweredKernel:
        """Adapt the shape-generic kernel to one graph's binding arrays."""
        g = self._generic.get(name)
        if g is None:
            raise AcceleratorError(f"{name!r} is not a device kernel")
        gba = backend.split_gb_arrays(gb)
        compiled, jit_full = g.compiled_full, g.jit_full

        def run_full(state, scalars):
            if compiled is not None:
                return compiled(gba, state, scalars)
            return jit_full(gba, state, scalars)

        def trace_full(state, scalars):
            return g.raw_full(gba, state, scalars)

        run_subset = None
        if g.jit_subset is not None:
            def run_subset(state, scalars, batch):
                return g.jit_subset(gba, state, scalars, batch)

        return backend.LoweredKernel(
            name, g.kind, run_full=run_full, run_subset=run_subset,
            frontier=g.frontier, trace_full=trace_full,
        )

    def batched_for(self, name: str, gb: Dict[str, Any]):
        """Shared batch-axis executable for one graph's binding arrays.

        The vmapped trace lives on the generic kernel (one jit per library,
        graph bindings as an unbatched argument), so a rebind of the same
        accelerator reuses every batch-size trace already compiled — which
        keeps the engines' shared warm-key accounting truthful.
        """
        g = self._generic.get(name)
        if g is None:
            raise AcceleratorError(f"{name!r} is not a device kernel")
        if g.jit_batched is None:
            g.jit_batched = jax.jit(
                jax.vmap(g.raw_full, in_axes=(None, 0, 0))
            )
        gba = backend.split_gb_arrays(gb)
        jit_batched = g.jit_batched

        def run(state, scalars):
            return jit_batched(gba, state, scalars)

        return run

    def frontier_builder(self):
        """Shared jitted frontier expansion (graph arrays as arguments).

        One builder per library: every bind of the accelerator reuses the
        (pad_v, pad_e) buckets any previous bind compiled.
        """
        if self._frontier_build is None:
            self._frontier_build = backend.make_frontier_builder(
                self.shape.n_vertices, self.shape.n_edges,
                self.module.graph.weighted,
            )
        return self._frontier_build


# ---------------------------------------------------------------------------
# resource report (the HLS report analogue)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KernelPlan:
    """Per-kernel launch plan + cost estimates of one lowered accelerator."""

    name: str
    kind: str  # 'vertex' | 'edge' | 'pipeline'
    stages: Tuple[str, ...]  # fused stage names (pipelines), else ()
    direction: str  # compile-time push/pull verdict ('auto' pre-pass)
    mode: str  # 'aot' | 'aot-loaded' | 'lazy'
    flops: Optional[float] = None  # per full-stream launch (XLA estimate)
    bytes_accessed: Optional[float] = None
    arg_bytes: Optional[int] = None
    out_bytes: Optional[int] = None
    temp_bytes: Optional[int] = None
    compile_time_s: float = 0.0


def _xla_estimates(compiled) -> Dict[str, Optional[float]]:
    """Best-effort XLA cost/memory estimates for one AOT executable.

    Interpreted/CPU backends (and deserialized executables on some JAX
    versions) may not implement ``cost_analysis``/``memory_analysis``,
    may return empty results, or may raise — every failure mode degrades
    to explicit ``None`` estimates here. Callers (``report()``, the
    :mod:`repro.autotune` cost model) treat ``None`` as "unknown"; an
    unavailable estimate must never crash a report or a tuning trial.
    """
    est: Dict[str, Optional[float]] = {
        "flops": None, "bytes_accessed": None,
        "arg_bytes": None, "out_bytes": None, "temp_bytes": None,
    }
    if compiled is None:
        return est
    with contextlib.suppress(Exception):
        cost = compiled.cost_analysis()
        entry = cost[0] if isinstance(cost, (list, tuple)) else cost
        if entry:
            est["flops"] = float(entry.get("flops", 0.0)) or None
            est["bytes_accessed"] = (
                float(entry.get("bytes accessed", 0.0)) or None
            )
    with contextlib.suppress(Exception):
        m = compiled.memory_analysis()
        est["arg_bytes"] = int(m.argument_size_in_bytes)
        est["out_bytes"] = int(m.output_size_in_bytes)
        est["temp_bytes"] = int(m.temp_size_in_bytes)
    return est


def _kernel_plan(module, kern, compiled, mode, compile_time_s, shape) -> KernelPlan:
    est = _xla_estimates(compiled)
    flops, bytes_accessed = est["flops"], est["bytes_accessed"]
    arg_bytes, out_bytes, temp_bytes = (
        est["arg_bytes"], est["out_bytes"], est["temp_bytes"]
    )
    if flops is None:
        # static fallback: one op-estimate per streamed lane per access
        lanes = shape.n_edges if kern.kind is mir.KernelKind.EDGE else shape.n_vertices
        if isinstance(kern, mir.PipelineKernel):
            lanes = sum(
                shape.n_edges if s.kind is mir.KernelKind.EDGE else shape.n_vertices
                for s in kern.stages
            )
            accesses = sum(len(s.reads) + len(s.writes) for s in kern.stages)
        else:
            accesses = len(kern.reads) + len(kern.writes)
        flops = float(lanes * max(1, accesses))
    stages = tuple(s.name for s in kern.stages) if isinstance(kern, mir.PipelineKernel) else ()
    direction = getattr(getattr(kern, "direction", None), "value", "auto")
    return KernelPlan(
        name=kern.name, kind=kern.kind.value, stages=stages,
        direction=direction, mode=mode, flops=flops,
        bytes_accessed=bytes_accessed, arg_bytes=arg_bytes,
        out_bytes=out_bytes, temp_bytes=temp_bytes,
        compile_time_s=compile_time_s,
    )


@dataclass(frozen=True)
class AcceleratorReport:
    """Queryable resource report of one lowered accelerator."""

    target: Target
    shape: GraphShape
    kernels: Tuple[KernelPlan, ...]
    state_bytes: int  # device property buffers (+ weights)
    gb_bytes: int  # graph-binding arrays (the Burst Read plan)
    live_buffer_peak_bytes: int  # resident state+plan+worst kernel temps
    lower_time_s: float
    pass_report: Tuple[str, ...] = ()
    #: determinism certificate from repro.analysis (deterministic /
    #: reduction-deterministic / racy) — also stored in artifact manifests
    determinism: str = "unknown"
    #: profiling baseline from traced runs (repro.telemetry): ``{"runs": N,
    #: "spans": {name: {count, total_s, max_s}}}`` — persisted with the
    #: artifact manifest so warm-started processes inherit it
    profile: Dict[str, Any] = field(default_factory=dict)

    @property
    def total_flops_per_launch_set(self) -> float:
        return sum(k.flops or 0.0 for k in self.kernels)

    def describe(self) -> str:
        lines = [
            f"accelerator [{self.target.describe()}] {self.shape.describe()}",
            f"  buffers: state {_fmt_bytes(self.state_bytes)}, "
            f"graph plan {_fmt_bytes(self.gb_bytes)}, "
            f"live peak {_fmt_bytes(self.live_buffer_peak_bytes)}",
            f"  lowered in {self.lower_time_s:.3f}s "
            f"({sum(1 for k in self.kernels if k.mode.startswith('aot'))}"
            f"/{len(self.kernels)} kernels AOT)",
            f"  determinism: {self.determinism}",
        ]
        if self.profile.get("runs"):
            hot = sorted(
                ((k, v) for k, v in self.profile.get("spans", {}).items()
                 if k.startswith("launch:")),
                key=lambda kv: -kv[1].get("total_s", 0.0),
            )[:5]
            hottest = ", ".join(
                f"{k.split(':', 1)[1]} {v['total_s']:.3f}s" for k, v in hot
            )
            lines.append(
                f"  profile: {self.profile['runs']} traced run(s)"
                + (f"; hottest: {hottest}" if hottest else "")
            )
        for k in self.kernels:
            extra = f" = {' -> '.join(k.stages)}" if k.stages else ""
            cost = f"{k.flops:.3g} flops" if k.flops else "?"
            if k.bytes_accessed:
                cost += f", {_fmt_bytes(int(k.bytes_accessed))} accessed"
            lines.append(
                f"  kernel {k.name} [{k.kind}{extra}] {k.mode} "
                f"dir={k.direction} ~{cost} "
                f"(compile {k.compile_time_s * 1e3:.0f}ms)"
            )
        for entry in self.pass_report:
            lines.append(f"  pass {entry}")
        return "\n".join(lines)


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n}B"
        n /= 1024
    return f"{n}B"  # pragma: no cover


def _module_state_bytes(module: mir.Module, shape: GraphShape) -> int:
    total = 0
    for p in module.properties.values():
        n = shape.n_edges if p.is_edge else shape.n_vertices
        total += n * jnp.dtype(DTYPES[p.scalar]).itemsize
    if module.graph.weighted:
        wdt = DTYPES[module.graph.weight_scalar or "float"]
        total += shape.n_edges * jnp.dtype(wdt).itemsize
    return total


# ---------------------------------------------------------------------------
# executable serialization (best-effort; re-lower is always a valid fallback)
# ---------------------------------------------------------------------------


def _serialize_executable(compiled) -> Optional[bytes]:
    try:
        from jax.experimental import serialize_executable

        return pickle.dumps(serialize_executable.serialize(compiled))
    except Exception:
        return None


def _deserialize_executable(payload: bytes):
    try:
        from jax.experimental import serialize_executable

        return serialize_executable.deserialize_and_load(*pickle.loads(payload))
    except Exception:
        return None


# ---------------------------------------------------------------------------
# the artifact
# ---------------------------------------------------------------------------


class Accelerator:
    """An AOT-lowered Graphitron accelerator for one (target, shape bucket).

    Produced by ``program.lower(target, shape)``. Bind it to any graph of
    the bucket — ``bind`` performs a shape/padding check only and returns a
    ready-warm :class:`~repro.core.session.Session`. ``save``/:func:
    `load_accelerator` persist it across processes.
    """

    def __init__(self, program: "Program", target: Target, shape: GraphShape,
                 *, _blobs: Optional[Dict[str, bytes]] = None,
                 _profile: Optional[Dict[str, Any]] = None,
                 _tuned: Optional[Dict[str, Any]] = None):
        module = program.module
        if module.graph.weighted and not shape.weighted:
            raise AcceleratorError(
                "program declares a weighted edgeset but the shape bucket is "
                "unweighted; lower with GraphShape(..., weighted=True)"
            )
        self.program = program
        self.target = target
        self.shape = shape
        self.fingerprint = accelerator_fingerprint(
            program.fingerprint, target, shape
        )
        # provenance of an autotuned Target (a TunedConfig dict from
        # repro.autotune, stamped by the tuner / tuned lowering paths);
        # persisted in the artifact manifest so a warm-started process
        # knows it runs a tuned substrate without re-searching
        self.tuned: Optional[Dict[str, Any]] = (
            dict(_tuned) if _tuned else None
        )
        # profiling baseline fed by traced runs (repro.telemetry): per span
        # name -> {count, total_s, max_s}; persisted in the artifact
        # manifest so warm-started processes inherit it
        self._profile_lock = threading.Lock()
        self._profile: Dict[str, Dict[str, float]] = dict(
            (_profile or {}).get("spans", {})
        )
        self.profile_runs = int((_profile or {}).get("runs", 0))
        tr = tel.get()
        sp = tr.span(
            "lower", fingerprint=self.fingerprint[:16], target=target.kind,
            bucket=f"{shape.n_vertices}v/{shape.n_edges}e",
            from_artifact=_blobs is not None,
        ) if tr.enabled else tel.NULL_SPAN
        t0 = time.perf_counter()
        with sp:
            if target.kind == "local":
                self.library: Optional[KernelLibrary] = KernelLibrary(
                    module, target, shape
                )
                self._plans = self.library.compile_all(blobs=_blobs)
            else:
                # distributed supersteps close over the device mesh: lowered
                # lazily at bind, but the artifact metadata/report still holds
                self.library = None
                self._plans = tuple(
                    _kernel_plan(module, k, None, "lazy", 0.0, shape)
                    for k in module.kernels.values()
                )
        self.lower_time_s = time.perf_counter() - t0
        self.binds = 0

    # -- introspection -------------------------------------------------------
    def report(self) -> AcceleratorReport:
        """The HLS-resource-report analogue for this lowering."""
        module = self.program.module
        state_bytes = _module_state_bytes(module, self.shape)
        gb_bytes = 4 * (
            (len(backend.GB_ARRAY_KEYS) - 2) * self.shape.n_edges
            + self.shape.n_vertices  # orig_id is [V]
            + 2  # logical_counts is [2]
        )
        temps = [k.temp_bytes or 0 for k in self._plans]
        outs = [k.out_bytes or 0 for k in self._plans]
        peak = state_bytes + gb_bytes + max(
            (t + o for t, o in zip(temps, outs)), default=0
        )
        return AcceleratorReport(
            target=self.target, shape=self.shape, kernels=self._plans,
            state_bytes=state_bytes, gb_bytes=gb_bytes,
            live_buffer_peak_bytes=peak, lower_time_s=self.lower_time_s,
            pass_report=tuple(module.pass_report),
            determinism=self._determinism(),
            profile=self.profile(),
        )

    def _determinism(self) -> str:
        from ..analysis import determinism_certificate

        return determinism_certificate(self.program.module)

    # -- profiling baseline (repro.telemetry) --------------------------------
    def record_profile(self, trace: Optional[Dict[str, Any]]) -> None:
        """Fold one traced run's summary (``EngineResult.trace``) into the
        accelerator's profile. Sessions call this after every traced run;
        untraced runs pass None and cost one branch."""
        if not trace:
            return
        spans = trace.get("spans") or {}
        with self._profile_lock:
            self.profile_runs += 1
            for name, a in spans.items():
                cur = self._profile.setdefault(
                    name, {"count": 0, "total_s": 0.0, "max_s": 0.0}
                )
                cur["count"] += a.get("count", 0)
                cur["total_s"] = round(cur["total_s"] + a.get("total_s", 0.0), 6)
                cur["max_s"] = max(cur["max_s"], a.get("max_s", 0.0))

    def profile(self) -> Dict[str, Any]:
        """The accumulated profiling baseline: ``{"runs": N, "spans":
        {name: {count, total_s, max_s}}}`` (empty until a traced run)."""
        with self._profile_lock:
            return {
                "runs": self.profile_runs,
                "spans": {k: dict(v) for k, v in self._profile.items()},
            }

    def __repr__(self) -> str:
        return (
            f"Accelerator({self.fingerprint[:12]}, {self.target.describe()}, "
            f"{self.shape.describe()}, kernels={len(self._plans)})"
        )

    # -- binding -------------------------------------------------------------
    def _check(self, graph: "GraphData") -> None:
        self.shape.check_bucket(graph)

    def _backend_opts(self, extra: Dict[str, Any]) -> Dict[str, Any]:
        opts = dict(extra)
        opts["target"] = self.target
        if self.target.kind == "local":
            opts["library"] = self.library
        else:
            opts.setdefault("mesh", self.target.mesh())
            opts.setdefault("axis", self.target.axis)
        return opts

    def bind(self, graph: "GraphData", *, argv: Optional[list] = None,
             **backend_opts) -> "Session":
        """Place this accelerator onto a graph of the bucket shape.

        A shape/padding check is the only per-graph work: the returned
        Session reuses the artifact's AOT executables, so N graphs of one
        bucket — and every process restart via :func:`load_accelerator` —
        share a single lowering.
        """
        from .session import Session

        self._check(graph)
        self.binds += 1
        tr = tel.get()
        sp = tr.span(
            "bind", fingerprint=self.fingerprint[:16],
            n_vertices=graph.n_vertices, n_edges=graph.n_edges,
        ) if tr.enabled else tel.NULL_SPAN
        with sp:
            session = Session(self.program, graph, backend=self.target.kind,
                              argv=argv, **self._backend_opts(backend_opts))
        session.accelerator = self
        return session

    def pool(self, graph: "GraphData", size: int = 2, *,
             argv: Optional[list] = None, **backend_opts) -> "SessionPool":
        """A SessionPool over one bucket graph; every worker shares the
        artifact's kernel library (no per-worker compile cost)."""
        from .session import SessionPool

        self._check(graph)
        self.binds += 1
        return SessionPool(self.program, graph, backend=self.target.kind,
                           size=size, argv=argv,
                           **self._backend_opts(backend_opts))

    def bind_batch(self, graph: "GraphData", *, argv: Optional[list] = None,
                   max_batch: Optional[int] = None, msbfs: bool = True,
                   **backend_opts) -> "BatchSession":
        """Batched multi-query twin of :meth:`bind` (see Program.bind_batch)."""
        from .session import BatchSession

        self._check(graph)
        self.binds += 1
        session = BatchSession(self.program, graph, backend=self.target.kind,
                               argv=argv, max_batch=max_batch, msbfs=msbfs,
                               **self._backend_opts(backend_opts))
        session.accelerator = self
        return session

    # -- persistence ---------------------------------------------------------
    def save(self, path: str, include_executables: bool = True) -> str:
        """Persist this accelerator to a directory artifact.

        Always written: the manifest (format/fingerprints/target/shape/
        options/pass report), the ``.gt`` source, and the canonical
        serialized MIR. When the JAX backend supports executable
        serialization (and ``include_executables``), the AOT executables
        are stored too; otherwise :func:`load_accelerator` transparently
        re-lowers from the MIR.
        """
        os.makedirs(path, exist_ok=True)
        opts = self.program.options
        kernels_manifest: Dict[str, Dict[str, Any]] = {}
        exe_dir = os.path.join(path, "executables")
        for plan in self._plans:
            entry: Dict[str, Any] = {"mode": plan.mode, "executable": None}
            if include_executables and self.library is not None:
                g = self.library._generic.get(plan.name)
                payload = (
                    _serialize_executable(g.compiled_full)
                    if g is not None and g.compiled_full is not None else None
                )
                if payload is not None:
                    os.makedirs(exe_dir, exist_ok=True)
                    rel = os.path.join("executables", f"{plan.name}.bin")
                    with open(os.path.join(path, rel), "wb") as f:
                        f.write(payload)
                    entry["executable"] = rel
            kernels_manifest[plan.name] = entry
        manifest = {
            "format": ARTIFACT_FORMAT,
            "jax_version": jax.__version__,
            "jax_backend": jax.default_backend(),
            "fingerprint": self.fingerprint,
            "program_fingerprint": self.program.fingerprint,
            "mir_fingerprint": mir.fingerprint(self.program.module),
            "target": self.target.to_dict(),
            "shape": self.shape.to_dict(),
            "options": {
                "passes": opts.passes,
                "scalar_bindings": [list(b) for b in opts.scalar_bindings],
                "target_overrides": [list(o) for o in opts.target_overrides],
            },
            "pass_report": list(self.program.module.pass_report),
            "determinism": self._determinism(),
            "kernels": kernels_manifest,
            "profile": self.profile(),
            "tuned": self.tuned,
        }
        with open(os.path.join(path, "program.gt"), "w") as f:
            f.write(self.program.source)
        with open(os.path.join(path, "mir.txt"), "w") as f:
            f.write(mir.canonical_serialize(self.program.module))
        with open(os.path.join(path, MANIFEST_NAME), "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
            f.write("\n")
        return path


def quarantine_artifact(path: str) -> Optional[str]:
    """Move a failed artifact directory aside so it is never re-probed.

    Re-lowering after a load failure overwrites the directory in place
    (``save`` is the normal heal path), but serving registries want the
    failed content *out of the key's path* atomically — otherwise every
    request between the failure and the heal retries the same corrupt
    load (a stale-artifact retry storm). A rename keeps the bytes around
    for postmortem under ``<path>.quarantined[.N]``. Best-effort: returns
    the new path, or None when the store does not permit the rename.
    """
    for i in range(1000):
        dst = f"{path}.quarantined" + ("" if i == 0 else f".{i}")
        if os.path.exists(dst):
            continue
        try:
            os.rename(path, dst)
            return dst
        except OSError:
            return None
    return None  # pragma: no cover - 1000 quarantines of one key


def load_or_lower(program: "Program", target: Target, shape: GraphShape,
                  artifact_dir: str) -> Tuple[Accelerator, bool, float]:
    """Resolve an accelerator from an artifact store, lowering on a miss.

    Artifact directories are keyed by :func:`accelerator_fingerprint`, so a
    stale or foreign artifact is simply not found (and a corrupt one fails
    its load check and is re-lowered). On a miss the fresh lowering is
    saved back best-effort — an unwritable store degrades to cold lowering,
    never to a failure. Returns ``(accelerator, loaded, seconds)`` where
    ``seconds`` is the load or lower wall time. This is the one shared
    resolution path (serve warm-start, ci_bench warm-bind gate).
    """
    key = accelerator_fingerprint(program.fingerprint, target, shape)
    path = os.path.join(artifact_dir, key[:24])
    if os.path.isdir(path):
        # corrupt/stale content at a matching path: a tampered manifest
        # or truncated source raises anything from AcceleratorError to
        # ProgramError/ValueError — every load failure means re-lower
        with contextlib.suppress(Exception):
            t0 = time.perf_counter()
            acc = load_accelerator(path)
            return acc, True, time.perf_counter() - t0
    t0 = time.perf_counter()
    acc = Accelerator(program, target, shape)
    dt = time.perf_counter() - t0
    # artifact store not writable: cold result is still valid
    with contextlib.suppress(OSError):
        acc.save(path)
    return acc, False, dt


def load_accelerator(path: str) -> Accelerator:
    """Load a saved accelerator artifact (see :meth:`Accelerator.save`).

    The source is recompiled through the (front-end) Program cache and the
    result is verified against the stored program fingerprint — a drifted
    toolchain or edited artifact fails loudly instead of running a program
    that no longer matches its executables. Stored executables are loaded
    where the current JAX backend can deserialize them; anything else
    re-lowers transparently.
    """
    from .program import compile_program

    manifest_path = os.path.join(path, MANIFEST_NAME)
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise AcceleratorError(f"cannot read accelerator manifest: {e}") from e
    if manifest.get("format") != ARTIFACT_FORMAT:
        raise AcceleratorError(
            f"unsupported artifact format {manifest.get('format')!r} "
            f"(this build reads format {ARTIFACT_FORMAT})"
        )
    try:
        with open(os.path.join(path, "program.gt")) as f:
            source = f.read()
    except OSError as e:
        raise AcceleratorError(f"artifact is missing program.gt: {e}") from e
    o = manifest.get("options", {})
    options = CompileOptions(
        passes=o.get("passes", "default"),
        scalar_bindings=tuple(tuple(b) for b in o.get("scalar_bindings", [])),
        target_overrides=tuple(tuple(t) for t in o.get("target_overrides", [])),
    )
    program = compile_program(source, options)
    if program.fingerprint != manifest.get("program_fingerprint"):
        raise AcceleratorError(
            "stale accelerator artifact: recompiling its source yields a "
            "different program fingerprint (source/options/toolchain drift); "
            "re-lower with program.lower(target, shape) and save again"
        )
    blobs: Dict[str, bytes] = {}
    if manifest.get("jax_version") == jax.__version__ and \
            manifest.get("jax_backend") == jax.default_backend():
        for name, entry in manifest.get("kernels", {}).items():
            rel = entry.get("executable")
            if rel:
                # unreadable blob: re-lower this kernel
                with contextlib.suppress(OSError), \
                        open(os.path.join(path, rel), "rb") as f:
                    blobs[name] = f.read()
    target = Target.from_dict(manifest["target"])
    shape = GraphShape(**manifest["shape"])
    profile = manifest.get("profile")
    tuned = manifest.get("tuned")
    return Accelerator(program, target, shape, _blobs=blobs or None,
                       _profile=profile if isinstance(profile, dict) else None,
                       _tuned=tuned if isinstance(tuned, dict) else None)
