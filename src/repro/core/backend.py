"""Back-end: lowers MIR kernels to JAX executables (paper §III-B3).

The FPGA back-end emits Xilinx OpenCL modules (Burst Read, Cache, Edge/Vertex
Operation, Shuffle, RAW-resolve, Reduce, Burst Write — Fig. 4). Here each
module becomes a composable JAX/Pallas stage:

    Burst Read    -> static processing order: dst-partitioned, ascending-src
                     edge streaming (tiled HBM->VMEM DMA on TPU)
    Cache         -> hub-vertex relabeling so hot properties live in a dense
                     prefix block (VMEM-resident on TPU)
    Edge/Vertex Op-> the user function body, evaluated lane-parallel by the
                     expression evaluator below (VPU/MXU code on TPU)
    Shuffle+Reduce-> precomputed dst-sort permutation + sorted segment
                     reduction (conflict-free by construction); optionally
                     routed through the Pallas ``shuffle_reduce`` kernel
    Burst Write   -> sequential lane-aligned writes (plain vector ops)

Semantics notes (mirror the paper's pipeline transforms):
* RAW decoupling (Fig. 5->6): within one kernel, property reads observe the
  kernel's *input* state; scattered reduce-writes commit at kernel exit.
* RMW normalization (§III-C2) happens in the middle-end, so every scattered
  write reaching this layer is either a reduction or a declared plain store.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import fir, mir
from .options import CompileOptions
from ..graph.storage import GraphData

DTYPES = {"int": jnp.int32, "float": jnp.float32, "bool": jnp.bool_}

WEIGHT_KEY = "__weight__"


def dtype_of(scalar: str):
    return DTYPES[scalar]


def identity_for(op: str, dtype) -> Any:
    dtype = jnp.dtype(dtype)
    if op == "+":
        return dtype.type(0)
    if op == "*":
        return dtype.type(1)
    if op == "min":
        return jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer) else dtype.type(jnp.inf)
    if op == "max":
        return jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer) else dtype.type(-jnp.inf)
    raise ValueError(f"no identity for reduce op {op!r}")


def combine(op: str, a, b):
    if op == "+":
        return a + b
    if op == "-":
        return a - b
    if op == "*":
        return a * b
    if op == "min":
        return jnp.minimum(a, b)
    if op == "max":
        return jnp.maximum(a, b)
    raise ValueError(f"unknown reduce op {op!r}")


def segment_reduce(op: str, vals, ids, num_segments: int, indices_are_sorted: bool):
    if op in ("+", "-"):
        return jax.ops.segment_sum(vals, ids, num_segments, indices_are_sorted=indices_are_sorted)
    if op == "*":
        return jax.ops.segment_prod(vals, ids, num_segments, indices_are_sorted=indices_are_sorted)
    if op == "min":
        return jax.ops.segment_min(vals, ids, num_segments, indices_are_sorted=indices_are_sorted)
    if op == "max":
        return jax.ops.segment_max(vals, ids, num_segments, indices_are_sorted=indices_are_sorted)
    raise ValueError(op)


def apply_scatter(
    prop_arr: jnp.ndarray,
    idx: jnp.ndarray,
    vals: jnp.ndarray,
    mask: Optional[jnp.ndarray],
    op: Optional[str],
    *,
    sort_perm: Optional[jnp.ndarray] = None,
    options: CompileOptions,
) -> jnp.ndarray:
    """Commit one scattered write group — the Shuffle/RAW/Reduce stage."""
    n = prop_arr.shape[0]
    vals = vals.astype(prop_arr.dtype) if vals.dtype != prop_arr.dtype else vals
    if op is None:
        if options.shuffle:
            # Deterministic last-write-wins: XLA leaves duplicate-index
            # .set() order unspecified, so under the shuffle substrate we
            # resolve each slot to the LAST writing edge in stream order —
            # the answer a sequential interpretation of the kernel gives.
            # (This is the commit path the GT101 race analysis forces on.)
            n_lanes = idx.shape[0]
            pos = jnp.arange(n_lanes, dtype=jnp.int32)
            if mask is not None:
                pos = jnp.where(mask, pos, -1)
            last = jax.ops.segment_max(pos, idx, n)
            written = last >= 0
            chosen = vals[jnp.clip(last, 0, max(n_lanes - 1, 0))]
            return jnp.where(written, chosen, prop_arr)
        # plain scatter store: mask by re-storing the original value
        if mask is not None:
            old = prop_arr[idx]
            vals = jnp.where(mask, vals, old)
        return prop_arr.at[idx].set(vals)
    if op == "-":
        vals, op = -vals, "+"
    ident = identity_for(op, prop_arr.dtype)
    if mask is not None:
        vals = jnp.where(mask, vals, ident)
    if options.pallas:
        from ..kernels import ops as kops

        reduced = kops.shuffle_reduce(
            vals, idx, n, op, interpret=options.interpret_effective
        )
        return combine(op, prop_arr, reduced)
    if options.shuffle and sort_perm is not None:
        # conflict-free path: precomputed routing (sort) + segment reduce
        reduced = segment_reduce(op, vals[sort_perm], idx[sort_perm], n, True)
        # segment_min/max fill empty segments with identity of that reduce,
        # segment_sum fills 0 — all are the correct identities.
        return combine(op, prop_arr, reduced)
    # unoptimized random scatter (the "baseline" path)
    if op == "+":
        return prop_arr.at[idx].add(vals)
    if op == "*":
        return prop_arr.at[idx].mul(vals)
    if op == "min":
        return prop_arr.at[idx].min(vals)
    if op == "max":
        return prop_arr.at[idx].max(vals)
    raise ValueError(op)


# ---------------------------------------------------------------------------
# Expression / statement evaluation contexts
# ---------------------------------------------------------------------------


@dataclass
class LaneCtx:
    """One vectorized execution scope (vertex lanes or edge lanes)."""

    n_lanes: int
    bindings: Dict[str, jnp.ndarray]  # param/loop-var name -> lane index array
    valid: Optional[jnp.ndarray]  # lane validity (padded subsets)
    # expanded-lane support: position into the parent lane array
    parent: Optional["LaneCtx"] = None
    parent_pos: Optional[jnp.ndarray] = None
    env: Dict[str, jnp.ndarray] = field(default_factory=dict)


@dataclass
class KernelExec:
    """Mutable state while lowering/executing one kernel invocation."""

    module: mir.Module
    kernel: mir.Kernel
    options: CompileOptions
    state: Dict[str, jnp.ndarray]
    scalars: Dict[str, jnp.ndarray]
    graph_bind: Dict[str, Any]  # csr/csc arrays for neighbor loops
    scatter_updates: List[Tuple[str, Optional[str], jnp.ndarray, jnp.ndarray, Optional[jnp.ndarray], Optional[jnp.ndarray]]] = field(default_factory=list)
    seq_writes: Dict[str, jnp.ndarray] = field(default_factory=dict)

    # -- property views -------------------------------------------------
    def prop_current(self, name: str) -> jnp.ndarray:
        return self.seq_writes.get(name, self.state[name])

    # -- expression evaluation -------------------------------------------
    def eval(self, e: fir.Expr, lane: LaneCtx):
        m = self.module
        if isinstance(e, fir.IntLit):
            return jnp.int32(e.value)
        if isinstance(e, fir.FloatLit):
            return jnp.float32(e.value)
        if isinstance(e, fir.BoolLit):
            return jnp.bool_(e.value)
        if isinstance(e, fir.Ident):
            name = e.name
            if name in lane.bindings:
                return lane.bindings[name]
            if name in lane.env:
                return lane.env[name]
            if lane.parent is not None:
                # gather vertex-lane values into the expanded lane
                if name in lane.parent.bindings:
                    return lane.parent.bindings[name][lane.parent_pos]
                if name in lane.parent.env:
                    v = lane.parent.env[name]
                    return v[lane.parent_pos] if getattr(v, "ndim", 0) > 0 else v
            if name in self.scalars:
                return self.scalars[name]
            if name in m.properties:
                raise BackendError(
                    f"property {name!r} used without an index in kernel "
                    f"{self.kernel.name!r}"
                )
            raise BackendError(f"unknown identifier {name!r} in kernel {self.kernel.name!r}")
        if isinstance(e, fir.Index):
            if isinstance(e.base, fir.Ident) and e.base.name in m.properties:
                idx = self.eval(e.index, lane)
                return self.prop_current(e.base.name)[idx]
            raise BackendError("only property indexing is supported in kernels")
        if isinstance(e, fir.BinOp):
            a = self.eval(e.lhs, lane)
            b = self.eval(e.rhs, lane)
            return _binop(e.op, a, b)
        if isinstance(e, fir.UnaryOp):
            v = self.eval(e.operand, lane)
            return jnp.logical_not(v) if e.op == "!" else -v
        if isinstance(e, fir.Call):
            if e.func == "original_id":
                idx = self.eval(e.args[0], lane)
                return self.graph_bind["orig_id"][idx]
            args = [self.eval(a, lane) for a in e.args]
            return _builtin(e.func, args)
        if isinstance(e, fir.MethodCall):
            if e.method == "size":
                name = _obj_name(e.obj)
                # logical (unpadded) counts, traced so one AOT executable
                # serves every graph of the bucket; globally-normalized
                # algorithms (PageRank 1/|V|) thus agree padded vs unpadded
                lc = self.graph_bind.get("logical_counts")
                if name == self.module.graph.edgeset_name:
                    if lc is not None:
                        return lc[1]
                    return jnp.int32(self.graph_bind["n_edges"])
                if lc is not None:
                    return lc[0]
                return jnp.int32(self.graph_bind["n_vertices"])
            raise BackendError(f"method {e.method!r} not allowed inside kernels")
        raise BackendError(f"cannot evaluate {type(e).__name__} in kernel")

    # -- statement execution -----------------------------------------------
    def exec_block(self, stmts: Sequence[fir.Stmt], lane: LaneCtx, mask):
        for st in stmts:
            self.exec_stmt(st, lane, mask)

    def exec_stmt(self, st: fir.Stmt, lane: LaneCtx, mask):
        m = self.module
        if isinstance(st, fir.VarDecl):
            val = self.eval(st.init, lane) if st.init is not None else jnp.zeros((), DTYPES[st.type.kind])
            if isinstance(st.type, fir.ScalarType):
                val = _cast(val, DTYPES[st.type.kind])
            lane.env[st.name] = _broadcast(val, lane.n_lanes)
            return
        if isinstance(st, fir.Assign):
            self._write(st.target, None, self.eval(st.value, lane), lane, mask, st.line)
            return
        if isinstance(st, fir.ReduceAssign):
            self._write(st.target, st.op, self.eval(st.value, lane), lane, mask, st.line)
            return
        if isinstance(st, fir.If):
            cond = _broadcast(self.eval(st.cond, lane), lane.n_lanes)
            cond = cond.astype(jnp.bool_)
            tmask = cond if mask is None else jnp.logical_and(mask, cond)
            self.exec_block(st.then_body, lane, tmask)
            if st.else_body:
                fmask = jnp.logical_not(cond) if mask is None else jnp.logical_and(mask, jnp.logical_not(cond))
                self.exec_block(st.else_body, lane, fmask)
            return
        if isinstance(st, fir.For):
            self._exec_neighbor_loop(st, lane, mask)
            return
        if isinstance(st, fir.ExprStmt):
            self.eval(st.expr, lane)
            return
        raise BackendError(f"unsupported device statement {type(st).__name__}")

    # -- neighbor loop: vertex lane -> expanded CSR lane ---------------------
    def _exec_neighbor_loop(self, st: fir.For, lane: LaneCtx, mask):
        it = st.iter
        assert isinstance(it, fir.MethodCall)
        direction = "out" if it.method == "getNeighbors" else "in"
        gb = self.graph_bind
        if direction == "out":
            row_pos, ngh, eids = gb["csr_row_pos"], gb["csr_indices"], gb["csr_eids"]
        else:
            row_pos, ngh, eids = gb["csc_row_pos"], gb["csc_indices"], gb["csc_eids"]
        ex = LaneCtx(
            n_lanes=int(ngh.shape[0]),
            bindings={st.var: ngh, "edge": eids},
            valid=gb.get(f"{direction}_valid"),
            parent=lane,
            parent_pos=row_pos,
        )
        exp_mask = None
        if mask is not None:
            exp_mask = mask[row_pos]
        if ex.valid is not None:
            exp_mask = ex.valid if exp_mask is None else jnp.logical_and(exp_mask, ex.valid)
        # execute body in the expanded lane; local reduce-assigns to parent
        # vars become segment reductions (the unroll+reduce transform)
        self._expanded_parent_reduce(st.body, ex, exp_mask, lane, row_pos)

    def _expanded_parent_reduce(self, body, ex: LaneCtx, exp_mask, lane: LaneCtx, row_pos):
        for st in body:
            if isinstance(st, fir.ReduceAssign) and isinstance(st.target, fir.Ident) \
                    and st.target.name in lane.env:
                vals = _broadcast(self.eval(st.value, ex), ex.n_lanes)
                op = st.op
                if op == "-":
                    vals, op = -vals, "+"
                ident = identity_for(op, vals.dtype)
                if exp_mask is not None:
                    vals = jnp.where(exp_mask, vals, ident)
                red = segment_reduce(op, vals, row_pos, lane.n_lanes, True)
                old = lane.env[st.target.name]
                lane.env[st.target.name] = combine(op, old, red.astype(old.dtype))
            elif isinstance(st, fir.If):
                cond = _broadcast(self.eval(st.cond, ex), ex.n_lanes).astype(jnp.bool_)
                tmask = cond if exp_mask is None else jnp.logical_and(exp_mask, cond)
                self._expanded_parent_reduce(st.then_body, ex, tmask, lane, row_pos)
                if st.else_body:
                    fm = jnp.logical_not(cond)
                    fm = fm if exp_mask is None else jnp.logical_and(exp_mask, fm)
                    self._expanded_parent_reduce(st.else_body, ex, fm, lane, row_pos)
            else:
                self.exec_stmt(st, ex, exp_mask)

    # -- writes -------------------------------------------------------------
    def _write(self, target: fir.Expr, op: Optional[str], val, lane: LaneCtx, mask, line: int):
        m = self.module
        # local variable
        if isinstance(target, fir.Ident):
            name = target.name
            if name == self.kernel.weight_param:
                # edge-weight write (CGAW-style): lane-aligned store, visible
                # to subsequent reads of the weight param in this kernel
                cur = self.seq_writes.get(WEIGHT_KEY, lane.bindings[name])
                val = _broadcast(val, lane.n_lanes).astype(cur.dtype)
                new = val if op is None else combine(op, cur, val)
                wmask = mask
                if lane.valid is not None:
                    wmask = lane.valid if wmask is None else jnp.logical_and(wmask, lane.valid)
                if wmask is not None:
                    new = jnp.where(wmask, new, cur)
                self.seq_writes[WEIGHT_KEY] = new
                lane.bindings[name] = new
                return
            if name in lane.env:
                old = lane.env[name]
                new = _broadcast(val, lane.n_lanes).astype(old.dtype) if hasattr(old, "dtype") else val
                if op is not None:
                    new = combine(op, old, new)
                if mask is not None:
                    new = jnp.where(mask, new, old)
                lane.env[name] = new
                return
            if lane.parent is not None and name in lane.parent.env:
                raise BackendError(
                    f"line {line}: plain assignment to outer var {name!r} inside a "
                    "neighbor loop is ambiguous; use a reduction (+=, min=, ...)"
                )
            raise BackendError(f"line {line}: assignment to undeclared variable {name!r}")
        # property write
        assert isinstance(target, fir.Index) and isinstance(target.base, fir.Ident)
        prop = target.base.name
        if prop not in m.properties:
            raise BackendError(f"line {line}: write to unknown property {prop!r}")
        idx_expr = target.index
        # sequential (burst write) path: P[v] at the kernel's own vertex lane
        if (
            self.kernel.kind is mir.KernelKind.VERTEX
            and isinstance(idx_expr, fir.Ident)
            and idx_expr.name == self.kernel.vertex_param
            and lane.parent is None
        ):
            cur = self.prop_current(prop)
            vids = lane.bindings[idx_expr.name]
            val = _broadcast(val, lane.n_lanes).astype(cur.dtype)
            if lane.valid is None and lane.n_lanes == cur.shape[0]:
                old = cur
                new = val if op is None else combine(op, old, val)
                if mask is not None:
                    new = jnp.where(mask, new, old)
                self.seq_writes[prop] = new
            else:
                wmask = mask
                if lane.valid is not None:
                    wmask = lane.valid if wmask is None else jnp.logical_and(wmask, lane.valid)
                old = cur[vids]
                new = val if op is None else combine(op, old, val)
                if wmask is not None:
                    new = jnp.where(wmask, new, old)
                self.seq_writes[prop] = cur.at[vids].set(new)
            return
        # scattered / accumulator path
        idx = self.eval(idx_expr, lane)
        # the precomputed shuffle routing is only valid when scattering
        # along the edge kernel's destination lane in full-stream order
        dst_sorted = (
            self.kernel.kind is mir.KernelKind.EDGE
            and isinstance(idx_expr, fir.Ident)
            and idx_expr.name == self.kernel.dst_param
            and lane.parent is None
        )
        self._scatter(prop, op, idx, val, lane, mask, dst_sorted=dst_sorted)

    def _scatter(self, prop: str, op: Optional[str], idx, val, lane: LaneCtx, mask,
                 dst_sorted: bool = False):
        val = _broadcast(val, lane.n_lanes)
        idx = _broadcast(idx, lane.n_lanes)
        wmask = mask
        if lane.valid is not None:
            wmask = lane.valid if wmask is None else jnp.logical_and(wmask, lane.valid)
        sort_perm = self.graph_bind.get("dst_sort_perm") if dst_sorted else None
        self.scatter_updates.append((prop, op, idx, val, wmask, sort_perm))

    # -- commit ---------------------------------------------------------------
    def commit(self) -> Dict[str, jnp.ndarray]:
        out: Dict[str, jnp.ndarray] = {}
        out.update(self.seq_writes)
        for prop, op, idx, val, wmask, sort_perm in self.scatter_updates:
            cur = out.get(prop, self.state[prop])
            out[prop] = apply_scatter(
                cur, idx, val, wmask, op, sort_perm=sort_perm, options=self.options
            )
        return out


class BackendError(Exception):
    pass


def _binop(op: str, a, b):
    if op == "+":
        return a + b
    if op == "-":
        return a - b
    if op == "*":
        return a * b
    if op == "/":
        # '/' follows numpy true-division; integer contexts should use
        # to_int() explicitly (the paper's algorithms only divide floats)
        return a / b
    if op == "==":
        return a == b
    if op == "!=":
        return a != b
    if op == "<":
        return a < b
    if op == "<=":
        return a <= b
    if op == ">":
        return a > b
    if op == ">=":
        return a >= b
    if op == "&":
        return jnp.logical_and(a, b)
    if op == "|":
        return jnp.logical_or(a, b)
    raise BackendError(f"unknown operator {op!r}")


def _builtin(name: str, args):
    if name == "exp":
        return jnp.exp(args[0])
    if name == "log":
        return jnp.log(args[0])
    if name == "abs":
        return jnp.abs(args[0])
    if name == "sqrt":
        return jnp.sqrt(args[0])
    if name == "sigmoid":
        return jax.nn.sigmoid(args[0])
    if name == "leakyrelu":
        return jnp.where(args[0] > 0, args[0], args[0] * args[1])
    if name == "min":
        return jnp.minimum(args[0], args[1])
    if name == "max":
        return jnp.maximum(args[0], args[1])
    if name == "floor":
        return jnp.floor(args[0])
    if name == "pow":
        return jnp.power(args[0], args[1])
    if name == "to_float":
        return args[0].astype(jnp.float32)
    if name == "to_int":
        return args[0].astype(jnp.int32)
    raise BackendError(f"unknown builtin {name!r}")


def _broadcast(v, n: int):
    v = jnp.asarray(v)
    if v.ndim == 0:
        return jnp.broadcast_to(v, (n,))
    return v


def _cast(v, dt):
    v = jnp.asarray(v)
    return v.astype(dt) if v.dtype != dt else v


def _obj_name(e: fir.Expr) -> str:
    if isinstance(e, fir.Ident):
        return e.name
    raise BackendError("expected a plain identifier")


# ---------------------------------------------------------------------------
# Kernel lowering
# ---------------------------------------------------------------------------


@dataclass
class LoweredKernel:
    """A device kernel lowered against a concrete graph + target."""

    name: str
    kind: mir.KernelKind
    run_full: Callable  # jit'd (or AOT-compiled): (state, scalars) -> prop updates
    run_subset: Optional[Callable] = None  # jit'd: (state, scalars, batch) -> updates
    frontier: Optional[mir.FrontierInfo] = None
    # traceable twin of run_full (raw Python, un-jitted): what vmap-based
    # batch lowering traces through. AOT-compiled executables cannot be
    # traced, so library-backed kernels MUST provide this.
    trace_full: Optional[Callable] = None


# graph-binding entries that are device arrays (as opposed to the static
# n_vertices/n_edges ints). Shape-generic (AOT) lowering passes exactly
# these as traced arguments so one executable serves every graph of a
# shape bucket; all are int32, [E]-shaped except orig_id ([V]) and
# logical_counts ([2]: unpadded |V|, |E| — what size() reports).
GB_ARRAY_KEYS: Tuple[str, ...] = (
    "order", "src", "dst", "dst_sort_perm",
    "csr_row_pos", "csr_indices", "csr_eids",
    "csc_row_pos", "csc_indices", "csc_eids",
    "orig_id", "logical_counts",
)


def make_frontier_builder(n_vertices: int, n_edges: int, weighted: bool):
    """Jitted device-side frontier expansion, shape-generic.

    Maps active-vertex masks to padded CSR edge ranges in O(V + pad_e)
    work (never O(E)). Per-graph arrays (degrees, row starts, CSR
    indices/eids) are traced arguments, so one builder serves every graph
    of a shape bucket; only (|V|, |E|, weighted) are baked in. This is the
    single copy of the expansion math — the engine binds its own graph's
    arrays over it, the accelerator's KernelLibrary shares one across
    binds.
    """

    import functools

    @functools.partial(jax.jit, static_argnames=("pad_v", "pad_e"))
    def build(deg, starts, csr_indices, csr_eids, mask, weights, pad_v, pad_e):
        (act,) = jnp.nonzero(mask, size=pad_v, fill_value=n_vertices)  # O(V)
        vok = act < n_vertices
        act_c = jnp.minimum(act, n_vertices - 1)
        deg_a = jnp.where(vok, deg[act_c], 0)
        starts_a = starts[act_c]
        cum = jnp.cumsum(deg_a) - deg_a
        # ragged CSR-range expansion, O(pad_e)
        src = jnp.repeat(act_c, deg_a, total_repeat_length=pad_e)
        offs = jnp.repeat(cum, deg_a, total_repeat_length=pad_e)
        base = jnp.repeat(starts_a, deg_a, total_repeat_length=pad_e)
        pos = jnp.arange(pad_e, dtype=jnp.int32)
        valid = pos < jnp.sum(deg_a)
        slots = jnp.minimum(base + (pos - offs), n_edges - 1)
        dst = csr_indices[slots]
        eid = csr_eids[slots]
        w = weights[eid] if weighted else jnp.zeros((pad_e,), jnp.float32)
        return src, dst, w, eid, valid

    return build


def gb_array_specs(n_vertices: int, n_edges: int) -> Dict[str, Any]:
    """jax.ShapeDtypeStruct tree of the graph-binding arrays for a shape."""
    specs = {}
    for key in GB_ARRAY_KEYS:
        if key == "logical_counts":
            n = 2
        elif key == "orig_id":
            n = n_vertices
        else:
            n = n_edges
        specs[key] = jax.ShapeDtypeStruct((n,), jnp.int32)
    return specs


def split_gb_arrays(gb: Dict[str, Any]) -> Dict[str, jnp.ndarray]:
    """Project a concrete graph-binding dict onto its array entries."""
    return {k: gb[k] for k in GB_ARRAY_KEYS}


def _graph_bindings(
    g: GraphData,
    module: mir.Module,
    options,
    new2old: Optional[np.ndarray] = None,
):
    """Precompute static processing-order arrays (the Burst Read plan).

    ``options`` is a :class:`~repro.core.target.Target` (or a legacy
    CompileOptions through the compat shim — both expose the substrate
    attributes read here).
    """
    if options.burst:
        auto = getattr(options, "auto_partitions", None)
        if auto is not None:
            n_parts = auto(g.n_vertices)
        else:
            n_parts = options.n_partitions or max(1, g.n_vertices // 4096)
        pe = g.partition_by_dst(n_parts)
        order = pe.edge_order
    else:
        order = np.arange(g.n_edges, dtype=np.int32)
    src_o = g.src[order]
    dst_o = g.dst[order]
    dst_sort = np.argsort(dst_o, kind="stable").astype(np.int32)

    indptr, csr_idx, csr_eids = g.csr
    in_indptr, csc_idx, csc_eids = g.csc
    row_ids = np.repeat(np.arange(g.n_vertices, dtype=np.int32), np.diff(indptr).astype(np.int64))
    in_row_ids = np.repeat(np.arange(g.n_vertices, dtype=np.int32), np.diff(in_indptr).astype(np.int64))
    gb = {
        "n_vertices": g.n_vertices,
        "n_edges": g.n_edges,
        "order": jnp.asarray(order),
        "src": jnp.asarray(src_o),
        "dst": jnp.asarray(dst_o),
        "dst_sort_perm": jnp.asarray(dst_sort),
        "csr_row_pos": jnp.asarray(row_ids),
        "csr_indices": jnp.asarray(csr_idx),
        "csr_eids": jnp.asarray(csr_eids),
        "csc_row_pos": jnp.asarray(in_row_ids),
        "csc_indices": jnp.asarray(csc_idx),
        "csc_eids": jnp.asarray(csc_eids),
        # lane-id -> original vertex id (identity unless hub-relabeled)
        "orig_id": jnp.asarray(
            new2old if new2old is not None else np.arange(g.n_vertices, dtype=np.int32)
        ),
        # unpadded counts behind size(): traced so in-bucket graph updates
        # (and padding itself) never change the executable
        "logical_counts": jnp.asarray(
            [g.n_vertices_logical, g.n_edges_logical], dtype=np.int32
        ),
    }
    return gb


def _exec_kernel_full(
    module: mir.Module,
    kernel: mir.Kernel,
    options: CompileOptions,
    gb: Dict[str, Any],
    state: Dict[str, jnp.ndarray],
    scalars: Dict[str, jnp.ndarray],
) -> Dict[str, jnp.ndarray]:
    """Trace one full-stream kernel execution: lanes -> body -> commit.

    Shared between the per-kernel ``run_full`` lowering and the fused
    pipeline lowering (which chains several of these inside ONE jit, each
    stage seeing the previous stage's committed updates)."""
    ex = KernelExec(module, kernel, options, state, scalars, gb)
    if kernel.kind is mir.KernelKind.EDGE:
        n = gb["src"].shape[0]
        bindings = {kernel.src_param: gb["src"], kernel.dst_param: gb["dst"],
                    "edge": gb["order"]}
        if kernel.weight_param is not None:
            bindings[kernel.weight_param] = state[WEIGHT_KEY][gb["order"]]
        lane = LaneCtx(n_lanes=n, bindings=bindings, valid=None)
        ex.exec_block(kernel.func.body, lane, None)
        out = ex.commit()
        if WEIGHT_KEY in out:
            # processing-order weights -> original edge order
            out[WEIGHT_KEY] = state[WEIGHT_KEY].at[gb["order"]].set(out[WEIGHT_KEY])
        return out
    n = gb["n_vertices"]
    lane = LaneCtx(
        n_lanes=n,
        bindings={kernel.vertex_param: jnp.arange(n, dtype=jnp.int32)},
        valid=None,
    )
    ex.exec_block(kernel.func.body, lane, None)
    return ex.commit()


def lower_pipeline(
    module: mir.Module,
    pipeline: mir.PipelineKernel,
    gb: Dict[str, Any],
    options: CompileOptions,
) -> LoweredKernel:
    """Lower a fused multi-stage launch (paper Fig. 4 single pipeline).

    All stages trace into ONE jitted executable. Stage boundaries keep
    launch semantics: each stage's updates (including scattered reduces)
    are committed into the running state before the next stage traces, so
    results are identical to launching the stages separately — minus the
    per-launch dispatch/transfer overhead."""
    stages = list(pipeline.stages)

    def run_full(state, scalars):
        cur = dict(state)
        out: Dict[str, jnp.ndarray] = {}
        for stage in stages:
            upd = _exec_kernel_full(module, stage, options, gb, cur, scalars)
            cur.update(upd)
            out.update(upd)
        return out

    return LoweredKernel(
        pipeline.name, mir.KernelKind.PIPELINE, run_full=jax.jit(run_full),
        trace_full=run_full,
    )


def lower_kernel_batched(lowered: LoweredKernel) -> Callable:
    """Batch-axis lowering: vectorize a lowered kernel over a query axis.

    The full-stream executable already maps ``(state, scalars) -> updates``
    for one query; ``vmap`` lifts every state array to ``[K, n]`` and every
    scalar to ``[K]``, sharing the graph bindings (CSR/CSC/order arrays are
    closed over, so the graph is traversed ONCE per launch for all K lanes).
    vmap semantics guarantee per-lane results bit-identical to K sequential
    launches, which is what makes Session.run_many's batched rerouting a
    pure optimization.

    Library-backed (AOT) kernels supply ``trace_full`` — an un-jitted twin
    of ``run_full`` — because a compiled executable cannot be traced.
    """
    fn = lowered.trace_full if lowered.trace_full is not None else lowered.run_full
    return jax.jit(jax.vmap(fn))


def lower_kernel(
    module: mir.Module,
    kernel: mir.Kernel,
    gb: Dict[str, Any],
    options: CompileOptions,
) -> LoweredKernel:
    if isinstance(kernel, mir.PipelineKernel):
        return lower_pipeline(module, kernel, gb, options)

    if kernel.kind is mir.KernelKind.EDGE:

        def run_full(state, scalars):
            return _exec_kernel_full(module, kernel, options, gb, state, scalars)

        def run_subset(state, scalars, batch):
            src, dst, w, eid, valid = batch
            # subsets are unsorted: disable the static shuffle permutation
            sub_gb = dict(gb, dst_sort_perm=None)
            ex = KernelExec(module, kernel, options, state, scalars, sub_gb)
            bindings = {kernel.src_param: src, kernel.dst_param: dst, "edge": eid}
            if kernel.weight_param is not None:
                bindings[kernel.weight_param] = w
            lane = LaneCtx(n_lanes=src.shape[0], bindings=bindings, valid=valid)
            ex.exec_block(kernel.func.body, lane, None)
            out = ex.commit()
            if WEIGHT_KEY in out:
                prev = state[WEIGHT_KEY]
                vals = jnp.where(valid, out[WEIGHT_KEY], prev[eid])
                out[WEIGHT_KEY] = prev.at[eid].set(vals)
            return out

        return LoweredKernel(
            kernel.name, kernel.kind,
            run_full=jax.jit(run_full),
            run_subset=jax.jit(run_subset),
            frontier=kernel.frontier,
            trace_full=run_full,
        )

    # vertex kernel
    def run_full(state, scalars):
        return _exec_kernel_full(module, kernel, options, gb, state, scalars)

    def run_subset(state, scalars, batch):
        vids, valid = batch
        ex = KernelExec(module, kernel, options, state, scalars, gb)
        lane = LaneCtx(n_lanes=vids.shape[0], bindings={kernel.vertex_param: vids}, valid=valid)
        ex.exec_block(kernel.func.body, lane, None)
        return ex.commit()

    return LoweredKernel(
        kernel.name, kernel.kind,
        run_full=jax.jit(run_full),
        run_subset=jax.jit(run_subset) if not kernel.has_neighbor_loop else None,
        frontier=kernel.frontier,
        trace_full=run_full,
    )


# ---------------------------------------------------------------------------
# Shape-generic (AOT) kernel lowering — the Accelerator artifact's back-end
# ---------------------------------------------------------------------------


@dataclass
class GenericLoweredKernel:
    """A kernel lowered against a (target, shape bucket), graph-independent.

    Unlike :class:`LoweredKernel`, the graph-binding arrays are traced
    *arguments* rather than closed-over constants: every array the Burst
    Read plan produces has a shape fully determined by (|V|, |E|), so one
    executable serves every graph of the bucket — the software analogue of
    rebinding a synthesized bitstream to a new graph. ``compiled_full`` is
    the AOT executable (``jax.jit(...).lower(specs).compile()``) when the
    accelerator has been lowered; ``jit_full`` is the shared lazily-traced
    fallback (also what compacted-subset and batched paths reuse across
    binds, so shape-bucket rebinds never recompile).
    """

    name: str
    kind: mir.KernelKind
    raw_full: Callable  # traceable: (gb_arrays, state, scalars) -> updates
    jit_full: Callable  # jax.jit(raw_full)
    jit_subset: Optional[Callable] = None  # (gb_arrays, state, scalars, batch)
    frontier: Optional[mir.FrontierInfo] = None
    compiled_full: Optional[Any] = None  # AOT executable or None
    # shared batch-axis lowering (built lazily by KernelLibrary.batched_for):
    # jit(vmap(raw_full, in_axes=(None, 0, 0))) — graph bindings unbatched,
    # state/scalars over the query axis. Living here (not per engine) is
    # what lets same-bucket rebinds reuse the batched XLA traces too.
    jit_batched: Optional[Callable] = None


def lower_kernel_generic(
    module: mir.Module,
    kernel,
    n_vertices: int,
    n_edges: int,
    target,
) -> GenericLoweredKernel:
    """Lower one kernel with graph bindings as arguments (shape-generic)."""
    statics = {"n_vertices": n_vertices, "n_edges": n_edges}

    if isinstance(kernel, mir.PipelineKernel):
        stages = list(kernel.stages)

        def raw_full(gba, state, scalars):
            gb = dict(gba, **statics)
            cur = dict(state)
            out: Dict[str, jnp.ndarray] = {}
            for stage in stages:
                upd = _exec_kernel_full(module, stage, target, gb, cur, scalars)
                cur.update(upd)
                out.update(upd)
            return out

        return GenericLoweredKernel(
            kernel.name, mir.KernelKind.PIPELINE, raw_full, jax.jit(raw_full)
        )

    if kernel.kind is mir.KernelKind.EDGE:

        def raw_full(gba, state, scalars):
            return _exec_kernel_full(
                module, kernel, target, dict(gba, **statics), state, scalars
            )

        def raw_subset(gba, state, scalars, batch):
            src, dst, w, eid, valid = batch
            # subsets are unsorted: disable the static shuffle permutation
            sub_gb = dict(gba, **statics, dst_sort_perm=None)
            ex = KernelExec(module, kernel, target, state, scalars, sub_gb)
            bindings = {kernel.src_param: src, kernel.dst_param: dst, "edge": eid}
            if kernel.weight_param is not None:
                bindings[kernel.weight_param] = w
            lane = LaneCtx(n_lanes=src.shape[0], bindings=bindings, valid=valid)
            ex.exec_block(kernel.func.body, lane, None)
            out = ex.commit()
            if WEIGHT_KEY in out:
                prev = state[WEIGHT_KEY]
                vals = jnp.where(valid, out[WEIGHT_KEY], prev[eid])
                out[WEIGHT_KEY] = prev.at[eid].set(vals)
            return out

        return GenericLoweredKernel(
            kernel.name, kernel.kind, raw_full, jax.jit(raw_full),
            jit_subset=jax.jit(raw_subset), frontier=kernel.frontier,
        )

    # vertex kernel
    def raw_full(gba, state, scalars):
        return _exec_kernel_full(
            module, kernel, target, dict(gba, **statics), state, scalars
        )

    def raw_subset(gba, state, scalars, batch):
        vids, valid = batch
        ex = KernelExec(module, kernel, target, state, scalars, dict(gba, **statics))
        lane = LaneCtx(n_lanes=vids.shape[0], bindings={kernel.vertex_param: vids},
                       valid=valid)
        ex.exec_block(kernel.func.body, lane, None)
        return ex.commit()

    return GenericLoweredKernel(
        kernel.name, kernel.kind, raw_full, jax.jit(raw_full),
        jit_subset=jax.jit(raw_subset) if not kernel.has_neighbor_loop else None,
        frontier=kernel.frontier,
    )
