"""Multi-chip graph processing: the shuffle network generalized across
devices (ForeGraph-style multi-accelerator scaling, expressed in JAX).

Vertices are range-partitioned across D devices; each edge lives on its
**source owner**. One edge-centric superstep is:

1. local gather+apply: every device computes (dst, value) update tuples
   for its edge shard from its local source-property slice;
2. **all_to_all**: tuples are routed to their destination owner — this is
   exactly the paper's shuffle module, with ICI links playing the role of
   the on-chip crossbar (updates were pre-bucketed by dst owner at
   partition time, so the routing is a static all_to_all, not dynamic);
3. local conflict-free reduce (sorted segment reduction) into the local
   destination-property slice — the URAM bank analogue.

``DistGraph.push_step`` runs one superstep under ``shard_map``; it is the
distribution layer used by the multi-device graph tests and benchmarks.

:class:`DistEngine` (bottom of this module) is the full execution backend
built on top of it: it interprets the same host program as the local
:class:`~repro.core.engine.Engine`, but launches every edge kernel whose
body fits the ``src-gather -> dst-scatter-reduce`` shape as a distributed
superstep across the device mesh. Kernels outside that shape (multi-write
bodies, edge-weight mutation, neighbor loops) transparently fall back to
the local lowering, so any program that runs locally runs distributed
with identical results.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import backend, fir, mir
from .engine import Engine
from .options import CompileOptions
from .. import telemetry as tel
from ..graph.storage import GraphData


@dataclass
class DistGraph:
    """Edge buckets [D, D, Emax]: axis0 = src owner (sharded), axis1 = dst
    owner (all_to_all routing axis)."""

    n_devices: int
    n_vertices_padded: int  # multiple of D
    src_local: np.ndarray  # [D, D, Emax] source id local to src owner
    dst_local: np.ndarray  # [D, D, Emax] dest id local to dst owner
    weight: np.ndarray  # [D, D, Emax]
    valid: np.ndarray  # [D, D, Emax]
    mesh: Mesh
    axis: str

    @property
    def slice_len(self) -> int:
        return self.n_vertices_padded // self.n_devices


def partition_graph(g: GraphData, mesh: Mesh, axis: str = "data") -> DistGraph:
    d = mesh.shape[axis]
    vpad = ((g.n_vertices + d - 1) // d) * d
    sl = vpad // d
    src_owner = g.src // sl
    dst_owner = g.dst // sl
    emax = 0
    buckets = {}
    for i in range(d):
        for j in range(d):
            sel = np.flatnonzero((src_owner == i) & (dst_owner == j))
            buckets[(i, j)] = sel
            emax = max(emax, len(sel))
    emax = max(1, emax)
    shape = (d, d, emax)
    src_l = np.zeros(shape, np.int32)
    dst_l = np.zeros(shape, np.int32)
    w = np.zeros(shape, np.float32)
    valid = np.zeros(shape, bool)
    for (i, j), sel in buckets.items():
        n = len(sel)
        src_l[i, j, :n] = g.src[sel] - i * sl
        dst_l[i, j, :n] = g.dst[sel] - j * sl
        if g.weights is not None:
            w[i, j, :n] = g.weights[sel]
        valid[i, j, :n] = True
    return DistGraph(d, vpad, src_l, dst_l, w, valid, mesh, axis)


def _identity(op: str, dtype):
    if op == "+":
        return jnp.zeros((), dtype)
    if op == "min":
        return jnp.asarray(
            jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer) else jnp.inf, dtype
        )
    return jnp.asarray(
        jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer) else -jnp.inf, dtype
    )


def make_push_step(
    dg: DistGraph,
    value_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
    reduce_op: str = "+",
    combine: bool = True,
):
    """Build the jitted superstep.

    value_fn(src_prop_vals, weights) -> update values (elementwise).
    Returns fn(prop [Vpad]) -> reduced updates [Vpad] (combined with the
    old property by the caller's vertex kernel).
    """
    mesh, axis, sl = dg.mesh, dg.axis, dg.slice_len
    src_l = jnp.asarray(dg.src_local)
    dst_l = jnp.asarray(dg.dst_local)
    w = jnp.asarray(dg.weight)
    valid = jnp.asarray(dg.valid)
    pspec = P(axis)

    def local_step(prop_slice, src_b, dst_b, w_b, valid_b):
        # [1, D, Emax] shards (leading src-owner axis sharded away)
        src_b, dst_b, w_b, valid_b = (
            src_b[0], dst_b[0], w_b[0], valid_b[0])
        prop = prop_slice.reshape(-1)  # [sl]
        vals = value_fn(prop[src_b], w_b)  # [D, Emax]
        ident = _identity(reduce_op, vals.dtype)
        vals = jnp.where(valid_b, vals, ident)
        # shuffle across chips: route each dst-owner bucket to its device
        vals_r = jax.lax.all_to_all(vals[None], axis, 1, 0, tiled=False)[:, 0]
        dst_r = jax.lax.all_to_all(dst_b[None], axis, 1, 0, tiled=False)[:, 0]
        valid_r = jax.lax.all_to_all(valid_b[None], axis, 1, 0, tiled=False)[:, 0]
        # local conflict-free reduce (sorted segment reduction)
        flat_v = jnp.where(valid_r, vals_r, ident).reshape(-1)
        flat_d = jnp.where(valid_r, dst_r, sl).reshape(-1)
        order = jnp.argsort(flat_d)
        seg = {
            "+": jax.ops.segment_sum,
            "min": jax.ops.segment_min,
            "max": jax.ops.segment_max,
        }[reduce_op]
        red = seg(flat_v[order], flat_d[order], sl + 1, indices_are_sorted=True)[:sl]
        return red[None]

    smapped = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(pspec, pspec, pspec, pspec, pspec),
        out_specs=pspec,
        check_rep=False,
    )

    @jax.jit
    def step(prop: jnp.ndarray) -> jnp.ndarray:
        grid = prop.reshape(dg.n_devices, sl)
        red = smapped(grid, src_l, dst_l, w, valid)
        return red.reshape(-1)

    return step


# ---------------------------------------------------------------------------
# Generalized distributed edge-kernel superstep
# ---------------------------------------------------------------------------


class _NotDistributable(Exception):
    """Kernel body falls outside the src-gather -> dst-reduce shape."""


def _lower_dist_expr(
    module: mir.Module,
    kern: mir.Kernel,
    e: fir.Expr,
    src_props: Set[str],
    weight_ok: bool,
) -> Callable:
    """Lower a per-edge expression to ``fn(env, w, scalars) -> array``.

    ``env`` maps property name -> values gathered at the edge's source,
    ``w`` is the per-edge weight, ``scalars`` the host scalar environment.
    Anything needing dst-side gathers, accumulator cells, or id
    translation raises :class:`_NotDistributable` (local fallback).
    """
    if isinstance(e, fir.IntLit):
        v = jnp.int32(e.value)
        return lambda env, w, s: v
    if isinstance(e, fir.FloatLit):
        v = jnp.float32(e.value)
        return lambda env, w, s: v
    if isinstance(e, fir.BoolLit):
        v = jnp.bool_(e.value)
        return lambda env, w, s: v
    if isinstance(e, fir.Ident):
        name = e.name
        if name == kern.weight_param:
            if not weight_ok:
                raise _NotDistributable("edge weights are mutated elsewhere")
            return lambda env, w, s: w
        if name in module.scalars:
            return lambda env, w, s: s[name]
        raise _NotDistributable(f"identifier {name!r}")
    if isinstance(e, fir.Index):
        base, idx = e.base, e.index
        if (
            isinstance(base, fir.Ident)
            and base.name in module.properties
            and isinstance(idx, fir.Ident)
            and idx.name == kern.src_param
            and not module.properties[base.name].is_edge
        ):
            prop = base.name
            src_props.add(prop)
            return lambda env, w, s: env[prop]
        raise _NotDistributable("non-src-indexed property read")
    if isinstance(e, fir.BinOp):
        fa = _lower_dist_expr(module, kern, e.lhs, src_props, weight_ok)
        fb = _lower_dist_expr(module, kern, e.rhs, src_props, weight_ok)
        op = e.op
        return lambda env, w, s: backend._binop(op, fa(env, w, s), fb(env, w, s))
    if isinstance(e, fir.UnaryOp):
        fv = _lower_dist_expr(module, kern, e.operand, src_props, weight_ok)
        if e.op == "!":
            return lambda env, w, s: jnp.logical_not(fv(env, w, s))
        return lambda env, w, s: -fv(env, w, s)
    if isinstance(e, fir.Call):
        if e.func == "original_id":
            raise _NotDistributable("original_id needs the relabel table")
        fargs = [
            _lower_dist_expr(module, kern, a, src_props, weight_ok) for a in e.args
        ]
        func = e.func
        return lambda env, w, s: backend._builtin(func, [f(env, w, s) for f in fargs])
    raise _NotDistributable(type(e).__name__)


def _match_dist_kernel(kern: mir.Kernel) -> Tuple[Optional[fir.Expr], str, str, fir.Expr]:
    """Match ``[if cond] prop[dst] op= value`` and return its pieces."""
    body = list(kern.func.body)
    cond: Optional[fir.Expr] = None
    if (
        len(body) == 1
        and isinstance(body[0], fir.If)
        and not body[0].else_body
        and len(body[0].then_body) == 1
    ):
        cond = body[0].cond
        st = body[0].then_body[0]
    elif len(body) == 1:
        st = body[0]
    else:
        raise _NotDistributable("multi-statement body")
    if not isinstance(st, fir.ReduceAssign) or st.op not in ("+", "min", "max"):
        raise _NotDistributable("not a +/min/max reduction")
    tgt = st.target
    if not (
        isinstance(tgt, fir.Index)
        and isinstance(tgt.base, fir.Ident)
        and isinstance(tgt.index, fir.Ident)
        and tgt.index.name == kern.dst_param
    ):
        raise _NotDistributable("write is not prop[dst]")
    return cond, tgt.base.name, st.op, st.value


def make_expr_push_step(
    dg: DistGraph,
    src_props: List[str],
    val_fn: Callable,
    cond_fn: Optional[Callable],
    reduce_op: str,
    out_dtype,
):
    """Build a jitted distributed superstep for one lowered edge kernel.

    Like :func:`make_push_step`, but the per-edge value/condition read an
    arbitrary set of src-gathered properties plus host scalars:

        step(props: {name: [Vpad]}, scalars: {name: 0-d}) -> reduced [Vpad]

    The returned array combines with the destination property via the
    kernel's reduce op (identity-filled where no edge contributed).
    """
    mesh, axis, sl = dg.mesh, dg.axis, dg.slice_len
    d = dg.n_devices
    vpad = dg.n_vertices_padded
    src_l = jnp.asarray(dg.src_local)
    dst_l = jnp.asarray(dg.dst_local)
    w = jnp.asarray(dg.weight)
    valid = jnp.asarray(dg.valid)
    pspec = P(axis)
    seg = {
        "+": jax.ops.segment_sum,
        "min": jax.ops.segment_min,
        "max": jax.ops.segment_max,
    }[reduce_op]
    ident = _identity(reduce_op, out_dtype)

    def local_step(prop_slices, scalars, src_b, dst_b, w_b, valid_b):
        # [1, D, Emax] shards (leading src-owner axis sharded away)
        src_b, dst_b, w_b, valid_b = src_b[0], dst_b[0], w_b[0], valid_b[0]
        env = {n: ps.reshape(-1)[src_b] for n, ps in prop_slices.items()}
        vals = val_fn(env, w_b, scalars).astype(out_dtype)
        ok = valid_b
        if cond_fn is not None:
            ok = jnp.logical_and(ok, cond_fn(env, w_b, scalars).astype(jnp.bool_))
        vals = jnp.where(ok, vals, ident)
        # shuffle across chips: route each dst-owner bucket to its device
        vals_r = jax.lax.all_to_all(vals[None], axis, 1, 0, tiled=False)[:, 0]
        dst_r = jax.lax.all_to_all(dst_b[None], axis, 1, 0, tiled=False)[:, 0]
        ok_r = jax.lax.all_to_all(ok[None], axis, 1, 0, tiled=False)[:, 0]
        # local conflict-free reduce (sorted segment reduction)
        flat_v = jnp.where(ok_r, vals_r, ident).reshape(-1)
        flat_d = jnp.where(ok_r, dst_r, sl).reshape(-1)
        order = jnp.argsort(flat_d)
        red = seg(flat_v[order], flat_d[order], sl + 1, indices_are_sorted=True)[:sl]
        return red[None]

    smapped = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(pspec, P(), pspec, pspec, pspec, pspec),
        out_specs=pspec,
        check_rep=False,
    )

    @jax.jit
    def step(props: Dict[str, jnp.ndarray], scalars: Dict[str, jnp.ndarray]):
        grids = {}
        for n in src_props:
            arr = props[n]
            padded = jnp.zeros((vpad,), arr.dtype).at[: arr.shape[0]].set(arr)
            grids[n] = padded.reshape(d, sl)
        red = smapped(grids, scalars, src_l, dst_l, w, valid)
        return red.reshape(-1)

    return step


class DistEngine(Engine):
    """Multi-device engine: the shared host interpreter of :class:`Engine`
    plus distributed supersteps for scatter-reduce edge kernels.

    Construction partitions the graph across ``mesh`` lazily (on the first
    distributable edge-kernel launch). Kernels that read edge weights are
    only distributed when no kernel in the module mutates weights (the
    partitioned weight buckets are built once at partition time).
    """

    def __init__(
        self,
        module: mir.Module,
        graph: GraphData,
        options: Optional[CompileOptions] = None,
        argv: Optional[List[str]] = None,
        mesh: Optional[Mesh] = None,
        axis: str = "data",
        *,
        target=None,
        library=None,
    ):
        super().__init__(module, graph, options, argv=argv, target=target,
                         library=library)
        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(),), (axis,))
        self.mesh = mesh
        self.axis = axis
        self._dist_graph: Optional[DistGraph] = None
        self._dist_lowered: Dict[str, Optional[tuple]] = {}
        self._weights_static = not any(
            k.writes_weight for k in module.kernels.values()
        )

    def refresh_graph(self, graph: Optional[GraphData] = None):
        super().refresh_graph(graph)
        # superstep closures captured the partitioned (sharded) graph:
        # re-partition lazily on the next distributable launch
        self._dist_graph = None
        self._dist_lowered.clear()

    # -- lazy partition -----------------------------------------------------
    def _partitioned(self) -> DistGraph:
        if self._dist_graph is None:
            self._dist_graph = partition_graph(self.graph, self.mesh, self.axis)
        return self._dist_graph

    # -- per-kernel distributed lowering ------------------------------------
    def _dist_kernel(self, name: str) -> Optional[tuple]:
        if name in self._dist_lowered:
            return self._dist_lowered[name]
        kern = self.module.kernels[name]
        entry = None
        try:
            cond, out_prop, op, value = _match_dist_kernel(kern)
            src_props: Set[str] = set()
            val_fn = _lower_dist_expr(
                self.module, kern, value, src_props, self._weights_static
            )
            cond_fn = (
                _lower_dist_expr(self.module, kern, cond, src_props,
                                 self._weights_static)
                if cond is not None
                else None
            )
            out_dtype = self.state[out_prop].dtype
            step = make_expr_push_step(
                self._partitioned(), sorted(src_props), val_fn, cond_fn, op, out_dtype
            )
            entry = (step, out_prop, op, sorted(src_props))
        except _NotDistributable:
            entry = None
        self._dist_lowered[name] = entry
        return entry

    # -- superstep execution -------------------------------------------------
    def _dist_exec(self, name: str, entry: tuple):
        """Run one distributed superstep for an already-lowered edge kernel."""
        step, out_prop, op, src_props = entry
        scalars = self._kernel_scalars(name)
        props = {p: self.state[p] for p in src_props}
        tr = tel.get()
        sp = tel.NULL_SPAN
        if tr.enabled:
            # shuffle volume: D x D dst-owner buckets of Emax slots each —
            # the all_to_all element count this superstep routes over ICI
            d0, d1, emax = self._partitioned().src_local.shape
            sp = tr.span(
                "superstep", kernel=name, devices=int(d0),
                shuffle_elements=int(d0 * d1 * emax),
                edges=self.graph.n_edges,
            )
        with sp:
            red = self._timed_call(("dist", name), step, props, scalars)[
                : self.graph.n_vertices
            ]
        cur = self.state[out_prop]
        self.state[out_prop] = backend.combine(op, cur, red.astype(cur.dtype))
        self.stats.dist_supersteps += 1
        self.stats.edges_traversed += self.graph.n_edges

    # -- per-launch batching hook (repro.batch) ------------------------------
    def batched_runner(self, name: str):
        """Batch-axis lowering of the distributed launch strategy.

        Edge kernels that run as shuffle supersteps sequentially keep doing
        so batched: the jitted shard_map step is vmapped over the query
        axis, so one all_to_all round serves all K queries (the batch axis
        rides along unsharded; per-lane reduction order is unchanged, hence
        results stay bit-identical to sequential distributed runs). Fused
        pipelines are consumed stage-wise exactly like the sequential
        ``launch`` override; everything else falls back to the local
        vmapped lowering via ``super()``.
        """
        from .engine import BatchedLaunch

        bl = self._batched.get(name)
        if bl is not None:
            return bl
        kern = self.module.kernels.get(name)
        if isinstance(kern, mir.PipelineKernel):
            entries = {s.name: self._dist_kernel(s.name) for s in kern.edge_stages}
            if any(e is not None for e in entries.values()):
                bl = self._batched[name] = self._batched_pipeline(kern, entries)
                return bl
        elif kern is not None and kern.kind is mir.KernelKind.EDGE:
            entry = self._dist_kernel(name)
            if entry is not None:
                step_fn = self._batched_superstep(entry)
                n_edges = self.graph.n_edges

                def bump(stats):
                    stats.dist_supersteps += 1
                    stats.edges_traversed += n_edges

                bl = self._batched[name] = BatchedLaunch(
                    fn=jax.jit(step_fn), bump_stats=bump
                )
                return bl
        return super().batched_runner(name)

    def _batched_superstep(self, entry: tuple):
        """fn(state, scalars) -> {out_prop: combined} over a leading K axis."""
        step, out_prop, op, src_props = entry
        vstep = jax.vmap(step)
        n_v = self.graph.n_vertices

        def run(state, scalars):
            red = vstep({p: state[p] for p in src_props}, scalars)[:, :n_v]
            cur = state[out_prop]
            return {out_prop: backend.combine(op, cur, red.astype(cur.dtype))}

        return run

    def _batched_pipeline(self, kern: mir.PipelineKernel, entries: Dict[str, Optional[tuple]]):
        """Stage-wise batched pipeline: dist-able edge stages run as vmapped
        supersteps, the rest as vmapped local traces, all inside ONE jit
        with stage-boundary commits (mirrors the sequential stage-wise
        consumption, so results and superstep accounting line up)."""
        from .engine import BatchedLaunch

        stage_fns = []
        n_dist = 0
        n_local_edges = 0
        for stage in kern.stages:
            entry = entries.get(stage.name)
            if entry is not None:
                stage_fns.append(self._batched_superstep(entry))
                n_dist += 1
            else:
                module, options, gb = self.module, self.options, self.gb
                stage_fns.append(jax.vmap(
                    lambda s, sc, stage=stage: backend._exec_kernel_full(
                        module, stage, options, gb, s, sc)
                ))
                if stage.kind is mir.KernelKind.EDGE:
                    n_local_edges += 1

        def run(state, scalars):
            cur = dict(state)
            out = {}
            for fn in stage_fns:
                upd = fn(cur, scalars)
                cur.update(upd)
                out.update(upd)
            return out

        n_edges = self.graph.n_edges

        def bump(stats):
            stats.dist_supersteps += n_dist
            stats.full_launches += len(stage_fns) - n_dist
            stats.edges_traversed += n_edges * (n_dist + n_local_edges)

        return BatchedLaunch(fn=jax.jit(run), bump_stats=bump)

    # -- launch override -----------------------------------------------------
    def launch(self, name: str):
        kern = self.module.kernels.get(name)
        if isinstance(kern, mir.PipelineKernel):
            # consume a fused pipeline stage-by-stage whenever an edge stage
            # can run as a distributed superstep (stage kernels keep their
            # own entries in module.kernels, so per-stage lowering caches
            # under the original names); otherwise fall through to the
            # single-jit local pipeline lowering
            entries = {s.name: self._dist_kernel(s.name) for s in kern.edge_stages}
            if any(e is not None for e in entries.values()):
                self._count_launch(name, kern)
                tr = tel.get()
                sp = tr.span("launch:" + name, kernel=name, kind="pipeline",
                             mode="dist") if tr.enabled else tel.NULL_SPAN
                with sp:
                    for stage in kern.stages:
                        entry = entries.get(stage.name)
                        if entry is not None:
                            self._dist_exec(stage.name, entry)
                        else:
                            self._execute_kernel(stage.name, stage)
                return
        elif kern is not None and kern.kind is mir.KernelKind.EDGE:
            entry = self._dist_kernel(name)
            if entry is not None:
                self._count_launch(name, kern)
                tr = tel.get()
                sp = tr.span("launch:" + name, kernel=name, kind="edge",
                             mode="dist") if tr.enabled else tel.NULL_SPAN
                with sp:
                    self._dist_exec(name, entry)
                return
        super().launch(name)
