"""Multi-chip graph processing: the shuffle network generalized across
devices (ForeGraph-style multi-accelerator scaling, expressed in JAX).

Vertices are range-partitioned across D devices; each edge lives on its
**source owner**. One edge-centric superstep is:

1. local gather+apply: every device computes (dst, value) update tuples
   for its edge shard from its local source-property slice;
2. **all_to_all**: tuples are routed to their destination owner — this is
   exactly the paper's shuffle module, with ICI links playing the role of
   the on-chip crossbar (updates were pre-bucketed by dst owner at
   partition time, so the routing is a static all_to_all, not dynamic);
3. local conflict-free reduce (sorted segment reduction) into the local
   destination-property slice — the URAM bank analogue.

``DistGraph.push_step`` runs one superstep under ``shard_map``; it is the
distribution layer used by the multi-device graph tests and benchmarks.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..graph.storage import GraphData


@dataclass
class DistGraph:
    """Edge buckets [D, D, Emax]: axis0 = src owner (sharded), axis1 = dst
    owner (all_to_all routing axis)."""

    n_devices: int
    n_vertices_padded: int  # multiple of D
    src_local: np.ndarray  # [D, D, Emax] source id local to src owner
    dst_local: np.ndarray  # [D, D, Emax] dest id local to dst owner
    weight: np.ndarray  # [D, D, Emax]
    valid: np.ndarray  # [D, D, Emax]
    mesh: Mesh
    axis: str

    @property
    def slice_len(self) -> int:
        return self.n_vertices_padded // self.n_devices


def partition_graph(g: GraphData, mesh: Mesh, axis: str = "data") -> DistGraph:
    d = mesh.shape[axis]
    vpad = ((g.n_vertices + d - 1) // d) * d
    sl = vpad // d
    src_owner = g.src // sl
    dst_owner = g.dst // sl
    emax = 0
    buckets = {}
    for i in range(d):
        for j in range(d):
            sel = np.flatnonzero((src_owner == i) & (dst_owner == j))
            buckets[(i, j)] = sel
            emax = max(emax, len(sel))
    emax = max(1, emax)
    shape = (d, d, emax)
    src_l = np.zeros(shape, np.int32)
    dst_l = np.zeros(shape, np.int32)
    w = np.zeros(shape, np.float32)
    valid = np.zeros(shape, bool)
    for (i, j), sel in buckets.items():
        n = len(sel)
        src_l[i, j, :n] = g.src[sel] - i * sl
        dst_l[i, j, :n] = g.dst[sel] - j * sl
        if g.weights is not None:
            w[i, j, :n] = g.weights[sel]
        valid[i, j, :n] = True
    return DistGraph(d, vpad, src_l, dst_l, w, valid, mesh, axis)


def _identity(op: str, dtype):
    if op == "+":
        return jnp.zeros((), dtype)
    if op == "min":
        return jnp.asarray(
            jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer) else jnp.inf, dtype
        )
    return jnp.asarray(
        jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer) else -jnp.inf, dtype
    )


def make_push_step(
    dg: DistGraph,
    value_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
    reduce_op: str = "+",
    combine: bool = True,
):
    """Build the jitted superstep.

    value_fn(src_prop_vals, weights) -> update values (elementwise).
    Returns fn(prop [Vpad]) -> reduced updates [Vpad] (combined with the
    old property by the caller's vertex kernel).
    """
    mesh, axis, sl = dg.mesh, dg.axis, dg.slice_len
    src_l = jnp.asarray(dg.src_local)
    dst_l = jnp.asarray(dg.dst_local)
    w = jnp.asarray(dg.weight)
    valid = jnp.asarray(dg.valid)
    pspec = P(axis)

    def local_step(prop_slice, src_b, dst_b, w_b, valid_b):
        # [1, D, Emax] shards (leading src-owner axis sharded away)
        src_b, dst_b, w_b, valid_b = (
            src_b[0], dst_b[0], w_b[0], valid_b[0])
        prop = prop_slice.reshape(-1)  # [sl]
        vals = value_fn(prop[src_b], w_b)  # [D, Emax]
        ident = _identity(reduce_op, vals.dtype)
        vals = jnp.where(valid_b, vals, ident)
        # shuffle across chips: route each dst-owner bucket to its device
        vals_r = jax.lax.all_to_all(vals[None], axis, 1, 0, tiled=False)[:, 0]
        dst_r = jax.lax.all_to_all(dst_b[None], axis, 1, 0, tiled=False)[:, 0]
        valid_r = jax.lax.all_to_all(valid_b[None], axis, 1, 0, tiled=False)[:, 0]
        # local conflict-free reduce (sorted segment reduction)
        flat_v = jnp.where(valid_r, vals_r, ident).reshape(-1)
        flat_d = jnp.where(valid_r, dst_r, sl).reshape(-1)
        order = jnp.argsort(flat_d)
        seg = {
            "+": jax.ops.segment_sum,
            "min": jax.ops.segment_min,
            "max": jax.ops.segment_max,
        }[reduce_op]
        red = seg(flat_v[order], flat_d[order], sl + 1, indices_are_sorted=True)[:sl]
        return red[None]

    smapped = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(pspec, pspec, pspec, pspec, pspec),
        out_specs=pspec,
        check_rep=False,
    )

    @jax.jit
    def step(prop: jnp.ndarray) -> jnp.ndarray:
        grid = prop.reshape(dg.n_devices, sl)
        red = smapped(grid, src_l, dst_l, w, valid)
        return red.reshape(-1)

    return step
