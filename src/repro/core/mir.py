"""Middle-end IR (MIR) for the Graphitron compiler.

The middle-end traverses the FIR from a global perspective (paper §III-B2)
and produces:

* a symbol table: graphs, properties (``vector{V}(T)``), host scalars;
* one :class:`Kernel` per device function with the *Property Detector*
  results: which properties are read/written, through which index pattern,
  with which reduction, plus RAW-decoupling and frontier annotations;
* a :class:`HostProgram` for ``main()`` and any host helper functions;
* a :class:`MemoryPlan` assigning every property to a device buffer with a
  dtype and length class (|V| or |E|) — the FPGA memory-channel planning
  re-targeted at HBM buffers.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from . import fir


class KernelKind(enum.Enum):
    VERTEX = "vertex"  # func f(v: Vertex)
    EDGE = "edge"  # func f(src: Vertex, dst: Vertex[, w: int|float])
    HOST = "host"  # zero-parameter functions (incl. main)


class IndexPattern(enum.Enum):
    """How a property access is indexed inside a kernel (Property Detector)."""

    SELF = "self"  # P[v] in a vertex kernel — sequential (burst) access
    SRC = "src"  # P[src] in an edge kernel — gather along source
    DST = "dst"  # P[dst] in an edge kernel — scatter along destination
    NEIGHBOR = "ngh"  # P[ngh] inside a neighbor loop — gather/scatter via CSR
    CONST = "const"  # P[0] — a global accumulator cell
    OTHER = "other"  # anything else (computed index)


@dataclass(frozen=True)
class PropAccess:
    prop: str
    pattern: IndexPattern
    reduce_op: Optional[str] = None  # None for plain assign / read


@dataclass
class PropertyInfo:
    name: str
    element: str  # 'Vertex' | 'Edge' element name
    scalar: str  # 'int' | 'float' | 'bool'
    is_edge: bool = False


@dataclass
class ScalarInfo:
    name: str
    scalar: str
    init: Optional[fir.Expr] = None


@dataclass
class GraphInfo:
    edgeset_name: str
    vertexset_name: Optional[str]
    weighted: bool
    weight_scalar: Optional[str]  # 'int' | 'float'
    load_args: List[fir.Expr] = field(default_factory=list)


@dataclass
class FrontierInfo:
    """A top-level guard ``if cond`` whose cond only reads props at the
    kernel's primary index — the paper's *Frontier Check* module."""

    cond: fir.Expr
    props: Set[str] = field(default_factory=set)


@dataclass
class Kernel:
    name: str
    kind: KernelKind
    func: fir.FuncDecl
    # parameter roles
    vertex_param: Optional[str] = None  # vertex kernels
    src_param: Optional[str] = None  # edge kernels
    dst_param: Optional[str] = None
    weight_param: Optional[str] = None
    # Property Detector results
    reads: List[PropAccess] = field(default_factory=list)
    writes: List[PropAccess] = field(default_factory=list)
    scalar_reads: Set[str] = field(default_factory=set)
    # transforms / annotations
    snapshot_props: Set[str] = field(default_factory=set)  # RAW decoupling (Fig. 5->6)
    frontier: Optional[FrontierInfo] = None
    has_neighbor_loop: bool = False
    writes_weight: bool = False
    accumulators: Set[str] = field(default_factory=set)  # props written at const index

    @property
    def scatter_props(self) -> Set[str]:
        """Properties written through a scattered index (shuffle path)."""
        return {
            w.prop
            for w in self.writes
            if w.pattern in (IndexPattern.DST, IndexPattern.NEIGHBOR, IndexPattern.OTHER)
        }

    @property
    def sequential_props(self) -> Set[str]:
        """Properties written at the kernel's own lane (burst-write path)."""
        return {
            w.prop
            for w in self.writes
            if w.pattern in (IndexPattern.SELF, IndexPattern.SRC)
        }


@dataclass
class MemoryPlan:
    """Device buffer plan: property -> (length class, dtype, channel id).

    The FPGA version assigns HBM pseudo-channels; here the channel id is
    informational (used by the textual codegen dump and by tests asserting
    the Property Detector found everything).
    """

    buffers: Dict[str, Tuple[str, str, int]] = field(default_factory=dict)

    def add(self, prop: PropertyInfo):
        length = "E" if prop.is_edge else "V"
        self.buffers[prop.name] = (length, prop.scalar, len(self.buffers))


@dataclass
class HostProgram:
    main: fir.FuncDecl
    host_funcs: Dict[str, fir.FuncDecl] = field(default_factory=dict)


@dataclass
class Module:
    """The complete MIR context handed to the back-end."""

    program: fir.Program
    graph: GraphInfo
    properties: Dict[str, PropertyInfo] = field(default_factory=dict)
    scalars: Dict[str, ScalarInfo] = field(default_factory=dict)
    kernels: Dict[str, Kernel] = field(default_factory=dict)
    host: Optional[HostProgram] = None
    memory: MemoryPlan = field(default_factory=MemoryPlan)
    # degree vectors requested via edges.getOutDegrees()/getInDegrees()
    degree_props: Dict[str, str] = field(default_factory=dict)  # prop -> 'out'|'in'

    def describe(self) -> str:
        """Textual MIR dump — the analogue of the generated-OpenCL listing."""
        lines = [f"graph {self.graph.edgeset_name} (weighted={self.graph.weighted})"]
        for p in self.properties.values():
            ln, dt, ch = self.memory.buffers[p.name]
            lines.append(f"  buffer {p.name}: {dt}[{ln}] @channel{ch}")
        for s in self.scalars.values():
            lines.append(f"  host scalar {s.name}: {s.scalar}")
        for k in self.kernels.values():
            lines.append(f"  kernel {k.name} [{k.kind.value}]")
            for r in k.reads:
                lines.append(f"    read  {r.prop}[{r.pattern.value}]")
            for w in k.writes:
                op = f" {w.reduce_op}=" if w.reduce_op else " ="
                lines.append(f"    write {w.prop}[{w.pattern.value}]{op}")
            if k.snapshot_props:
                lines.append(f"    decouple(RAW): snapshot {sorted(k.snapshot_props)}")
            if k.frontier is not None:
                lines.append(f"    frontier-check on {sorted(k.frontier.props)}")
            if k.accumulators:
                lines.append(f"    accumulators {sorted(k.accumulators)}")
        return "\n".join(lines)
