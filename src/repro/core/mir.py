"""Middle-end IR (MIR) for the Graphitron compiler.

The middle-end traverses the FIR from a global perspective (paper §III-B2)
and produces:

* a symbol table: graphs, properties (``vector{V}(T)``), host scalars;
* one :class:`Kernel` per device function with the *Property Detector*
  results: which properties are read/written, through which index pattern,
  with which reduction, plus RAW-decoupling and frontier annotations;
* a :class:`HostProgram` for ``main()`` and any host helper functions;
* a :class:`MemoryPlan` assigning every property to a device buffer with a
  dtype and length class (|V| or |E|) — the FPGA memory-channel planning
  re-targeted at HBM buffers.
"""
from __future__ import annotations

import enum
import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from . import fir


class KernelKind(enum.Enum):
    VERTEX = "vertex"  # func f(v: Vertex)
    EDGE = "edge"  # func f(src: Vertex, dst: Vertex[, w: int|float])
    HOST = "host"  # zero-parameter functions (incl. main)
    PIPELINE = "pipeline"  # fused multi-stage launch (created by passes.py)


class Direction(enum.Enum):
    """Compile-time traversal-direction decision for an edge kernel.

    The paper's direction optimization (Fig. 2) is a runtime heuristic in
    the engine; the ``direction`` pass replaces it with a per-kernel
    compile-time verdict derived from frontier information:

    * ``DENSE``  — the frontier condition is loop-invariant (e.g. the
      ``deg[src] > 0`` guard of PageRank) or absent: always stream the full
      edge list, never evaluate a host-side frontier mask.
    * ``SPARSE`` — the frontier props are mutated between launches (a real
      shrinking/growing frontier, e.g. BFS levels): always attempt frontier
      compaction, with the edge-count threshold kept as the switch-back.
    * ``AUTO``   — no pass ran; the engine keeps its runtime-only fallback.
    """

    AUTO = "auto"
    DENSE = "dense"
    SPARSE = "sparse"


class IndexPattern(enum.Enum):
    """How a property access is indexed inside a kernel (Property Detector)."""

    SELF = "self"  # P[v] in a vertex kernel — sequential (burst) access
    SRC = "src"  # P[src] in an edge kernel — gather along source
    DST = "dst"  # P[dst] in an edge kernel — scatter along destination
    NEIGHBOR = "ngh"  # P[ngh] inside a neighbor loop — gather/scatter via CSR
    CONST = "const"  # P[0] — a global accumulator cell
    OTHER = "other"  # anything else (computed index)


@dataclass(frozen=True)
class PropAccess:
    prop: str
    pattern: IndexPattern
    reduce_op: Optional[str] = None  # None for plain assign / read


@dataclass
class PropertyInfo:
    name: str
    element: str  # 'Vertex' | 'Edge' element name
    scalar: str  # 'int' | 'float' | 'bool'
    is_edge: bool = False


@dataclass
class ScalarInfo:
    name: str
    scalar: str
    init: Optional[fir.Expr] = None


@dataclass
class GraphInfo:
    edgeset_name: str
    vertexset_name: Optional[str]
    weighted: bool
    weight_scalar: Optional[str]  # 'int' | 'float'
    load_args: List[fir.Expr] = field(default_factory=list)


@dataclass
class FrontierInfo:
    """A top-level guard ``if cond`` whose cond only reads props at the
    kernel's primary index — the paper's *Frontier Check* module."""

    cond: fir.Expr
    props: Set[str] = field(default_factory=set)


@dataclass
class Kernel:
    name: str
    kind: KernelKind
    func: fir.FuncDecl
    # parameter roles
    vertex_param: Optional[str] = None  # vertex kernels
    src_param: Optional[str] = None  # edge kernels
    dst_param: Optional[str] = None
    weight_param: Optional[str] = None
    # Property Detector results
    reads: List[PropAccess] = field(default_factory=list)
    writes: List[PropAccess] = field(default_factory=list)
    scalar_reads: Set[str] = field(default_factory=set)
    # transforms / annotations
    snapshot_props: Set[str] = field(default_factory=set)  # RAW decoupling (Fig. 5->6)
    frontier: Optional[FrontierInfo] = None
    has_neighbor_loop: bool = False
    writes_weight: bool = False
    accumulators: Set[str] = field(default_factory=set)  # props written at const index
    # compile-time push/pull decision (assigned by the `direction` pass)
    direction: Direction = Direction.AUTO

    @property
    def scatter_props(self) -> Set[str]:
        """Properties written through a scattered index (shuffle path)."""
        return {
            w.prop
            for w in self.writes
            if w.pattern in (IndexPattern.DST, IndexPattern.NEIGHBOR, IndexPattern.OTHER)
        }

    @property
    def sequential_props(self) -> Set[str]:
        """Properties written at the kernel's own lane (burst-write path)."""
        return {
            w.prop
            for w in self.writes
            if w.pattern in (IndexPattern.SELF, IndexPattern.SRC)
        }


@dataclass
class PipelineKernel:
    """A fused multi-stage launch: the paper's Fig. 4 single pipeline.

    Created by the ``fuse`` pass when an edge kernel and the vertex apply
    over its scatter target (or adjacent vertex kernels that cannot be
    body-merged) are launched back to back with no intervening host
    dependency. The back-end lowers all stages into ONE jitted executable;
    each stage's scattered writes commit before the next stage runs, so
    the result is bit-identical to the unfused launch sequence.

    Stage kernels keep their own entries in ``Module.kernels`` (the host
    program may still launch them individually elsewhere).
    """

    name: str
    stages: List[Kernel] = field(default_factory=list)
    kind: KernelKind = KernelKind.PIPELINE

    # -- aggregate views so engines can treat this like a Kernel ----------
    @property
    def scalar_reads(self) -> Set[str]:
        out: Set[str] = set()
        for s in self.stages:
            out |= s.scalar_reads
        return out

    @property
    def accumulators(self) -> Set[str]:
        out: Set[str] = set()
        for s in self.stages:
            out |= s.accumulators
        return out

    @property
    def writes_weight(self) -> bool:
        return any(s.writes_weight for s in self.stages)

    @property
    def has_neighbor_loop(self) -> bool:
        return any(s.has_neighbor_loop for s in self.stages)

    @property
    def frontier(self) -> Optional[FrontierInfo]:
        return None  # pipelines always run the full stream

    @property
    def edge_stages(self) -> List[Kernel]:
        return [s for s in self.stages if s.kind is KernelKind.EDGE]


@dataclass
class MemoryPlan:
    """Device buffer plan: property -> (length class, dtype, channel id).

    The FPGA version assigns HBM pseudo-channels; here the channel id is
    informational (used by the textual codegen dump and by tests asserting
    the Property Detector found everything).
    """

    buffers: Dict[str, Tuple[str, str, int]] = field(default_factory=dict)

    def add(self, prop: PropertyInfo):
        length = "E" if prop.is_edge else "V"
        self.buffers[prop.name] = (length, prop.scalar, len(self.buffers))


@dataclass
class HostProgram:
    main: fir.FuncDecl
    host_funcs: Dict[str, fir.FuncDecl] = field(default_factory=dict)


@dataclass
class Module:
    """The complete MIR context handed to the back-end."""

    program: fir.Program
    graph: GraphInfo
    properties: Dict[str, PropertyInfo] = field(default_factory=dict)
    scalars: Dict[str, ScalarInfo] = field(default_factory=dict)
    kernels: Dict[str, Kernel] = field(default_factory=dict)
    host: Optional[HostProgram] = None
    memory: MemoryPlan = field(default_factory=MemoryPlan)
    # degree vectors requested via edges.getOutDegrees()/getInDegrees()
    degree_props: Dict[str, str] = field(default_factory=dict)  # prop -> 'out'|'in'
    # optimization-pass bookkeeping (populated by passes.run_pipeline):
    # fused launch name -> the original kernel names it replaces, in order
    fusion_groups: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    # human-readable log of what each pass did (golden-tested via describe)
    pass_report: List[str] = field(default_factory=list)

    def describe(self) -> str:
        """Textual MIR dump — the analogue of the generated-OpenCL listing.

        When optimization passes ran (``CompileOptions.passes``), the dump
        ends with one ``pass <name>: ...`` line per transformation applied,
        so golden tests can pin exactly which kernels fused, which buffers
        were eliminated, and which direction each edge kernel was assigned.
        """
        lines = [f"graph {self.graph.edgeset_name} (weighted={self.graph.weighted})"]
        for p in self.properties.values():
            ln, dt, ch = self.memory.buffers[p.name]
            lines.append(f"  buffer {p.name}: {dt}[{ln}] @channel{ch}")
        for s in self.scalars.values():
            lines.append(f"  host scalar {s.name}: {s.scalar}")
        for k in self.kernels.values():
            if isinstance(k, PipelineKernel):
                stages = " -> ".join(s.name for s in k.stages)
                lines.append(f"  kernel {k.name} [pipeline: {stages}]")
                continue
            lines.append(f"  kernel {k.name} [{k.kind.value}]")
            for r in k.reads:
                lines.append(f"    read  {r.prop}[{r.pattern.value}]")
            for w in k.writes:
                op = f" {w.reduce_op}=" if w.reduce_op else " ="
                lines.append(f"    write {w.prop}[{w.pattern.value}]{op}")
            if k.snapshot_props:
                lines.append(f"    decouple(RAW): snapshot {sorted(k.snapshot_props)}")
            if k.frontier is not None:
                lines.append(f"    frontier-check on {sorted(k.frontier.props)}")
            if k.accumulators:
                lines.append(f"    accumulators {sorted(k.accumulators)}")
            if k.kind is KernelKind.EDGE and k.direction is not Direction.AUTO:
                lines.append(f"    direction {k.direction.value}")
        for entry in self.pass_report:
            lines.append(f"  pass {entry}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# incremental-recomputation metadata (streaming path)
# ---------------------------------------------------------------------------
# Derived lazily by repro.core.passes.analyze_incremental and consumed by
# repro.streaming — deliberately NOT part of Module.describe(), so the
# canonical serialization (and with it program fingerprints, cache
# identities and saved artifacts) is unchanged by this analysis.


@dataclass(frozen=True)
class IncrementalTemplate:
    """A recognized monotone-convergence shape with a repair recipe.

    ``kind`` selects the host-side repair driver in
    :mod:`repro.streaming.incremental`:

    * ``unit_distance`` — level/hop propagation guarded on a host round
      scalar (BFS family): ``dist + 1`` relaxations.
    * ``weighted_distance`` — active-mask guarded ``dist + weight``
      relaxations (SSSP family).
    * ``label`` — symmetric min-label propagation (connected components).
    """

    kind: str  # 'unit_distance' | 'weighted_distance' | 'label'
    dist_prop: str  # the converged result property (levels/distances/labels)
    tuple_prop: Optional[str] = None  # tentative-min buffer (distance kinds)
    mirror_props: Tuple[str, ...] = ()  # equal to dist_prop at the fixpoint
    unreached: Optional[int] = None  # sentinel literal for unreached vertices
    round_scalar: Optional[str] = None  # host scalar = max(level) + 1 at exit


@dataclass(frozen=True)
class IncrementalInfo:
    """Monotonicity verdict for a module (streaming re-convergence).

    ``monotone`` is true when every scattered vertex write (DST / NEIGHBOR
    / OTHER index pattern) carries a ``min=`` / ``max=`` reduction —
    additional edges can then only tighten the fixpoint, so re-convergence
    may be seeded from the delta endpoints alone. ``template`` is the
    matched repair recipe, or None when the program is monotone but not of
    a recognized shape (repair falls back to full recompute either way).
    """

    monotone: bool
    reduce_ops: Tuple[str, ...] = ()
    reasons: Tuple[str, ...] = ()
    template: Optional[IncrementalTemplate] = None

    @property
    def incremental_ok(self) -> bool:
        return self.monotone and self.template is not None


# ---------------------------------------------------------------------------
# canonical serialization / fingerprinting
# ---------------------------------------------------------------------------


def canonical_serialize(module: Module) -> str:
    """Canonical text form of an analyzed module, front-end independent.

    Two programs that reach the middle-end as the same MIR — whether they
    were parsed from ``.gt`` text or built by the embedded Python front-end
    (:mod:`repro.frontend`) — serialize to the same string: the symbol
    table / Property Detector dump (:meth:`Module.describe`) followed by
    the normalized FIR program (``fir.dump`` is formatting-, comment- and
    parenthesization-independent, and semantic analysis has already applied
    the RMW normalization, so surface spelling differences vanish).

    This is the string the Program cache is keyed on: see
    :func:`fingerprint` and :func:`repro.core.program.compile_program`.
    """
    return module.describe() + "\n%% fir\n" + fir.dump(module.program)


def fingerprint(module: Module) -> str:
    """Content hash of the canonical serialized MIR (the cache identity)."""
    return hashlib.sha256(canonical_serialize(module).encode("utf-8")).hexdigest()
