"""Compilation options: which back-end optimizations to compose.

These mirror the ablation axes of paper Fig. 9 (Graphitron-withBurst /
-withCache / -withShuffle vs full Graphitron) plus the TPU-kernel routing
switch. ``CompileOptions.baseline()`` is the "handcrafted HLS without
optimizations" reference configuration from the paper's evaluation.

Two option groups interact with the compiler *middle-end* rather than the
back-end:

* ``passes`` selects the MIR optimization pass pipeline that runs between
  semantic analysis and lowering (see :mod:`repro.core.passes`): kernel
  fusion, dead-property elimination, host constant folding, and
  compile-time push/pull direction selection. ``"default"`` runs all of
  them in order; ``"none"`` disables the pipeline (the pre-pass 1:1
  kernel-per-launch lowering); a comma list (``"fold,fuse"``) runs a
  subset. Because ``CompileOptions`` is part of the Program cache key
  (``repr(options)`` is hashed into the content fingerprint), the same
  source compiled with different ``passes`` values yields distinct cached
  Programs — pass ablations never alias.

* ``scalar_bindings`` binds host scalars to values *at compile time*: the
  ``fold`` pass substitutes them as literals into every kernel and host
  expression (then simplifies), and the scalar disappears from the
  program's declared run-time parameters. Use it to specialize a kernel on
  a known-constant parameter (e.g. ``scalar_bindings=(("damp", 0.85),)``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class CompileOptions:
    # memory-access optimizations (paper §III-C3)
    burst: bool = True  # partitioned, ascending-src streaming order
    cache: bool = True  # hub-vertex relabeling (dense VMEM-prefix hub cache)
    shuffle: bool = True  # dst-binned sorted segment reduction (conflict-free)
    # pipeline optimizations (paper §III-C1/C2) are always-on semantics-level
    # transforms (RAW decoupling, RMW normalization) — not toggles.
    # frontier compaction: only traverse active edges (direction/frontier opt)
    compact_frontier: bool = True
    # route scatter-reduce / gather through Pallas TPU kernels
    pallas: bool = False
    # dst-range partitions target (VMEM sizing unit); 0 = auto
    n_partitions: int = 0
    # Pallas interpret mode: None = auto (interpreted unless a real TPU
    # backend is present), True/False = forced
    interpret: Optional[bool] = None
    # MIR optimization pass pipeline: "default" | "none" | "fuse,dce,..."
    passes: str = "default"
    # compile-time scalar bindings consumed by the `fold` pass
    scalar_bindings: Tuple[Tuple[str, object], ...] = ()

    @property
    def interpret_effective(self) -> bool:
        """Resolve ``interpret=None`` to the platform default.

        Pallas kernels must run interpreted on CPU (CI), but interpreting
        on a real TPU would silently deoptimize device runs — so auto
        means "interpret unless jax is actually backed by a TPU".
        """
        if self.interpret is not None:
            return self.interpret
        import jax

        return jax.default_backend() != "tpu"

    @staticmethod
    def baseline() -> "CompileOptions":
        """Unoptimized reference: random scatter, no partitioning/caching,
        no MIR passes — one kernel per launch, exactly as authored."""
        return CompileOptions(
            burst=False, cache=False, shuffle=False, compact_frontier=False,
            pallas=False, passes="none",
        )

    @staticmethod
    def with_only(opt: str) -> "CompileOptions":
        """Fig. 9 ablation points: exactly one memory optimization enabled."""
        base = CompileOptions.baseline()
        if opt not in ("burst", "cache", "shuffle"):
            raise ValueError(f"unknown ablation axis {opt!r}")
        return replace(base, **{opt: True})

    @staticmethod
    def full(pallas: bool = False) -> "CompileOptions":
        return CompileOptions(pallas=pallas)
