"""Compilation options: front-end / middle-end concerns only.

Since the Target/Accelerator split, ``CompileOptions`` describes *what the
compiler does to the program* — the MIR pass pipeline and compile-time
scalar specialization — while :class:`~repro.core.target.Target` describes
*where the result runs* (backend kind, device mesh, memory-access
optimizations, partition/VMEM budget, Pallas routing, interpret mode).

Two option groups remain here:

* ``passes`` selects the MIR optimization pass pipeline that runs between
  semantic analysis and lowering (see :mod:`repro.core.passes`): kernel
  fusion, dead-property elimination, host constant folding, and
  compile-time push/pull direction selection. ``"default"`` runs all of
  them in order; ``"none"`` disables the pipeline (the pre-pass 1:1
  kernel-per-launch lowering); a comma list (``"fold,fuse"``) runs a
  subset. Because ``CompileOptions`` is part of the Program cache key
  (``repr(options)`` is hashed into the content fingerprint), the same
  source compiled with different ``passes`` values yields distinct cached
  Programs — pass ablations never alias.

* ``scalar_bindings`` binds host scalars to values *at compile time*: the
  ``fold`` pass substitutes them as literals into every kernel and host
  expression (then simplifies), and the scalar disappears from the
  program's declared run-time parameters. Use it to specialize a kernel on
  a known-constant parameter (e.g. ``scalar_bindings=(("damp", 0.85),)``).

Compat shim — the substrate fields that used to live here (``burst``,
``cache``, ``shuffle``, ``compact_frontier``, ``pallas``,
``n_partitions``, ``interpret``) are still accepted as constructor
kwargs (with a :class:`DeprecationWarning` naming the exact ``Target``
replacement) and still readable as attributes, but they are stored as
``target_overrides`` and replayed onto a :class:`Target` by
:meth:`Target.from_options` / :meth:`CompileOptions.resolve_target`.
Overrides equal to the Target default are dropped at construction, so
``CompileOptions(pallas=False) == CompileOptions()`` — cosmetic legacy
kwargs never split the Program cache. ``CompileOptions.baseline()`` /
``with_only()`` / ``full()`` (the paper Fig. 9 ablation axes) keep
working through the shim; new code should build a :class:`Target`.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional, Tuple

from .target import DEFAULT_TARGET, LEGACY_OPTION_FIELDS, Target


@dataclass(frozen=True, init=False)
class CompileOptions:
    # MIR optimization pass pipeline: "default" | "none" | "fuse,dce,..."
    passes: str = "default"
    # compile-time scalar bindings consumed by the `fold` pass
    scalar_bindings: Tuple[Tuple[str, object], ...] = ()
    # legacy substrate kwargs, canonicalized: sorted, defaults dropped.
    # Replayed onto Target by Target.from_options / resolve_target().
    target_overrides: Tuple[Tuple[str, object], ...] = ()

    def __init__(
        self,
        passes: str = "default",
        scalar_bindings: Tuple[Tuple[str, object], ...] = (),
        target_overrides: Tuple[Tuple[str, object], ...] = (),
        **legacy,
    ):
        unknown = sorted(set(legacy) - set(LEGACY_OPTION_FIELDS))
        if unknown:
            raise TypeError(
                f"unknown CompileOptions field(s) {unknown}; substrate fields "
                f"moved to repro.Target — the accepted legacy kwargs are "
                f"{list(LEGACY_OPTION_FIELDS)}"
            )
        if legacy:
            repl = ", ".join(f"{k}={legacy[k]!r}" for k in sorted(legacy))
            warnings.warn(
                f"passing substrate kwargs to CompileOptions is deprecated; "
                f"build a Target instead: repro.Target({repl}) — and pass it "
                f"to program.lower(target, shape) or a bind "
                f"(program.bind(graph, target=target)). CompileOptions now "
                f"carries only passes/scalar_bindings.",
                DeprecationWarning,
                stacklevel=2,
            )
        merged = dict(target_overrides)
        merged.update(legacy)
        # canonicalize: drop overrides that equal the Target default so
        # cosmetic legacy kwargs don't split the Program cache
        canon = tuple(sorted(
            (k, v) for k, v in merged.items()
            if v != getattr(DEFAULT_TARGET, k)
        ))
        object.__setattr__(self, "passes", passes)
        object.__setattr__(self, "scalar_bindings", tuple(scalar_bindings))
        object.__setattr__(self, "target_overrides", canon)

    # -- target resolution ----------------------------------------------------
    def resolve_target(self, kind: str = "local", **overrides) -> Target:
        """The Target these options imply (legacy overrides replayed)."""
        return Target.from_options(self, kind=kind, **overrides)

    def _target_value(self, name: str):
        for k, v in self.target_overrides:
            if k == name:
                return v
        return getattr(DEFAULT_TARGET, name)

    # legacy attribute surface (kept so existing engines/tests/benchmarks
    # reading options.burst etc. run unchanged against either object)
    @property
    def burst(self) -> bool:
        return self._target_value("burst")

    @property
    def cache(self) -> bool:
        return self._target_value("cache")

    @property
    def shuffle(self) -> bool:
        return self._target_value("shuffle")

    @property
    def compact_frontier(self) -> bool:
        return self._target_value("compact_frontier")

    @property
    def pallas(self) -> bool:
        return self._target_value("pallas")

    @property
    def n_partitions(self) -> int:
        return self._target_value("n_partitions")

    @property
    def interpret(self) -> Optional[bool]:
        return self._target_value("interpret")

    @property
    def interpret_effective(self) -> bool:
        return self.resolve_target().interpret_effective

    # -- ablation constructors (paper Fig. 9) ---------------------------------
    @staticmethod
    def baseline() -> "CompileOptions":
        """Unoptimized reference: random scatter, no partitioning/caching,
        no MIR passes — one kernel per launch, exactly as authored."""
        over = {
            "burst": False, "cache": False, "shuffle": False,
            "compact_frontier": False, "pallas": False,
        }
        return CompileOptions(
            passes="none",
            target_overrides=tuple(sorted(over.items())),
        )

    @staticmethod
    def with_only(opt: str) -> "CompileOptions":
        """Fig. 9 ablation points: exactly one memory optimization enabled."""
        if opt not in ("burst", "cache", "shuffle"):
            raise ValueError(f"unknown ablation axis {opt!r}")
        base = CompileOptions.baseline()
        over = dict(base.target_overrides)
        over[opt] = True
        return CompileOptions(passes=base.passes,
                              target_overrides=tuple(sorted(over.items())))

    @staticmethod
    def full(pallas: bool = False) -> "CompileOptions":
        return CompileOptions(target_overrides=(("pallas", pallas),))
