"""Compilation options: which back-end optimizations to compose.

These mirror the ablation axes of paper Fig. 9 (Graphitron-withBurst /
-withCache / -withShuffle vs full Graphitron) plus the TPU-kernel routing
switch. ``CompileOptions.baseline()`` is the "handcrafted HLS without
optimizations" reference configuration from the paper's evaluation.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class CompileOptions:
    # memory-access optimizations (paper §III-C3)
    burst: bool = True  # partitioned, ascending-src streaming order
    cache: bool = True  # hub-vertex relabeling (dense VMEM-prefix hub cache)
    shuffle: bool = True  # dst-binned sorted segment reduction (conflict-free)
    # pipeline optimizations (paper §III-C1/C2) are always-on semantics-level
    # transforms (RAW decoupling, RMW normalization) — not toggles.
    # frontier compaction: only traverse active edges (direction/frontier opt)
    compact_frontier: bool = True
    # route scatter-reduce / gather through Pallas TPU kernels
    pallas: bool = False
    # dst-range partitions target (VMEM sizing unit); 0 = auto
    n_partitions: int = 0
    # interpret=True for Pallas on CPU
    interpret: bool = True

    @staticmethod
    def baseline() -> "CompileOptions":
        """Unoptimized reference: random scatter, no partitioning/caching."""
        return CompileOptions(
            burst=False, cache=False, shuffle=False, compact_frontier=False,
            pallas=False,
        )

    @staticmethod
    def with_only(opt: str) -> "CompileOptions":
        """Fig. 9 ablation points: exactly one memory optimization enabled."""
        base = CompileOptions.baseline()
        if opt not in ("burst", "cache", "shuffle"):
            raise ValueError(f"unknown ablation axis {opt!r}")
        return replace(base, **{opt: True})

    @staticmethod
    def full(pallas: bool = False) -> "CompileOptions":
        return CompileOptions(pallas=pallas)
