"""Sessions: a bound (program, graph, backend) triple you run many times.

A :class:`Session` owns lowered kernels and device state for one graph on
one execution backend, and exposes exactly one way to execute: explicit,
validated keyword parameters —

    session = program.bind(graph, backend="local")
    result = session.run(root=3)

replacing the old pattern of constructing an ``Engine`` by hand and
mutating ``engine.host_env`` between runs. Backends implement the
:class:`ExecutionBackend` protocol; "local" wraps the single-device
:class:`~repro.core.engine.Engine` and "distributed" wraps the
multi-device :class:`~repro.core.dist_engine.DistEngine` (shard_map +
all_to_all shuffle supersteps). New backends register via
:func:`register_backend`.

:class:`SessionPool` holds N sessions over the same bound graph and
serves batch/async query streams — the serving path used by
``repro.launch.serve --graph``.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Protocol, Sequence, runtime_checkable

from .engine import EngineResult
from .program import Program, ProgramError

try:  # pragma: no cover - trivially importable in-repo
    from ..graph.storage import GraphData
except ImportError:  # pragma: no cover
    GraphData = Any  # type: ignore


class SessionError(Exception):
    pass


@runtime_checkable
class ExecutionBackend(Protocol):
    """What a backend must provide to host a Session.

    The lifecycle per ``run()`` is reset -> apply_params -> execute; the
    backend keeps compiled/lowered kernels warm across the reset.
    """

    name: str

    def reset(self) -> None:  # pragma: no cover - protocol
        ...

    def apply_params(self, params: Dict[str, Any]) -> None:  # pragma: no cover
        ...

    def execute(self) -> EngineResult:  # pragma: no cover
        ...


class EngineBackend:
    """Backend over any :class:`~repro.core.engine.Engine` (sub)class: the
    run lifecycle (reset -> apply_params -> execute) is engine-independent,
    so every engine flavor shares this one implementation."""

    def __init__(self, name: str, engine):
        self.name = name
        self.engine = engine

    def reset(self) -> None:
        self.engine.reset()

    def apply_params(self, params: Dict[str, Any]) -> None:
        self.engine.host_env.update(params)

    def execute(self) -> EngineResult:
        return self.engine.run()


def LocalBackend(program: Program, graph: GraphData,
                 argv: Optional[list] = None) -> EngineBackend:
    """Single-device execution: the paper's one-accelerator system."""
    from .engine import Engine

    return EngineBackend(
        "local", Engine(program.module, graph, program.options, argv=argv)
    )


def DistributedBackend(program: Program, graph: GraphData,
                       argv: Optional[list] = None, mesh=None,
                       axis: str = "data") -> EngineBackend:
    """Multi-device execution: edge kernels become shuffle supersteps
    across the device mesh (ForeGraph-style multi-accelerator scaling)."""
    from .dist_engine import DistEngine

    return EngineBackend(
        "distributed",
        DistEngine(program.module, graph, program.options, argv=argv,
                   mesh=mesh, axis=axis),
    )


_BACKENDS: Dict[str, Callable[..., ExecutionBackend]] = {}


def register_backend(name: str, factory: Callable[..., ExecutionBackend]) -> None:
    """Register an execution backend under ``name`` for Program.bind()."""
    _BACKENDS[name] = factory


def backend_names() -> List[str]:
    return sorted(_BACKENDS)


register_backend("local", LocalBackend)
register_backend("distributed", DistributedBackend)


class Session:
    """One program bound to one graph on one backend; run it many times.

    ``run(**params)`` validates the keyword parameters against the
    program's declared host scalars, resets device/host state (keeping
    lowered kernels warm), applies the parameters, and executes.
    """

    def __init__(self, program: Program, graph: GraphData, backend: str = "local",
                 *, argv: Optional[list] = None, **backend_opts):
        if backend not in _BACKENDS:
            raise SessionError(
                f"unknown backend {backend!r}; available: {backend_names()}"
            )
        self.program = program
        self.graph = graph
        self.backend_name = backend
        argv = list(argv) if argv is not None else ["prog", "<graph>"]
        self.backend: ExecutionBackend = _BACKENDS[backend](
            program, graph, argv=argv, **backend_opts
        )
        self.runs = 0
        self._lock = threading.Lock()

    def run(self, **params) -> EngineResult:
        """Execute the bound program with explicit run-time parameters."""
        coerced = self.program.validate_params(params)
        with self._lock:  # a Session is a stateful device context
            self.backend.reset()
            self.backend.apply_params(coerced)
            result = self.backend.execute()
            self.runs += 1
            return result

    def run_many(self, param_sets: Sequence[Dict[str, Any]]) -> List[EngineResult]:
        """Run a sequence of parameter sets back-to-back (results in order)."""
        return [self.run(**p) for p in param_sets]

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Release the session (hook for future device-owning backends)."""

    def __repr__(self) -> str:
        return (
            f"Session({self.program.fingerprint[:12]} on {self.backend_name}, "
            f"|V|={getattr(self.graph, 'n_vertices', '?')}, runs={self.runs})"
        )


class SessionPool:
    """N worker sessions over one (program, graph, backend): batch serving.

    Each worker owns an independent session (its own device state and its
    own jitted kernels), so queries execute concurrently — but each worker
    also pays its own one-time kernel-compilation cost on its first run;
    call :meth:`warmup` before latency-sensitive serving. ``submit``
    returns a Future; ``run_batch`` preserves submission order in its
    result list.
    """

    def __init__(self, program: Program, graph: GraphData, backend: str = "local",
                 size: int = 2, *, argv: Optional[list] = None, **backend_opts):
        if size < 1:
            raise SessionError("SessionPool size must be >= 1")
        self.program = program
        self.graph = graph
        self.size = size
        self._sessions = [
            Session(program, graph, backend=backend, argv=argv, **backend_opts)
            for _ in range(size)
        ]
        self._idle: "list[Session]" = list(self._sessions)
        self._idle_lock = threading.Lock()
        self._idle_ready = threading.Condition(self._idle_lock)
        self._executor = ThreadPoolExecutor(
            max_workers=size, thread_name_prefix="repro-session"
        )
        self._closed = False

    # -- scheduling ---------------------------------------------------------
    def _acquire(self) -> Session:
        with self._idle_ready:
            while not self._idle:
                self._idle_ready.wait()
            return self._idle.pop()

    def _release(self, sess: Session) -> None:
        with self._idle_ready:
            self._idle.append(sess)
            self._idle_ready.notify()

    def _run_one(self, params: Dict[str, Any]) -> EngineResult:
        sess = self._acquire()
        try:
            return sess.run(**params)
        finally:
            self._release(sess)

    # -- public API ---------------------------------------------------------
    def warmup(self, **params) -> None:
        """Run one query on EVERY worker session so each jit-compiles its
        kernel launch paths before real traffic arrives. Warmups run
        concurrently (XLA compilation releases the GIL)."""
        if self._closed:
            raise SessionError("SessionPool is closed")
        self.program.validate_params(params)
        futures = [self._executor.submit(s.run, **params) for s in self._sessions]
        for f in futures:
            f.result()

    def submit(self, **params) -> "Future[EngineResult]":
        """Async: enqueue one parameterized query, get a Future."""
        if self._closed:
            raise SessionError("SessionPool is closed")
        self.program.validate_params(params)  # fail fast on the caller thread
        return self._executor.submit(self._run_one, params)

    def run_batch(self, param_sets: Sequence[Dict[str, Any]]) -> List[EngineResult]:
        """Batch: run every parameter set; results in submission order."""
        futures = [self.submit(**p) for p in param_sets]
        return [f.result() for f in futures]

    def close(self, wait: bool = True) -> None:
        self._closed = True
        self._executor.shutdown(wait=wait)
        for s in self._sessions:
            s.close()

    def __enter__(self) -> "SessionPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return f"SessionPool(size={self.size}, program={self.program.fingerprint[:12]})"


__all__ = [
    "ExecutionBackend",
    "EngineBackend",
    "LocalBackend",
    "DistributedBackend",
    "Session",
    "SessionError",
    "SessionPool",
    "ProgramError",
    "register_backend",
    "backend_names",
]
