"""Sessions: a bound (program, graph, backend) triple you run many times.

A :class:`Session` owns lowered kernels and device state for one graph on
one execution backend, and exposes exactly one way to execute: explicit,
validated keyword parameters —

    session = program.bind(graph, backend="local")
    result = session.run(root=3)

replacing the old pattern of constructing an ``Engine`` by hand and
mutating ``engine.host_env`` between runs. Backends implement the
:class:`ExecutionBackend` protocol; "local" wraps the single-device
:class:`~repro.core.engine.Engine` and "distributed" wraps the
multi-device :class:`~repro.core.dist_engine.DistEngine` (shard_map +
all_to_all shuffle supersteps). New backends register via
:func:`register_backend`.

:class:`SessionPool` holds N sessions over the same bound graph and
serves batch/async query streams — the serving path used by
``repro.launch.serve --graph``.

:class:`BatchSession` (``program.bind_batch(graph)``) answers K parameter
bindings per launch set through :class:`repro.batch.BatchEngine`; both
``Session.run_many`` and ``SessionPool.run_batch`` reroute batch-eligible
query lists through it automatically, falling back to the sequential path
otherwise. Any backend registered via :func:`register_backend` whose
:class:`ExecutionBackend` exposes an ``engine`` attribute (an
:class:`~repro.core.engine.Engine` subclass) serves batches through its
own launch strategy — the local and distributed engines both do.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Protocol, Sequence, runtime_checkable

from .engine import EngineResult
from .program import Program, ProgramError

try:  # pragma: no cover - trivially importable in-repo
    from ..graph.storage import GraphData
except ImportError:  # pragma: no cover
    GraphData = Any  # type: ignore


class SessionError(Exception):
    pass


class ServiceClosed(SessionError):
    """Submission to a closed serving surface (pool, batcher, scheduler,
    service). Typed so clients can distinguish "shut down, stop sending"
    from a genuine execution failure — previously a closed pool could
    surface a raw executor/queue RuntimeError instead."""


@runtime_checkable
class ExecutionBackend(Protocol):
    """What a backend must provide to host a Session.

    The lifecycle per ``run()`` is reset -> apply_params -> execute; the
    backend keeps compiled/lowered kernels warm across the reset.
    """

    name: str

    def reset(self) -> None:  # pragma: no cover - protocol
        ...

    def apply_params(self, params: Dict[str, Any]) -> None:  # pragma: no cover
        ...

    def execute(self) -> EngineResult:  # pragma: no cover
        ...


class EngineBackend:
    """Backend over any :class:`~repro.core.engine.Engine` (sub)class: the
    run lifecycle (reset -> apply_params -> execute) is engine-independent,
    so every engine flavor shares this one implementation."""

    def __init__(self, name: str, engine):
        self.name = name
        self.engine = engine

    def reset(self) -> None:
        self.engine.reset()

    def apply_params(self, params: Dict[str, Any]) -> None:
        self.engine.host_env.update(params)

    def execute(self) -> EngineResult:
        return self.engine.run()


def LocalBackend(program: Program, graph: GraphData,
                 argv: Optional[list] = None, target=None,
                 library=None) -> EngineBackend:
    """Single-device execution: the paper's one-accelerator system.

    ``target`` pins the execution substrate explicitly (otherwise resolved
    from the program's CompileOptions); ``library`` is an AOT kernel
    library from :meth:`repro.core.accelerator.Accelerator` — when given,
    the engine starts warm (no per-bind jit compilation).
    """
    from .engine import Engine

    return EngineBackend(
        "local",
        Engine(program.module, graph, program.options, argv=argv,
               target=target, library=library),
    )


def DistributedBackend(program: Program, graph: GraphData,
                       argv: Optional[list] = None, mesh=None,
                       axis: str = "data", target=None) -> EngineBackend:
    """Multi-device execution: edge kernels become shuffle supersteps
    across the device mesh (ForeGraph-style multi-accelerator scaling)."""
    from .dist_engine import DistEngine

    return EngineBackend(
        "distributed",
        DistEngine(program.module, graph, program.options, argv=argv,
                   mesh=mesh, axis=axis, target=target),
    )


_BACKENDS: Dict[str, Callable[..., ExecutionBackend]] = {}


def register_backend(name: str, factory: Callable[..., ExecutionBackend]) -> None:
    """Register an execution backend under ``name`` for Program.bind()."""
    _BACKENDS[name] = factory


def backend_names() -> List[str]:
    return sorted(_BACKENDS)


register_backend("local", LocalBackend)
register_backend("distributed", DistributedBackend)


# chunk size for the implicit BatchSessions behind Session.run_many /
# SessionPool.run_batch: every distinct batch size K is a fresh XLA trace
# of all kernels at state shape [K, n], so the automatic reroute caps K —
# the possible trace shapes are then bounded (at most AUTO_MAX_BATCH of
# them) no matter how query-list lengths vary across calls. Explicit
# bind_batch() callers pick their own max_batch.
AUTO_MAX_BATCH = 64


def batch_eligible(coerced_sets: Sequence[Dict[str, Any]]) -> bool:
    """True when a list of validated parameter sets can share one batch.

    Eligibility is purely structural: every set binds the SAME parameter
    names (values are scalars by construction — ``validate_params`` already
    coerced them), so one vectorized state layout fits all of them. Mixed
    key sets (e.g. some queries overriding ``iters`` and some not) fall
    back to the sequential path.
    """
    if not coerced_sets:
        return False
    keys = set(coerced_sets[0])
    return all(set(p) == keys for p in coerced_sets[1:])


class Session:
    """One program bound to one graph on one backend; run it many times.

    ``run(**params)`` validates the keyword parameters against the
    program's declared host scalars, resets device/host state (keeping
    lowered kernels warm), applies the parameters, and executes.
    """

    def __init__(self, program: Program, graph: GraphData, backend: str = "local",
                 *, argv: Optional[list] = None, **backend_opts):
        if backend not in _BACKENDS:
            raise SessionError(
                f"unknown backend {backend!r}; available: {backend_names()}"
            )
        self.program = program
        self.graph = graph
        self.backend_name = backend
        argv = list(argv) if argv is not None else ["prog", "<graph>"]
        self._argv = argv
        self._backend_opts = dict(backend_opts)
        self.backend: ExecutionBackend = _BACKENDS[backend](
            program, graph, argv=argv, **backend_opts
        )
        self.runs = 0
        # set by Accelerator.bind: traced runs feed its profiling baseline
        self.accelerator = None
        self._batch_session: Optional["BatchSession"] = None
        self._batch_unsupported = False
        self._batch_init_lock = threading.Lock()
        self._lock = threading.Lock()

    def run(self, **params) -> EngineResult:
        """Execute the bound program with explicit run-time parameters."""
        coerced = self.program.validate_params(params)
        with self._lock:  # a Session is a stateful device context
            self.backend.reset()
            self.backend.apply_params(coerced)
            result = self.backend.execute()
            self.runs += 1
        if result.trace is not None and self.accelerator is not None:
            self.accelerator.record_profile(result.trace)
        return result

    def run_many(self, param_sets: Sequence[Dict[str, Any]],
                 batched: Optional[bool] = None) -> List[EngineResult]:
        """Run a sequence of parameter sets; results in submission order.

        Results are **element-wise identical** to calling :meth:`run` once
        per set, in order: ``run_many(ps)[i]`` carries bit-identical
        properties and host scalars to ``run(**ps[i])``. When the sets are
        batch-eligible (two or more sets sharing one parameter key set —
        see :func:`batch_eligible`) and the backend exposes an engine, the
        queries are answered by ONE batched execution
        (:class:`BatchSession`) whose launches serve all K lanes at once;
        otherwise the sequential loop runs. ``batched=True`` forces the
        batched path (raising if ineligible), ``batched=False`` forces the
        sequential loop; the default picks automatically. Only the
        ``stats`` objects differ between the two paths: batched results
        share one :class:`~repro.core.engine.EngineStats` with
        ``batch_size == K`` and per-batch launch counters.
        """
        sets = [dict(p) for p in param_sets]
        if batched is None:
            coerced = [self.program.validate_params(p) for p in sets]
            batched = len(sets) > 1 and batch_eligible(coerced)
            if batched and self._ensure_batch_session() is None:
                batched = False
        if batched:
            bs = self._ensure_batch_session()
            if bs is None:
                raise SessionError(
                    f"backend {self.backend_name!r} does not expose an engine "
                    "for batched execution"
                )
            return bs.run_many(sets)
        return [self.run(**p) for p in sets]

    def refresh_graph(self, graph: Optional[GraphData] = None) -> None:
        """Rebind after an in-place graph mutation (streaming update path).

        Re-derives the backend engine's graph-dependent bindings (hub
        relabeling, processing order, CSR/CSC device arrays) against the
        updated — same-shape — graph. Only callable on backends exposing an
        ``engine``. The caller must guarantee no query is in flight (the
        :class:`repro.streaming.StreamingSession` write gate does);
        this method still takes the session lock as a second line of
        defense against torn reads.
        """
        graph = graph if graph is not None else self.graph
        engine = getattr(self.backend, "engine", None)
        if engine is None:
            raise SessionError(
                f"backend {self.backend_name!r} does not expose an engine; "
                "cannot refresh its graph binding in place"
            )
        with self._lock:
            self.graph = graph
            engine.refresh_graph(graph)
        if self._batch_session is not None:
            self._batch_session.refresh_graph(graph)

    def _ensure_batch_session(self) -> Optional["BatchSession"]:
        """Lazily build the batched twin of this session (None if the
        backend cannot host one; the failure is memoized so engine-less
        backends don't rebuild-and-discard a backend per call)."""
        with self._batch_init_lock:
            if self._batch_session is None and not self._batch_unsupported:
                try:
                    self._batch_session = BatchSession(
                        self.program, self.graph, backend=self.backend_name,
                        argv=self._argv, max_batch=AUTO_MAX_BATCH,
                        **self._backend_opts,
                    )
                except SessionError:
                    self._batch_unsupported = True
            return self._batch_session

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Release the session (hook for future device-owning backends)."""
        if self._batch_session is not None:
            self._batch_session.close()

    def __repr__(self) -> str:
        return (
            f"Session({self.program.fingerprint[:12]} on {self.backend_name}, "
            f"|V|={getattr(self.graph, 'n_vertices', '?')}, runs={self.runs})"
        )


class BatchSession:
    """One program bound to one graph, answering K queries per launch set.

    Created by ``program.bind_batch(graph, backend=...)``. ``run_many``
    takes a list of parameter sets that share one key set and executes
    them as a single batched run: state gains a leading batch axis, host
    control flow runs with per-query active masks, and BFS-like frontier
    programs automatically take the bit-packed multi-source path
    (:mod:`repro.batch.msbfs`). Results are element-wise **bit-identical**
    to sequential :meth:`Session.run` calls, in submission order.

    Works on any registered backend whose :class:`ExecutionBackend`
    exposes an ``engine`` attribute (the local and distributed engines
    both do): the batch engine drives that engine's own per-launch
    batching hooks, so e.g. distributed edge kernels still run as shuffle
    supersteps — one vmapped all_to_all round for the whole batch.

    ``max_batch`` chunks oversized query lists (a new batch size means a
    new XLA trace, so serving paths pick one size and stick to it);
    ``msbfs=False`` disables the multi-source BFS fast path (the generic
    vmapped path then serves BFS too).
    """

    def __init__(self, program: Program, graph: GraphData, backend: str = "local",
                 *, argv: Optional[list] = None, max_batch: Optional[int] = None,
                 msbfs: bool = True, **backend_opts):
        if backend not in _BACKENDS:
            raise SessionError(
                f"unknown backend {backend!r}; available: {backend_names()}"
            )
        if max_batch is not None and max_batch < 1:
            raise SessionError("max_batch must be >= 1")
        self.program = program
        self.graph = graph
        self.backend_name = backend
        argv = list(argv) if argv is not None else ["prog", "<graph>"]
        self.backend: ExecutionBackend = _BACKENDS[backend](
            program, graph, argv=argv, **backend_opts
        )
        inner = getattr(self.backend, "engine", None)
        if inner is None:
            raise SessionError(
                f"backend {backend!r} does not expose an engine attribute; "
                "batched execution needs one (see ExecutionBackend)"
            )
        from ..batch.engine import BatchEngine

        self.engine = BatchEngine(inner, enable_msbfs=msbfs)
        self.max_batch = max_batch
        self.runs = 0
        self.queries = 0
        # set by Accelerator.bind_batch: traced runs feed its profile
        self.accelerator = None
        self._lock = threading.Lock()

    def run_many(self, param_sets: Sequence[Dict[str, Any]]) -> List[EngineResult]:
        """Answer every parameter set in one (or few) batched executions.

        All sets must share one parameter key set; raises
        :class:`SessionError` otherwise (use :meth:`Session.run_many` for
        mixed streams — it falls back to the sequential path).
        """
        coerced = [self.program.validate_params(dict(p)) for p in param_sets]
        if not coerced:
            return []
        if not batch_eligible(coerced):
            raise SessionError(
                "param sets are not batch-eligible: every set must bind the "
                "same parameter names (Session.run_many handles mixed streams)"
            )
        step = self.max_batch or len(coerced)
        out: List[EngineResult] = []
        with self._lock:  # one batched device context
            for i in range(0, len(coerced), step):
                chunk = coerced[i:i + step]
                out.extend(self.engine.run_batch(chunk))
                self.runs += 1
                self.queries += len(chunk)
        if out and out[-1].trace is not None and self.accelerator is not None:
            # one summary per chunk; chunks share the run's trace shape
            seen = {id(r.trace): r.trace for r in out if r.trace is not None}
            for trace in seen.values():
                self.accelerator.record_profile(trace)
        return out

    def refresh_graph(self, graph: Optional[GraphData] = None) -> None:
        """Rebind after an in-place graph mutation (see Session.refresh_graph)."""
        graph = graph if graph is not None else self.graph
        with self._lock:
            self.graph = graph
            self.engine.engine.refresh_graph(graph)  # inner Engine
            self.engine.refresh_graph()  # BatchEngine re-points its snapshot

    def __enter__(self) -> "BatchSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Release the session (hook for future device-owning backends)."""

    def __repr__(self) -> str:
        return (
            f"BatchSession({self.program.fingerprint[:12]} on "
            f"{self.backend_name}, |V|={getattr(self.graph, 'n_vertices', '?')}, "
            f"runs={self.runs}, queries={self.queries})"
        )


class SessionPool:
    """N worker sessions over one (program, graph, backend): batch serving.

    Each worker owns an independent session (its own device state and its
    own jitted kernels), so queries execute concurrently — but each worker
    also pays its own one-time kernel-compilation cost on its first run;
    call :meth:`warmup` before latency-sensitive serving. ``submit``
    returns a Future; ``run_batch`` preserves submission order in its
    result list.

    ``batch=N`` (N > 1) turns on **dynamic batching**: submitted queries
    are collected by a :class:`repro.batch.DynamicBatcher` into groups of
    up to N (waiting ``batch_wait_s`` for stragglers) and answered by one
    shared :class:`BatchSession` instead of N worker runs — same results,
    same Future surface, far fewer launches. ``pool.batch_stats`` then
    reports batch occupancy.
    """

    def __init__(self, program: Program, graph: GraphData, backend: str = "local",
                 size: int = 2, *, argv: Optional[list] = None, batch: int = 0,
                 batch_wait_s: float = 0.002, **backend_opts):
        if size < 1:
            raise SessionError("SessionPool size must be >= 1")
        self.program = program
        self.graph = graph
        self.size = size
        self.backend_name = backend
        self._argv = argv
        self._backend_opts = dict(backend_opts)
        self._sessions = [
            Session(program, graph, backend=backend, argv=argv, **backend_opts)
            for _ in range(size)
        ]
        self._idle: "list[Session]" = list(self._sessions)
        self._idle_lock = threading.Lock()
        self._idle_ready = threading.Condition(self._idle_lock)
        self._executor = ThreadPoolExecutor(
            max_workers=size, thread_name_prefix="repro-session"
        )
        self._closed = False
        self._batch_session: Optional[BatchSession] = None
        self._batch_unsupported = False
        self._batch_lock = threading.Lock()
        self._batcher = None
        if batch > 1:
            from ..batch.dynamic import DynamicBatcher

            bs = self._ensure_batch_session(max_batch=batch)
            if bs is None:
                raise SessionError(
                    f"backend {backend!r} cannot host the dynamic batcher "
                    "(no engine attribute on its ExecutionBackend)"
                )
            self._batcher = DynamicBatcher(
                bs.run_many, max_batch=batch, max_wait_s=batch_wait_s
            )

    @property
    def batch_stats(self):
        """Dynamic-batching occupancy stats (None unless ``batch > 1``)."""
        return self._batcher.stats if self._batcher is not None else None

    def _ensure_batch_session(self, max_batch: Optional[int] = None):
        """Lazily build the pool-shared BatchSession (None if unsupported;
        the failure is memoized)."""
        with self._batch_lock:
            if self._batch_session is None and not self._batch_unsupported:
                try:
                    self._batch_session = BatchSession(
                        self.program, self.graph, backend=self.backend_name,
                        argv=self._argv, max_batch=max_batch or AUTO_MAX_BATCH,
                        **self._backend_opts,
                    )
                except SessionError:
                    self._batch_unsupported = True
            return self._batch_session

    # -- scheduling ---------------------------------------------------------
    def _acquire(self) -> Session:
        with self._idle_ready:
            while not self._idle:
                self._idle_ready.wait()
            return self._idle.pop()

    def _release(self, sess: Session) -> None:
        with self._idle_ready:
            self._idle.append(sess)
            self._idle_ready.notify()

    def _run_one(self, params: Dict[str, Any]) -> EngineResult:
        sess = self._acquire()
        try:
            return sess.run(**params)
        finally:
            self._release(sess)

    # -- public API ---------------------------------------------------------
    def warmup(self, **params) -> None:
        """Run one query on EVERY worker session so each jit-compiles its
        kernel launch paths before real traffic arrives. Warmups run
        concurrently (XLA compilation releases the GIL). With dynamic
        batching enabled, the shared BatchSession is warmed too — at a full
        ``batch``-sized query list, since that is the trace shape real
        traffic hits (partial trailing batches still compile on first
        sight)."""
        if self._closed:
            raise ServiceClosed("SessionPool is closed")
        self.program.validate_params(params)
        futures = [self._executor.submit(s.run, **params) for s in self._sessions]
        for f in futures:
            f.result()
        if self._batcher is not None and self._batch_session is not None:
            self._batch_session.run_many([dict(params)] * self._batcher.max_batch)

    def submit(self, **params) -> "Future[EngineResult]":
        """Async: enqueue one parameterized query, get a Future.

        With dynamic batching enabled (``batch > 1``), the query joins the
        collector queue and is answered as part of a batch; otherwise it is
        dispatched to the next idle worker session. Either way the Future
        resolves to the same result a dedicated :meth:`Session.run` would
        produce.
        """
        if self._closed:
            raise ServiceClosed("SessionPool is closed")
        self.program.validate_params(params)  # fail fast on the caller thread
        if self._batcher is not None:
            return self._batcher.submit(params)
        try:
            return self._executor.submit(self._run_one, params)
        except RuntimeError as e:
            # close() raced this submit: the executor rejects with a raw
            # RuntimeError("cannot schedule new futures after shutdown")
            raise ServiceClosed("SessionPool is closed") from e

    def refresh_graph(self, graph: Optional[GraphData] = None) -> None:
        """Rebind every worker (and the shared BatchSession) after an
        in-place graph mutation. The pool must be quiescent — no query in
        flight and the dynamic batcher drained; the streaming layer's
        write gate guarantees this, and callers driving the pool directly
        must arrange the same.
        """
        if self._closed:
            raise ServiceClosed("SessionPool is closed")
        graph = graph if graph is not None else self.graph
        self.graph = graph
        if self._batcher is not None:
            self._batcher.drain()
        for s in self._sessions:
            s.refresh_graph(graph)
        if self._batch_session is not None:
            self._batch_session.refresh_graph(graph)

    def run_batch(self, param_sets: Sequence[Dict[str, Any]],
                  batched: Optional[bool] = None) -> List[EngineResult]:
        """Run every parameter set; results in submission order.

        Results are element-wise identical to one :meth:`Session.run` per
        set — whichever path answers them. Batch-eligible lists (same
        parameter key set everywhere, two or more sets) are rerouted
        through the pool's shared :class:`BatchSession` so one launch set
        serves the whole list; anything else fans out to the worker
        sessions. ``batched=True``/``False`` forces the choice (True raises
        on ineligible lists).
        """
        if self._closed:
            raise ServiceClosed("SessionPool is closed")
        sets = [dict(p) for p in param_sets]
        if batched is None:
            coerced = [self.program.validate_params(p) for p in sets]
            batched = len(sets) > 1 and batch_eligible(coerced)
            if batched and self._ensure_batch_session() is None:
                batched = False
        if batched:
            bs = self._ensure_batch_session()
            if bs is None:
                raise SessionError(
                    f"backend {self.backend_name!r} does not expose an engine "
                    "for batched execution"
                )
            return bs.run_many(sets)
        futures = [self.submit(**p) for p in sets]
        return [f.result() for f in futures]

    def close(self, wait: bool = True) -> None:
        self._closed = True
        if self._batcher is not None:
            self._batcher.close(wait=wait)
        self._executor.shutdown(wait=wait)
        for s in self._sessions:
            s.close()
        if self._batch_session is not None:
            self._batch_session.close()

    def __enter__(self) -> "SessionPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return f"SessionPool(size={self.size}, program={self.program.fingerprint[:12]})"


__all__ = [
    "ExecutionBackend",
    "EngineBackend",
    "LocalBackend",
    "DistributedBackend",
    "BatchSession",
    "Session",
    "SessionError",
    "ServiceClosed",
    "SessionPool",
    "ProgramError",
    "batch_eligible",
    "register_backend",
    "backend_names",
]
