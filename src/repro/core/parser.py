"""Recursive-descent parser for the Graphitron DSL: token stream -> FIR.

The grammar is documented in :mod:`repro.core.fir`. The parser assembles
FIRNodes of varying granularity and returns the root :class:`fir.Program`,
exactly the front-end role described in paper §III-B1.
"""
from __future__ import annotations

from typing import List, Optional

from . import fir
from .lexer import Token, tokenize


class ParseError(SyntaxError):
    """Parse error with a 1-based ``line``/``col`` source location."""

    def __init__(self, msg: str, line: int = 0, col: int = 0):
        super().__init__(msg)
        self.line = line
        self.col = col


def _err(msg: str, tok: Token) -> ParseError:
    return ParseError(f"line {tok.line}, col {tok.col}: {msg}", tok.line, tok.col)


class Parser:
    def __init__(self, toks: List[Token]):
        self.toks = toks
        self.pos = 0

    # -- token helpers -----------------------------------------------------
    def peek(self, ahead: int = 0) -> Token:
        return self.toks[min(self.pos + ahead, len(self.toks) - 1)]

    def next(self) -> Token:
        t = self.toks[self.pos]
        self.pos += 1
        return t

    def at(self, kind: str, text: Optional[str] = None, ahead: int = 0) -> bool:
        t = self.peek(ahead)
        return t.kind == kind and (text is None or t.text == text)

    def expect(self, kind: str, text: Optional[str] = None) -> Token:
        t = self.peek()
        if not self.at(kind, text):
            want = text or kind
            raise _err(f"expected {want!r}, found {t!r}", t)
        return self.next()

    def accept(self, kind: str, text: Optional[str] = None) -> Optional[Token]:
        if self.at(kind, text):
            return self.next()
        return None

    # -- program -----------------------------------------------------------
    def parse_program(self) -> fir.Program:
        prog = fir.Program()
        while not self.at("eof"):
            if self.at("kw", "element"):
                prog.elements.append(self.parse_element())
            elif self.at("kw", "const"):
                prog.consts.append(self.parse_const())
            elif self.at("kw", "func"):
                prog.funcs.append(self.parse_func())
            else:
                t = self.peek()
                raise _err(f"expected declaration, found {t!r}", t)
        return prog

    def parse_element(self) -> fir.ElementDecl:
        t = self.expect("kw", "element")
        name = self.expect("ident").text
        self.expect("kw", "end")
        return fir.ElementDecl(line=t.line, col=t.col, name=name)

    def parse_const(self) -> fir.ConstDecl:
        t = self.expect("kw", "const")
        name = self.expect("ident").text
        self.expect("op", ":")
        ty = self.parse_type()
        init = None
        if self.accept("op", "="):
            init = self.parse_expr()
        self.expect("op", ";")
        return fir.ConstDecl(line=t.line, col=t.col, name=name, type=ty, init=init)

    # -- types ---------------------------------------------------------------
    def parse_type(self) -> fir.Type:
        t = self.peek()
        if t.kind == "kw" and t.text in ("int", "float", "bool"):
            self.next()
            return fir.ScalarType(t.text)
        if self.accept("kw", "vertexset"):
            self.expect("op", "{")
            elem = self.expect("ident").text
            self.expect("op", "}")
            return fir.VertexsetType(elem)
        if self.accept("kw", "edgeset"):
            self.expect("op", "{")
            elem = self.expect("ident").text
            self.expect("op", "}")
            self.expect("op", "(")
            src = self.expect("ident").text
            self.expect("op", ",")
            dst = self.expect("ident").text
            weight = None
            if self.accept("op", ","):
                wt = self.next()
                if wt.text not in ("int", "float"):
                    raise _err("edge weight must be int or float", wt)
                weight = wt.text
            self.expect("op", ")")
            return fir.EdgesetType(elem, src, dst, weight)
        if self.accept("kw", "vector"):
            self.expect("op", "{")
            elem = self.expect("ident").text
            self.expect("op", "}")
            self.expect("op", "(")
            st = self.next()
            if st.text not in ("int", "float", "bool"):
                raise _err("vector scalar must be int/float/bool", st)
            self.expect("op", ")")
            return fir.VectorType(elem, st.text)
        if t.kind == "ident":
            self.next()
            return fir.ElementType(t.text)
        raise _err(f"expected type, found {t!r}", t)

    # -- functions -----------------------------------------------------------
    def parse_func(self) -> fir.FuncDecl:
        t = self.expect("kw", "func")
        name = self.expect("ident").text
        self.expect("op", "(")
        params: List[fir.Param] = []
        if not self.at("op", ")"):
            while True:
                pn = self.expect("ident").text
                self.expect("op", ":")
                pt = self.parse_type()
                params.append(fir.Param(name=pn, type=pt))
                if not self.accept("op", ","):
                    break
        self.expect("op", ")")
        body = self.parse_block()
        self.expect("kw", "end")
        return fir.FuncDecl(line=t.line, col=t.col, name=name, params=params, body=body)

    def parse_block(self, until=("end", "else")) -> List[fir.Stmt]:
        stmts: List[fir.Stmt] = []
        while not (self.peek().kind == "kw" and self.peek().text in until) and not self.at("eof"):
            stmts.append(self.parse_stmt())
        return stmts

    # -- statements ------------------------------------------------------------
    def parse_stmt(self) -> fir.Stmt:
        t = self.peek()
        if self.at("kw", "var"):
            self.next()
            name = self.expect("ident").text
            self.expect("op", ":")
            ty = self.parse_type()
            init = None
            if self.accept("op", "="):
                init = self.parse_expr()
            self.expect("op", ";")
            return fir.VarDecl(line=t.line, col=t.col, name=name, type=ty, init=init)
        if self.at("kw", "if"):
            self.next()
            self.expect("op", "(")
            cond = self.parse_expr()
            self.expect("op", ")")
            then_body = self.parse_block()
            else_body: List[fir.Stmt] = []
            if self.accept("kw", "else"):
                else_body = self.parse_block(until=("end",))
            self.expect("kw", "end")
            return fir.If(line=t.line, col=t.col, cond=cond, then_body=then_body, else_body=else_body)
        if self.at("kw", "while"):
            self.next()
            self.expect("op", "(")
            cond = self.parse_expr()
            self.expect("op", ")")
            body = self.parse_block(until=("end",))
            self.expect("kw", "end")
            return fir.While(line=t.line, col=t.col, cond=cond, body=body)
        if self.at("kw", "for"):
            self.next()
            var = self.expect("ident").text
            self.expect("kw", "in")
            it = self.parse_expr()
            body = self.parse_block(until=("end",))
            self.expect("kw", "end")
            return fir.For(line=t.line, col=t.col, var=var, iter=it, body=body)
        # expression-leading statements: assign / reduce-assign / call
        expr = self.parse_expr()
        if self.at("op", "="):
            self.next()
            value = self.parse_expr()
            self.expect("op", ";")
            if not isinstance(expr, (fir.Ident, fir.Index)):
                raise _err("invalid assignment target", t)
            return fir.Assign(line=t.line, col=t.col, target=expr, value=value)
        for op_tok, op in (("min=", "min"), ("max=", "max"), ("+=", "+"), ("-=", "-"), ("*=", "*")):
            if self.at("op", op_tok):
                self.next()
                value = self.parse_expr()
                self.expect("op", ";")
                if not isinstance(expr, (fir.Ident, fir.Index)):
                    raise _err("invalid reduce target", t)
                return fir.ReduceAssign(line=t.line, col=t.col, target=expr, op=op, value=value)
        self.expect("op", ";")
        return fir.ExprStmt(line=t.line, col=t.col, expr=expr)

    # -- expressions ------------------------------------------------------------
    def parse_expr(self) -> fir.Expr:
        return self.parse_or()

    def parse_or(self) -> fir.Expr:
        e = self.parse_and()
        while self.at("op", "|"):
            t = self.next()
            e = fir.BinOp(line=t.line, col=t.col, op="|", lhs=e, rhs=self.parse_and())
        return e

    def parse_and(self) -> fir.Expr:
        e = self.parse_cmp()
        while self.at("op", "&"):
            t = self.next()
            e = fir.BinOp(line=t.line, col=t.col, op="&", lhs=e, rhs=self.parse_cmp())
        return e

    def parse_cmp(self) -> fir.Expr:
        e = self.parse_add()
        for op in ("==", "!=", "<=", ">=", "<", ">"):
            if self.at("op", op):
                t = self.next()
                return fir.BinOp(line=t.line, col=t.col, op=op, lhs=e, rhs=self.parse_add())
        return e

    def parse_add(self) -> fir.Expr:
        e = self.parse_mul()
        while self.at("op", "+") or self.at("op", "-"):
            t = self.next()
            e = fir.BinOp(line=t.line, col=t.col, op=t.text, lhs=e, rhs=self.parse_mul())
        return e

    def parse_mul(self) -> fir.Expr:
        e = self.parse_unary()
        while self.at("op", "*") or self.at("op", "/"):
            t = self.next()
            e = fir.BinOp(line=t.line, col=t.col, op=t.text, lhs=e, rhs=self.parse_unary())
        return e

    def parse_unary(self) -> fir.Expr:
        if self.at("op", "-") or self.at("op", "!"):
            t = self.next()
            return fir.UnaryOp(line=t.line, col=t.col, op=t.text, operand=self.parse_unary())
        return self.parse_postfix()

    def parse_postfix(self) -> fir.Expr:
        e = self.parse_primary()
        while True:
            if self.at("op", "."):
                t = self.next()
                method = self.expect("ident").text
                self.expect("op", "(")
                args = self.parse_args()
                self.expect("op", ")")
                e = fir.MethodCall(line=t.line, col=t.col, obj=e, method=method, args=args)
            elif self.at("op", "["):
                t = self.next()
                idx = self.parse_expr()
                self.expect("op", "]")
                e = fir.Index(line=t.line, col=t.col, base=e, index=idx)
            else:
                return e

    def parse_args(self) -> List[fir.Expr]:
        args: List[fir.Expr] = []
        if not self.at("op", ")"):
            while True:
                args.append(self.parse_expr())
                if not self.accept("op", ","):
                    break
        return args

    def parse_primary(self) -> fir.Expr:
        t = self.peek()
        if t.kind == "int":
            self.next()
            return fir.IntLit(line=t.line, col=t.col, value=int(t.text))
        if t.kind == "float":
            self.next()
            return fir.FloatLit(line=t.line, col=t.col, value=float(t.text))
        if t.kind == "string":
            self.next()
            return fir.StrLit(line=t.line, col=t.col, value=t.text)
        if self.at("kw", "true") or self.at("kw", "false"):
            self.next()
            return fir.BoolLit(line=t.line, col=t.col, value=t.text == "true")
        if t.kind == "ident":
            self.next()
            if self.at("op", "("):
                self.next()
                args = self.parse_args()
                self.expect("op", ")")
                return fir.Call(line=t.line, col=t.col, func=t.text, args=args)
            return fir.Ident(line=t.line, col=t.col, name=t.text)
        if self.accept("op", "("):
            e = self.parse_expr()
            self.expect("op", ")")
            return e
        raise _err(f"expected expression, found {t!r}", t)


def parse(src: str) -> fir.Program:
    """Front-end entry point: source text -> FIR Program (the AST root)."""
    return Parser(tokenize(src)).parse_program()
