"""Lexer for the Graphitron DSL.

Produces a token stream from source text. Illegal expressions (unclosed
string constants, stray characters) raise :class:`LexError`, mirroring the
front-end behaviour described in paper §III-B1. Every token carries its
line *and* column so parse/semantic diagnostics can point at the exact
offending character (surfaced with a source excerpt by
:class:`repro.core.program.ProgramError`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

KEYWORDS = {
    "element", "end", "const", "func", "var", "if", "else", "while", "for",
    "in", "int", "float", "bool", "vertexset", "edgeset", "vector", "true",
    "false",
}

# Longest-match-first multi-character operators.
MULTI_OPS = [
    "min=", "max=", "+=", "-=", "*=", "==", "!=", "<=", ">=",
]
SINGLE_OPS = "=+-*/<>!&|;:,.()[]{}"


class LexError(SyntaxError):
    """Lexical error with a 1-based ``line``/``col`` source location."""

    def __init__(self, msg: str, line: int = 0, col: int = 0):
        super().__init__(msg)
        self.line = line
        self.col = col


@dataclass(frozen=True)
class Token:
    kind: str  # 'ident' | 'int' | 'float' | 'string' | 'kw' | 'op' | 'eof'
    text: str
    line: int
    col: int = 0  # 1-based column of the token's first character

    def __repr__(self) -> str:  # compact for error messages
        return f"{self.kind}:{self.text!r}@{self.line}:{self.col}"


def tokenize(src: str) -> List[Token]:
    toks: List[Token] = []
    i, n, line = 0, len(src), 1
    line_start = 0  # offset of the first character of the current line

    def col(at: int) -> int:
        return at - line_start + 1

    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            line_start = i
            continue
        if c in " \t\r":
            i += 1
            continue
        if c == "%":  # comment to end of line (paper Fig. 1, line 29)
            while i < n and src[i] != "\n":
                i += 1
            continue
        if c == '"':
            j = i + 1
            while j < n and src[j] != '"':
                if src[j] == "\n":
                    raise LexError(
                        f"line {line}, col {col(i)}: unclosed string constant",
                        line, col(i),
                    )
                j += 1
            if j >= n:
                raise LexError(
                    f"line {line}, col {col(i)}: unclosed string constant",
                    line, col(i),
                )
            toks.append(Token("string", src[i + 1 : j], line, col(i)))
            i = j + 1
            continue
        if c.isdigit() or (c == "." and i + 1 < n and src[i + 1].isdigit()):
            j = i
            seen_dot = False
            while j < n and (src[j].isdigit() or (src[j] == "." and not seen_dot)):
                if src[j] == ".":
                    # '1.foo' is Index-like; only consume dot if digit follows
                    if j + 1 >= n or not src[j + 1].isdigit():
                        break
                    seen_dot = True
                j += 1
            text = src[i:j]
            toks.append(Token("float" if "." in text else "int", text, line, col(i)))
            i = j
            continue
        if c.isalpha() or c == "_":
            j = i
            while j < n and (src[j].isalnum() or src[j] == "_"):
                j += 1
            text = src[i:j]
            # 'min=' / 'max=' reduce operators: ident immediately followed by '='
            if text in ("min", "max"):
                k = j
                while k < n and src[k] in " \t":
                    k += 1
                if k < n and src[k] == "=" and (k + 1 >= n or src[k + 1] != "="):
                    toks.append(Token("op", text + "=", line, col(i)))
                    i = k + 1
                    continue
            kind = "kw" if text in KEYWORDS else "ident"
            toks.append(Token(kind, text, line, col(i)))
            i = j
            continue
        matched = False
        for op in MULTI_OPS:
            if src.startswith(op, i):
                # careful: '==' must not be split; '+=' etc. are fine
                toks.append(Token("op", op, line, col(i)))
                i += len(op)
                matched = True
                break
        if matched:
            continue
        if c in SINGLE_OPS:
            toks.append(Token("op", c, line, col(i)))
            i += 1
            continue
        raise LexError(
            f"line {line}, col {col(i)}: illegal character {c!r}", line, col(i)
        )
    toks.append(Token("eof", "", line, col(i)))
    return toks
