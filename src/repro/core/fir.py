"""Front-end IR (FIR) for the Graphitron DSL.

This module is the *rigorous grammar definition* the paper calls for: every
construct the language accepts is one of the dataclasses below, and the
parser can produce nothing else.

Grammar (EBNF)
--------------

    program      ::= decl*
    decl         ::= element_decl | const_decl | func_decl
    element_decl ::= 'element' IDENT 'end'
    const_decl   ::= 'const' IDENT ':' type ('=' expr)? ';'
    type         ::= 'int' | 'float' | 'bool'
                   | 'vertexset' '{' IDENT '}'
                   | 'edgeset' '{' IDENT '}' '(' IDENT ',' IDENT (',' ('int'|'float'))? ')'
                   | 'vector' '{' IDENT '}' '(' ('int'|'float'|'bool') ')'
    func_decl    ::= 'func' IDENT '(' params? ')' stmt* 'end'
    params       ::= param (',' param)*
    param        ::= IDENT ':' (IDENT | 'int' | 'float' | 'bool')
    stmt         ::= var_decl | assign | reduce_assign | if_stmt | while_stmt
                   | for_stmt | expr_stmt
    var_decl     ::= 'var' IDENT ':' type '=' expr ';'
    assign       ::= lvalue '=' expr ';'
    reduce_assign::= lvalue ('min='|'max='|'+='|'-='|'*=') expr ';'
    lvalue       ::= IDENT ('[' expr ']')?
    if_stmt      ::= 'if' '(' expr ')' stmt* ('else' stmt*)? 'end'
    while_stmt   ::= 'while' '(' expr ')' stmt* 'end'
    for_stmt     ::= 'for' IDENT 'in' expr stmt* 'end'
    expr_stmt    ::= expr ';'
    expr         ::= or_e ;  or_e ::= and_e ('|' and_e)* ; and_e ::= cmp_e ('&' cmp_e)*
    cmp_e        ::= add_e (('=='|'!='|'<'|'<='|'>'|'>=') add_e)?
    add_e        ::= mul_e (('+'|'-') mul_e)* ; mul_e ::= unary_e (('*'|'/') unary_e)*
    unary_e      ::= ('-'|'!') unary_e | postfix_e
    postfix_e    ::= primary ( '.' IDENT '(' args? ')' | '[' expr ']' )*
    primary      ::= INT | FLOAT | 'true' | 'false' | STRING | IDENT
                   | IDENT '(' args? ')' | '(' expr ')'

Comments start with '%' and run to end of line (paper Fig. 1 line 29).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Union

# --------------------------------------------------------------------------
# Types
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ScalarType:
    kind: str  # 'int' | 'float' | 'bool'

    def __str__(self) -> str:
        return self.kind


@dataclass(frozen=True)
class VertexsetType:
    element: str  # element name, e.g. 'Vertex'

    def __str__(self) -> str:
        return f"vertexset{{{self.element}}}"


@dataclass(frozen=True)
class EdgesetType:
    element: str
    src_element: str
    dst_element: str
    weight: Optional[str] = None  # 'int' | 'float' | None

    @property
    def weighted(self) -> bool:
        return self.weight is not None

    def __str__(self) -> str:
        w = f", {self.weight}" if self.weight else ""
        return f"edgeset{{{self.element}}}({self.src_element}, {self.dst_element}{w})"


@dataclass(frozen=True)
class VectorType:
    element: str  # 'Vertex' or 'Edge' (an element name)
    scalar: str  # 'int' | 'float' | 'bool'

    def __str__(self) -> str:
        return f"vector{{{self.element}}}({self.scalar})"


@dataclass(frozen=True)
class ElementType:
    """A bare element used as a parameter type, e.g. ``v: Vertex``."""

    name: str

    def __str__(self) -> str:
        return self.name


Type = Union[ScalarType, VertexsetType, EdgesetType, VectorType, ElementType]

INT = ScalarType("int")
FLOAT = ScalarType("float")
BOOL = ScalarType("bool")

# --------------------------------------------------------------------------
# Expressions
# --------------------------------------------------------------------------


@dataclass
class Node:
    """Base FIR node: every node carries its source line/column for
    diagnostics. Both fields are ``compare=False`` and ignored by
    :func:`dump`, so provenance never perturbs MIR fingerprints."""

    line: int = field(default=0, compare=False)
    col: int = field(default=0, compare=False)


@dataclass
class Expr(Node):
    pass


@dataclass
class IntLit(Expr):
    value: int = 0


@dataclass
class FloatLit(Expr):
    value: float = 0.0


@dataclass
class BoolLit(Expr):
    value: bool = False


@dataclass
class StrLit(Expr):
    value: str = ""


@dataclass
class Ident(Expr):
    name: str = ""


@dataclass
class BinOp(Expr):
    op: str = ""  # + - * / == != < <= > >= & |
    lhs: Expr = None
    rhs: Expr = None


@dataclass
class UnaryOp(Expr):
    op: str = ""  # - !
    operand: Expr = None


@dataclass
class Index(Expr):
    base: Expr = None
    index: Expr = None


@dataclass
class Call(Expr):
    func: str = ""
    args: List[Expr] = field(default_factory=list)


@dataclass
class MethodCall(Expr):
    obj: Expr = None
    method: str = ""
    args: List[Expr] = field(default_factory=list)


# --------------------------------------------------------------------------
# Statements
# --------------------------------------------------------------------------


@dataclass
class Stmt(Node):
    pass


@dataclass
class VarDecl(Stmt):
    name: str = ""
    type: Type = None
    init: Optional[Expr] = None


@dataclass
class Assign(Stmt):
    target: Expr = None  # Ident or Index
    value: Expr = None


@dataclass
class ReduceAssign(Stmt):
    target: Expr = None
    op: str = ""  # 'min' | 'max' | '+' | '-' | '*'
    value: Expr = None


@dataclass
class If(Stmt):
    cond: Expr = None
    then_body: List[Stmt] = field(default_factory=list)
    else_body: List[Stmt] = field(default_factory=list)


@dataclass
class While(Stmt):
    cond: Expr = None
    body: List[Stmt] = field(default_factory=list)


@dataclass
class For(Stmt):
    var: str = ""
    iter: Expr = None
    body: List[Stmt] = field(default_factory=list)


@dataclass
class ExprStmt(Stmt):
    expr: Expr = None


# --------------------------------------------------------------------------
# Declarations
# --------------------------------------------------------------------------


@dataclass
class ElementDecl(Node):
    name: str = ""


@dataclass
class ConstDecl(Node):
    name: str = ""
    type: Type = None
    init: Optional[Expr] = None


@dataclass
class Param(Node):
    name: str = ""
    type: Type = None


@dataclass
class FuncDecl(Node):
    name: str = ""
    params: List[Param] = field(default_factory=list)
    body: List[Stmt] = field(default_factory=list)


@dataclass
class Program(Node):
    """FIR root node; the front-end exposes this to later phases."""

    elements: List[ElementDecl] = field(default_factory=list)
    consts: List[ConstDecl] = field(default_factory=list)
    funcs: List[FuncDecl] = field(default_factory=list)

    def func(self, name: str) -> FuncDecl:
        for f in self.funcs:
            if f.name == name:
                return f
        raise KeyError(f"no function named {name!r}")


def dump(node, indent: int = 0) -> str:
    """Human-readable FIR dump (used by tests and ``--emit=fir``)."""
    pad = "  " * indent
    if isinstance(node, Program):
        parts = [dump(e, indent) for e in node.elements]
        parts += [dump(c, indent) for c in node.consts]
        parts += [dump(f, indent) for f in node.funcs]
        return "\n".join(parts)
    if isinstance(node, ElementDecl):
        return f"{pad}element {node.name} end"
    if isinstance(node, ConstDecl):
        init = f" = {dump(node.init)}" if node.init is not None else ""
        return f"{pad}const {node.name}: {node.type}{init};"
    if isinstance(node, FuncDecl):
        ps = ", ".join(f"{p.name}: {p.type}" for p in node.params)
        body = "\n".join(dump(s, indent + 1) for s in node.body)
        return f"{pad}func {node.name}({ps})\n{body}\n{pad}end"
    if isinstance(node, VarDecl):
        return f"{pad}var {node.name}: {node.type} = {dump(node.init)};"
    if isinstance(node, Assign):
        return f"{pad}{dump(node.target)} = {dump(node.value)};"
    if isinstance(node, ReduceAssign):
        return f"{pad}{dump(node.target)} {node.op}= {dump(node.value)};"
    if isinstance(node, If):
        s = f"{pad}if ({dump(node.cond)})\n"
        s += "\n".join(dump(x, indent + 1) for x in node.then_body)
        if node.else_body:
            s += f"\n{pad}else\n" + "\n".join(dump(x, indent + 1) for x in node.else_body)
        return s + f"\n{pad}end"
    if isinstance(node, While):
        body = "\n".join(dump(x, indent + 1) for x in node.body)
        return f"{pad}while ({dump(node.cond)})\n{body}\n{pad}end"
    if isinstance(node, For):
        body = "\n".join(dump(x, indent + 1) for x in node.body)
        return f"{pad}for {node.var} in {dump(node.iter)}\n{body}\n{pad}end"
    if isinstance(node, ExprStmt):
        return f"{pad}{dump(node.expr)};"
    if isinstance(node, BinOp):
        return f"({dump(node.lhs)} {node.op} {dump(node.rhs)})"
    if isinstance(node, UnaryOp):
        return f"({node.op}{dump(node.operand)})"
    if isinstance(node, Index):
        return f"{dump(node.base)}[{dump(node.index)}]"
    if isinstance(node, Call):
        return f"{node.func}({', '.join(dump(a) for a in node.args)})"
    if isinstance(node, MethodCall):
        return f"{dump(node.obj)}.{node.method}({', '.join(dump(a) for a in node.args)})"
    if isinstance(node, Ident):
        return node.name
    if isinstance(node, (IntLit, FloatLit, BoolLit)):
        return str(node.value).lower() if isinstance(node, BoolLit) else str(node.value)
    if isinstance(node, StrLit):
        # double-quoted: the lexer only accepts " strings, so dump() output
        # stays valid Graphitron (round-trip parse(dump(p)) requires it);
        # the lexer has no escape syntax, so quotes/newlines cannot be
        # represented — reject them rather than emit unlexable text
        if '"' in node.value or "\n" in node.value:
            raise ValueError(
                f"string constant {node.value!r} cannot be dumped: the DSL "
                "has no escape syntax for '\"' or newlines"
            )
        return '"' + node.value + '"'
    raise TypeError(f"cannot dump {type(node)}")
