"""Graphitron core: the paper's DSL + compiler, lowered to JAX/Pallas."""
from .engine import Engine, EngineResult, compile_source, run_source
from .options import CompileOptions
from .parser import parse
from .semantic import analyze

__all__ = [
    "Engine",
    "EngineResult",
    "CompileOptions",
    "compile_source",
    "run_source",
    "parse",
    "analyze",
]
