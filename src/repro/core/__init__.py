"""Graphitron core: the paper's DSL + compiler, lowered to JAX/Pallas.

Public surface — compile once, bind many, run parameterized:

    import repro

    program = repro.compile(src, options)         # cached on content hash
    session = program.bind(graph)                 # or backend="distributed"
    result  = session.run(root=3, iters=20)       # validated parameters

* :class:`Program` — the compiled artifact; knows its declared run-time
  parameters (the program's host scalars) and binds to any number of
  graphs and backends.
* :class:`Session` — one (program, graph, backend) binding; owns lowered
  kernels and device state, reusable across runs.
* :class:`SessionPool` — N sessions over one bound graph for batch/async
  query serving (``batch=N`` turns on dynamic batching).
* :class:`BatchSession` — ``program.bind_batch(graph)``: K parameterized
  queries per launch set (vmapped state + bit-packed multi-source BFS),
  bit-identical to sequential runs; ``Session.run_many`` reroutes
  batch-eligible lists here automatically.
* ``backend="local"`` wraps the single-device :class:`Engine`;
  ``backend="distributed"`` wraps :class:`DistEngine` (multi-device
  shuffle supersteps). New backends plug in via
  :func:`~repro.core.session.register_backend`.

``compile_source`` / ``run_source`` and hand-built :class:`Engine` objects
remain as deprecated shims for older callers.
"""
from .accelerator import (
    Accelerator,
    AcceleratorError,
    AcceleratorReport,
    GraphShape,
    load_accelerator,
)
from .engine import Engine, EngineResult, compile_source, run_source
from .options import CompileOptions
from .parser import parse
from .passes import PassError, DEFAULT_PASSES
from .program import (
    ParamSpec,
    Program,
    ProgramError,
    clear_program_cache,
    compile_program,
    program_cache_info,
    set_program_cache_limit,
)
from .program import compile  # noqa: A004 - intentional repro.compile verb
from .target import Target
from .semantic import analyze
from .session import (
    BatchSession,
    ExecutionBackend,
    ServiceClosed,
    Session,
    SessionError,
    SessionPool,
    batch_eligible,
    register_backend,
)

__all__ = [
    "Engine",
    "EngineResult",
    "CompileOptions",
    "Target",
    "Accelerator",
    "AcceleratorError",
    "AcceleratorReport",
    "GraphShape",
    "load_accelerator",
    "PassError",
    "DEFAULT_PASSES",
    "Program",
    "ProgramError",
    "ParamSpec",
    "BatchSession",
    "Session",
    "SessionError",
    "ServiceClosed",
    "SessionPool",
    "ExecutionBackend",
    "batch_eligible",
    "compile",
    "compile_program",
    "clear_program_cache",
    "program_cache_info",
    "set_program_cache_limit",
    "register_backend",
    "compile_source",
    "run_source",
    "parse",
    "analyze",
]
