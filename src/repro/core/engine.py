"""Host driver: executes ``main()`` and launches device kernels.

This is the system-integration layer of the paper (§III-D): the FPGA build
manages accelerators through OpenCL/XRT (clSetKernelArg / clEnqueueTask /
clEnqueueMigrateMemObjects). Here the host program is interpreted in
Python, device kernels are jitted JAX executables, and host<->device data
movement is JAX array transfer. Graph loading / partitioning / property
allocation are implicit interfaces hidden from the algorithm author,
exactly as in the paper.

Engine-level optimizations:
* **hub-vertex cache** (options.cache): the graph is degree-relabeled once
  at load so hub properties occupy a dense prefix; host-side vertex ids are
  transparently translated at the host/device boundary.
* **frontier compaction** (options.compact_frontier): edge kernels guarded
  by a Frontier Check only traverse edges whose source is active, with
  power-of-two padding to keep jit cache hits high. When the frontier is
  large the engine automatically falls back to the full-edge streaming
  kernel — the direction-switching insight of paper Fig. 2 applied
  automatically.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import backend, fir, mir, semantic
from .backend import WEIGHT_KEY, DTYPES
from .options import CompileOptions
from .. import telemetry as tel
from ..graph.storage import GraphData


class EngineError(Exception):
    pass


@dataclass
class EngineStats:
    """Per-run execution counters.

    A stats object describes ONE engine run, which may answer more than one
    query: batched execution (:mod:`repro.batch`) runs K parameter bindings
    through a single set of launches and attaches the same stats object to
    all K results with ``batch_size == K``. Launch/edge counters are
    per-*batch*, never silently per-query — divide by ``batch_size`` (or use
    :meth:`per_query_launches`) when aggregating across results that may mix
    batched and sequential runs.
    """

    kernel_launches: Dict[str, int] = field(default_factory=dict)
    compacted_launches: int = 0
    full_launches: int = 0
    dist_supersteps: int = 0
    edges_traversed: int = 0
    host_iterations: int = 0
    wall_time_s: float = 0.0
    # cold-vs-warm split of wall_time_s: compile_time_s is the first-touch
    # cost of every executable this run hit for the first time in-process
    # (trace + XLA compile + its one execution); run_time_s is the warm
    # remainder. An Accelerator-backed session starts pre-warmed (AOT), so
    # warm-start wins show up directly as compile_time_s ~ 0.
    compile_time_s: float = 0.0
    run_time_s: float = 0.0
    # kernel-fusion accounting (the `fuse` MIR pass): how many launches hit
    # a fused kernel, and how many separate launches fusion saved overall
    fused_launches: int = 0
    launches_saved: int = 0
    # how many queries this run answered (1 = plain sequential run; K > 1 =
    # one batched run whose launches served K parameter bindings at once)
    batch_size: int = 1

    @property
    def total_launches(self) -> int:
        return sum(self.kernel_launches.values())

    @property
    def per_query_launches(self) -> float:
        """Launches amortized over the queries this run answered."""
        return self.total_launches / max(self.batch_size, 1)


def count_launch(stats: EngineStats, module: mir.Module, name: str) -> None:
    """Record one logical kernel launch (a fused kernel counts once, not per
    stage). Shared by the sequential engines and the batch engine so fusion
    accounting stays consistent across both run modes."""
    stats.kernel_launches[name] = stats.kernel_launches.get(name, 0) + 1
    parts = module.fusion_groups.get(name)
    if parts:
        stats.fused_launches += 1
        stats.launches_saved += len(parts) - 1


@dataclass
class EngineResult:
    properties: Dict[str, np.ndarray]
    host_env: Dict[str, Any]
    stats: EngineStats
    # graph version the query was answered against (streaming sessions pin
    # every admitted query to one version; 0 = static/unversioned binding)
    version: int = 0
    # per-run telemetry summary (repro.telemetry): aggregated span tree of
    # this run when tracing was enabled, None otherwise. Batched runs share
    # one summary object across the K results, mirroring `stats`.
    trace: Optional[Dict[str, Any]] = None


@dataclass
class BatchedLaunch:
    """One kernel launch lowered over a leading batch (query) axis.

    ``fn(state, scalars) -> updates`` where every state array carries a
    leading ``K`` axis and every scalar is a ``[K]`` array; ``bump_stats``
    applies the same counter increments the sequential engine would record
    for ONE launch (the batch engine counts a batched launch once — the
    per-query amortization lives in ``EngineStats.batch_size``).
    """

    fn: Callable[[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray]], Dict[str, jnp.ndarray]]
    bump_stats: Callable[[EngineStats], None]


def _next_pow2(n: int) -> int:
    return 1 << max(10, (max(1, n) - 1).bit_length())


class Engine:
    """Executes one compiled Graphitron module against one graph."""

    def __init__(
        self,
        module: mir.Module,
        graph: GraphData,
        options: Optional[CompileOptions] = None,
        argv: Optional[List[str]] = None,
        *,
        target=None,
        library=None,
    ):
        from .target import Target

        options = options if options is not None else CompileOptions()
        self.module = module
        self.options = options
        # the execution substrate: an explicit Target (Accelerator path) or
        # one resolved from the legacy CompileOptions substrate fields
        self.target = target if target is not None else Target.from_options(options)
        # Race-safety override: a program whose static analysis found a true
        # scatter race (GT101) is only sequentially-correct under the sorted
        # shuffle substrate — disabling shuffle on it is an ablation of
        # correctness, not of performance, so the analysis verdict wins.
        self.shuffle_forced = False
        if not self.target.shuffle:
            from ..analysis.analyses import needs_shuffle

            if needs_shuffle(module):
                import dataclasses as _dc

                self.target = _dc.replace(self.target, shuffle=True)
                self.shuffle_forced = True
        self.argv = argv or []
        self.stats = EngineStats()
        # AOT kernel library (repro.core.accelerator): shape-generic lowered
        # kernels shared by every bind of one Accelerator
        self.library = library
        if library is not None:
            library.check_graph(graph)
        # executables already compiled in-process: first-touch timing keys.
        # Library-backed engines share the library's registry, so a rebind
        # of the same accelerator starts warm.
        self._warm_keys = library.warm_keys if library is not None else set()

        # the graph as handed in (original vertex ids) — refresh_graph
        # re-derives every binding from it after an in-place mutation
        self.source_graph = graph

        # ---- hub cache: degree relabeling (paper Fig. 7(b)) ----
        if self.target.cache:
            self.graph, self.old2new = graph.relabel_by_degree()
            new2old = graph.degree_rank
        else:
            self.graph, self.old2new = graph, None
            new2old = None

        self.gb = backend._graph_bindings(self.graph, module, self.target,
                                          new2old=new2old)
        self._lowered: Dict[str, backend.LoweredKernel] = {}
        self._subset_cache: Dict[Tuple[str, int], Callable] = {}
        # per-launch batching hooks: kernel name -> BatchedLaunch (built on
        # demand by batched_runner(); driven by repro.batch.BatchEngine)
        self._batched: Dict[str, "BatchedLaunch"] = {}

        # accumulator properties are NOT vertex-indexed (no id translation)
        self.accumulator_props = set()
        for k in module.kernels.values():
            self.accumulator_props |= k.accumulators

        # ---- memory allocation (implicit interface) ----
        self.state: Dict[str, jnp.ndarray] = {}
        for p in module.properties.values():
            n = self.graph.n_edges if p.is_edge else self.graph.n_vertices
            self.state[p.name] = jnp.zeros((n,), DTYPES[p.scalar])
        for name, direction in module.degree_props.items():
            deg = self.graph.out_degree if direction == "out" else self.graph.in_degree
            dt = DTYPES[module.properties[name].scalar]
            self.state[name] = jnp.asarray(deg).astype(dt)
        if module.graph.weighted:
            w = self.graph.weights
            if w is None:
                raise EngineError("weighted edgeset but the loaded graph has no weights")
            wdt = DTYPES[module.graph.weight_scalar or "float"]
            self.state[WEIGHT_KEY] = jnp.asarray(w).astype(wdt)

        # ---- host scalar environment ----
        self.host_env: Dict[str, Any] = {}
        for s in module.scalars.values():
            self.host_env[s.name] = self._eval_host(s.init) if s.init is not None else 0

    def reset(self):
        """Reinitialize device/host state, keeping lowered (compiled)
        kernels — the repeat-run path for benchmarking and reuse."""
        module, graph = self.module, self.graph
        self.stats = EngineStats()
        for p in module.properties.values():
            n = graph.n_edges if p.is_edge else graph.n_vertices
            self.state[p.name] = jnp.zeros((n,), DTYPES[p.scalar])
        for name, direction in module.degree_props.items():
            deg = graph.out_degree if direction == "out" else graph.in_degree
            self.state[name] = jnp.asarray(deg).astype(DTYPES[module.properties[name].scalar])
        if module.graph.weighted:
            wdt = DTYPES[module.graph.weight_scalar or "float"]
            self.state[WEIGHT_KEY] = jnp.asarray(graph.weights).astype(wdt)
        self.host_env = {}
        for s in module.scalars.values():
            self.host_env[s.name] = self._eval_host(s.init) if s.init is not None else 0

    def refresh_graph(self, graph: Optional[GraphData] = None):
        """Re-derive every graph-dependent binding after an in-place update.

        The streaming path mutates ``GraphData`` arrays in place
        (:meth:`GraphData.apply_updates`), which invalidates the hub
        relabeling, the burst processing order and every CSR/CSC binding
        this engine captured at construction. Because the physical shape is
        unchanged (same bucket), library-backed engines keep their AOT
        executables — graph arrays are traced arguments there, so the
        refresh costs no recompilation (``compile_time_s`` stays 0). Plain
        engines close graph constants into their jits and must re-lower;
        their first-touch timing keys are reset so the recompile is
        reported honestly.
        """
        graph = graph if graph is not None else self.source_graph
        self.source_graph = graph
        if self.library is not None:
            self.library.check_graph(graph)
        if self.target.cache:
            self.graph, self.old2new = graph.relabel_by_degree()
            new2old = graph.degree_rank
        else:
            self.graph, self.old2new = graph, None
            new2old = None
        self.gb = backend._graph_bindings(self.graph, self.module, self.target,
                                          new2old=new2old)
        # closures over the old gb arrays; rebuilt on demand (cheap binds
        # over the shared library, fresh jits otherwise)
        self._lowered.clear()
        self._subset_cache.clear()
        self._batched.clear()
        for attr in ("_build_batch", "_deg_np"):
            if hasattr(self, attr):
                delattr(self, attr)
        if self.library is None:
            # non-library jits captured graph constants: the rebuilt ones
            # recompile, so nothing is warm anymore
            self._warm_keys.clear()
        self.reset()

    # ------------------------------------------------------------------
    # vertex id translation at the host/device boundary
    # ------------------------------------------------------------------
    def _xlate(self, prop: str, idx: int) -> int:
        info = self.module.properties[prop]
        if (
            self.old2new is not None
            and not info.is_edge
            and prop not in self.accumulator_props
            and prop not in self.module.degree_props
        ):
            return int(self.old2new[idx])
        return int(idx)

    # ------------------------------------------------------------------
    # kernel launching
    # ------------------------------------------------------------------
    def _kernel(self, name: str) -> backend.LoweredKernel:
        if name not in self._lowered:
            k = self.module.kernels.get(name)
            if k is None:
                raise EngineError(f"{name!r} is not a device kernel")
            if self.library is not None:
                self._lowered[name] = self.library.kernel_for(name, self.gb)
            else:
                self._lowered[name] = backend.lower_kernel(
                    self.module, k, self.gb, self.target
                )
        return self._lowered[name]

    def _timed_call(self, key, fn, *args):
        """Call ``fn``; attribute a first-touch (cold) call's wall time to
        ``stats.compile_time_s``. The warm-key registry survives reset()
        (kernels stay compiled) and is shared across binds when a kernel
        library backs this engine."""
        if key in self._warm_keys:
            return fn(*args)
        t0 = time.perf_counter()
        try:
            return fn(*args)
        finally:
            self.stats.compile_time_s += time.perf_counter() - t0
            self._warm_keys.add(key)

    def _kernel_scalars(self, name: str) -> Dict[str, jnp.ndarray]:
        k = self.module.kernels[name]
        out = {}
        for s in sorted(k.scalar_reads):
            info = self.module.scalars[s]
            out[s] = jnp.asarray(self.host_env[s], DTYPES[info.scalar])
        return out

    def launch(self, name: str):
        kern = self.module.kernels.get(name)
        if kern is None:
            raise EngineError(f"{name!r} is not a device kernel")
        self._count_launch(name, kern)
        tr = tel.get()
        if not tr.enabled:  # hot path: one attribute check when untraced
            self._execute_kernel(name, kern)
            return
        direction = getattr(kern, "direction", None)
        with tr.span(
            "launch:" + name,
            kernel=name,
            kind=kern.kind.name.lower(),
            direction=direction.name.lower() if direction is not None else None,
        ) as sp:
            self._execute_kernel(name, kern, sp)

    # -- per-launch batching hook (repro.batch) -------------------------------
    def batched_runner(self, name: str) -> "BatchedLaunch":
        """Return the batch-axis executable for kernel ``name``.

        The returned :class:`BatchedLaunch` runs one logical launch over a
        leading query axis: state arrays are ``[K, n]``, scalar arrays are
        ``[K]``, and the per-lane results are bit-identical to ``K``
        independent sequential launches (vmap semantics). Subclasses
        (e.g. :class:`~repro.core.dist_engine.DistEngine`) override this to
        batch their own launch strategy — the shared contract is only
        ``fn(state, scalars) -> updates`` plus honest stats accounting.
        """
        bl = self._batched.get(name)
        if bl is None:
            kern = self.module.kernels.get(name)
            if kern is None:
                raise EngineError(f"{name!r} is not a device kernel")
            if self.library is not None:
                # library-shared vmap trace: rebinds of one accelerator
                # reuse every batch-size compilation (and the shared
                # warm-key registry stays honest about it)
                fn = self.library.batched_for(name, self.gb)
            else:
                fn = backend.lower_kernel_batched(self._kernel(name))
            bl = self._batched[name] = BatchedLaunch(
                fn=fn,
                bump_stats=self._full_stats_bump(kern),
            )
        return bl

    def _full_stats_bump(self, kern) -> Callable[[EngineStats], None]:
        """Stats increment matching one full-stream launch of ``kern``."""
        n_edges = self.graph.n_edges
        if kern.kind is mir.KernelKind.EDGE:
            edges = n_edges
        elif isinstance(kern, mir.PipelineKernel):
            edges = n_edges * len(kern.edge_stages)
        else:
            edges = 0

        def bump(stats: EngineStats) -> None:
            stats.full_launches += 1
            stats.edges_traversed += edges

        return bump

    def _count_launch(self, name: str, kern):
        """One logical launch (a fused kernel counts once, not per stage)."""
        count_launch(self.stats, self.module, name)

    def _execute_kernel(self, name: str, kern, sp=tel.NULL_SPAN):
        lk = self._kernel(name)
        scalars = self._kernel_scalars(name)
        if (
            self.target.compact_frontier
            and kern.kind is mir.KernelKind.EDGE
            # DENSE = compile-time verdict that the guard is loop-invariant:
            # skip host-side frontier mask evaluation entirely
            and kern.direction is not mir.Direction.DENSE
            and lk.frontier is not None
            and lk.run_subset is not None
        ):
            launched = self._launch_compacted_edge(lk, kern, scalars, sp)
            if launched:
                return
        self.stats.full_launches += 1
        edges = 0
        if kern.kind is mir.KernelKind.EDGE:
            edges = self.graph.n_edges
        elif isinstance(kern, mir.PipelineKernel):
            edges = self.graph.n_edges * len(kern.edge_stages)
        self.stats.edges_traversed += edges
        sp.set(mode="full", edges=edges)
        updates = self._timed_call(("full", name), lk.run_full, self.state, scalars)
        self.state.update(updates)

    # -- frontier compaction (direction optimization, engine-automatic) ----
    def _batch_builder(self):
        """Frontier expansion bound to this graph's arrays.

        The expansion math lives once, shape-generic, in
        :func:`backend.make_frontier_builder`; library-backed engines share
        the accelerator's builder (so same-bucket rebinds reuse every
        compiled (pad_v, pad_e) bucket), plain engines build their own.
        """
        if hasattr(self, "_build_batch"):
            return self._build_batch
        gb = self.gb
        indptr, _, _ = self.graph.csr
        deg_dev = jnp.asarray(np.diff(indptr).astype(np.int32))
        starts_dev = jnp.asarray(indptr[:-1].astype(np.int32))
        if self.library is not None:
            generic = self.library.frontier_builder()
        else:
            generic = backend.make_frontier_builder(
                self.graph.n_vertices, self.graph.n_edges,
                self.module.graph.weighted,
            )

        def build(mask, weights, pad_v, pad_e):
            return generic(
                deg_dev, starts_dev, gb["csr_indices"], gb["csr_eids"],
                mask, weights, pad_v=pad_v, pad_e=pad_e,
            )

        self._build_batch = build
        return build

    def _launch_compacted_edge(self, lk, kern: mir.Kernel, scalars,
                               sp=tel.NULL_SPAN) -> bool:
        mask = self._vertex_mask_host(kern, lk.frontier.cond)
        if mask is None:
            return False
        if not hasattr(self, "_deg_np"):
            indptr, _, _ = self.graph.csr
            self._deg_np = np.diff(indptr)
        n_active = int(mask.sum())
        n_active_edges = int(self._deg_np[mask].sum())
        # heuristic switch: large frontiers stream the whole edge list
        if n_active_edges > self.graph.n_edges // 4:
            return False
        pad_v = _next_pow2(n_active)
        pad_e = _next_pow2(n_active_edges)
        if pad_e > self.graph.n_edges:
            return False
        sp.set(
            mode="compacted", edges=n_active_edges, frontier_size=n_active,
            frontier_occupancy=round(n_active / max(1, self.graph.n_vertices), 6),
            pad_v=pad_v, pad_e=pad_e,
        )
        weights = self.state.get(WEIGHT_KEY, jnp.zeros((1,), jnp.float32))
        batch = self._timed_call(
            ("fbuild", pad_v, pad_e),
            self._batch_builder(), jnp.asarray(mask), weights, pad_v, pad_e,
        )
        updates = self._timed_call(
            ("subset", kern.name, pad_v, pad_e),
            lk.run_subset, self.state, scalars, batch,
        )
        self.state.update(updates)
        self.stats.compacted_launches += 1
        self.stats.edges_traversed += n_active_edges
        return True

    def _vertex_mask_host(self, kern: mir.Kernel, cond: fir.Expr) -> Optional[np.ndarray]:
        """Evaluate a frontier condition per-vertex on the host (numpy)."""

        def ev(e: fir.Expr):
            if isinstance(e, fir.IntLit):
                return e.value
            if isinstance(e, fir.FloatLit):
                return e.value
            if isinstance(e, fir.BoolLit):
                return e.value
            if isinstance(e, fir.Ident):
                if e.name in self.host_env:
                    return self.host_env[e.name]
                raise EngineError(f"frontier cond references {e.name!r}")
            if isinstance(e, fir.Index) and isinstance(e.base, fir.Ident):
                prop = e.base.name
                idx = e.index
                if isinstance(idx, fir.Ident) and idx.name in (
                    kern.src_param,
                    kern.vertex_param,
                ):
                    return np.asarray(self.state[prop])
                raise EngineError("frontier cond must index by src/v")
            if isinstance(e, fir.BinOp):
                a, b = ev(e.lhs), ev(e.rhs)
                return {
                    "+": lambda: a + b, "-": lambda: a - b, "*": lambda: a * b,
                    "/": lambda: a / b, "==": lambda: a == b, "!=": lambda: a != b,
                    "<": lambda: a < b, "<=": lambda: a <= b, ">": lambda: a > b,
                    ">=": lambda: a >= b,
                    "&": lambda: np.logical_and(a, b),
                    "|": lambda: np.logical_or(a, b),
                }[e.op]()
            if isinstance(e, fir.UnaryOp):
                v = ev(e.operand)
                return np.logical_not(v) if e.op == "!" else -v
            raise EngineError("unsupported frontier expression")

        try:
            mask = ev(cond)
        except EngineError:
            return None
        mask = np.asarray(mask)
        if mask.ndim != 1:
            return None
        return mask

    # ------------------------------------------------------------------
    # host program interpretation
    # ------------------------------------------------------------------
    def run(self) -> EngineResult:
        t0 = time.perf_counter()
        host = self.module.host
        assert host is not None
        tr = tel.get()
        root_ctx = None
        if tr.enabled:
            with tr.span("run", engine=type(self).__name__,
                         target=self.target.kind, batch_size=1) as sp:
                self._exec_host_block(host.main.body)
                sp.set(launches=self.stats.total_launches,
                       compacted=self.stats.compacted_launches,
                       full=self.stats.full_launches,
                       supersteps=self.stats.dist_supersteps)
            root_ctx = sp.context()
        else:
            self._exec_host_block(host.main.body)
        self.stats.wall_time_s = time.perf_counter() - t0
        self.stats.run_time_s = max(
            0.0, self.stats.wall_time_s - self.stats.compile_time_s
        )
        props = {}
        for p in self.module.properties.values():
            arr = np.asarray(self.state[p.name])
            if (
                self.old2new is not None
                and not p.is_edge
                and p.name not in self.accumulator_props
            ):
                arr = arr[self.old2new]
            props[p.name] = arr
        if WEIGHT_KEY in self.state:
            props["weight"] = np.asarray(self.state[WEIGHT_KEY])
        result = EngineResult(
            properties=props, host_env=dict(self.host_env), stats=self.stats
        )
        if root_ctx is not None:
            result.trace = tr.summarize(root=root_ctx)
        return result

    def _exec_host_block(self, body: List[fir.Stmt]):
        for st in body:
            self._exec_host_stmt(st)

    def _exec_host_stmt(self, st: fir.Stmt):
        if isinstance(st, fir.VarDecl):
            self.host_env[st.name] = (
                self._eval_host(st.init) if st.init is not None else 0
            )
            return
        if isinstance(st, fir.Assign):
            tgt = st.target
            val = self._eval_host(st.value)
            if isinstance(tgt, fir.Ident):
                self.host_env[tgt.name] = val
                return
            if isinstance(tgt, fir.Index) and isinstance(tgt.base, fir.Ident):
                prop = tgt.base.name
                if prop not in self.module.properties:
                    raise EngineError(f"host write to unknown property {prop!r}")
                i = self._xlate(prop, int(self._eval_host(tgt.index)))
                dt = self.state[prop].dtype
                self.state[prop] = self.state[prop].at[i].set(jnp.asarray(val, dt))
                return
            raise EngineError("unsupported host assignment")
        if isinstance(st, fir.ReduceAssign):
            # host scalar reduce: level += 1
            tgt = st.target
            if isinstance(tgt, fir.Ident):
                cur = self.host_env[tgt.name]
                val = self._eval_host(st.value)
                self.host_env[tgt.name] = {
                    "+": cur + val, "-": cur - val, "*": cur * val,
                    "min": min(cur, val), "max": max(cur, val),
                }[st.op]
                return
            if isinstance(tgt, fir.Index) and isinstance(tgt.base, fir.Ident):
                prop = tgt.base.name
                i = self._xlate(prop, int(self._eval_host(tgt.index)))
                cur = self.state[prop]
                val = jnp.asarray(self._eval_host(st.value), cur.dtype)
                if st.op == "+":
                    self.state[prop] = cur.at[i].add(val)
                elif st.op == "min":
                    self.state[prop] = cur.at[i].min(val)
                elif st.op == "max":
                    self.state[prop] = cur.at[i].max(val)
                elif st.op == "*":
                    self.state[prop] = cur.at[i].mul(val)
                else:
                    raise EngineError(f"host reduce {st.op!r}")
                return
            raise EngineError("unsupported host reduce target")
        if isinstance(st, fir.If):
            if self._truthy(self._eval_host(st.cond)):
                self._exec_host_block(st.then_body)
            else:
                self._exec_host_block(st.else_body)
            return
        if isinstance(st, fir.While):
            guard = 0
            while self._truthy(self._eval_host(st.cond)):
                self.stats.host_iterations += 1
                self._exec_host_block(st.body)
                guard += 1
                if guard > 1_000_000:
                    raise EngineError("host while loop exceeded 1e6 iterations")
            return
        if isinstance(st, fir.ExprStmt):
            self._eval_host(st.expr)
            return
        if isinstance(st, fir.For):
            raise EngineError("host for loops are not part of the grammar")
        raise EngineError(f"unsupported host statement {type(st).__name__}")

    @staticmethod
    def _truthy(v) -> bool:
        return bool(np.asarray(v).item() if hasattr(v, "item") else v)

    def _eval_host(self, e: Optional[fir.Expr]):
        if e is None:
            return None
        if isinstance(e, fir.IntLit):
            return e.value
        if isinstance(e, fir.FloatLit):
            return e.value
        if isinstance(e, fir.BoolLit):
            return e.value
        if isinstance(e, fir.StrLit):
            return e.value
        if isinstance(e, fir.Ident):
            if e.name in self.host_env:
                return self.host_env[e.name]
            if e.name == "argv":
                return self.argv
            raise EngineError(f"unknown host identifier {e.name!r}")
        if isinstance(e, fir.Index):
            base = e.base
            if isinstance(base, fir.Ident) and base.name in self.module.properties:
                i = self._xlate(base.name, int(self._eval_host(e.index)))
                return np.asarray(self.state[base.name][i]).item()
            if isinstance(base, fir.Ident) and base.name == "argv":
                return self.argv[int(self._eval_host(e.index))]
            seq = self._eval_host(base)
            return seq[int(self._eval_host(e.index))]
        if isinstance(e, fir.BinOp):
            a = self._eval_host(e.lhs)
            b = self._eval_host(e.rhs)
            return {
                "+": lambda: a + b, "-": lambda: a - b, "*": lambda: a * b,
                "/": lambda: a / b, "==": lambda: a == b, "!=": lambda: a != b,
                "<": lambda: a < b, "<=": lambda: a <= b, ">": lambda: a > b,
                ">=": lambda: a >= b, "&": lambda: bool(a) and bool(b),
                "|": lambda: bool(a) or bool(b),
            }[e.op]()
        if isinstance(e, fir.UnaryOp):
            v = self._eval_host(e.operand)
            return (not v) if e.op == "!" else -v
        if isinstance(e, fir.Call):
            return self._host_call(e)
        if isinstance(e, fir.MethodCall):
            return self._host_method(e)
        raise EngineError(f"cannot evaluate host expression {type(e).__name__}")

    def _host_call(self, e: fir.Call):
        if e.func == "load":
            return None  # graph loading happened at engine construction
        if e.func == "swap":
            a, b = e.args
            an, bn = a.name, b.name  # type: ignore[attr-defined]
            self.state[an], self.state[bn] = self.state[bn], self.state[an]
            return None
        if e.func == "print":
            print(*[self._eval_host(a) for a in e.args])
            return None
        if e.func in self.module.host.host_funcs:
            self._exec_host_block(self.module.host.host_funcs[e.func].body)
            return None
        if e.func in semantic.DEVICE_BUILTINS:
            import math

            args = [self._eval_host(a) for a in e.args]
            fns = {
                "exp": math.exp, "log": math.log, "abs": abs, "sqrt": math.sqrt,
                "min": min, "max": max, "floor": math.floor, "pow": pow,
                "to_float": float, "to_int": int,
                "sigmoid": lambda x: 1.0 / (1.0 + math.exp(-x)),
                "leakyrelu": lambda x, a: x if x > 0 else a * x,
            }
            return fns[e.func](*args)
        raise EngineError(f"unknown host function {e.func!r}")

    def _host_method(self, e: fir.MethodCall):
        obj = e.obj
        name = obj.name if isinstance(obj, fir.Ident) else None
        g = self.module.graph
        if e.method == "size":
            # logical counts: padding (isolated vertices + self-loops) and
            # free update slots are invisible to size()-normalized math
            if name == g.edgeset_name:
                return self.graph.n_edges_logical
            return self.graph.n_vertices_logical
        if e.method in ("init", "process"):
            fn = e.args[0]
            if not isinstance(fn, fir.Ident):
                raise EngineError("init/process expects a function name")
            self.launch(fn.name)
            return None
        if e.method == "getVertices":
            return None  # vertexset binding is implicit
        if e.method in ("getOutDegrees", "getInDegrees"):
            return None  # handled at allocation time
        raise EngineError(f"unknown host method {e.method!r}")


# ---------------------------------------------------------------------------
# deprecated one-call shims (use repro.compile(...).bind(...).run(...))
# ---------------------------------------------------------------------------


def compile_source(src: str) -> mir.Module:
    """Deprecated: use ``repro.compile(src)`` which returns a cached
    :class:`~repro.core.program.Program` (this shim shares its cache)."""
    from .program import compile_program

    return compile_program(src).module


def run_source(
    src: str,
    graph: GraphData,
    options: Optional[CompileOptions] = None,
    argv: Optional[List[str]] = None,
) -> EngineResult:
    """Deprecated: use ``repro.compile(src, options).bind(graph).run(...)``."""
    from .program import compile_program

    module = compile_program(src, options).module
    return Engine(module, graph, options, argv=argv).run()
