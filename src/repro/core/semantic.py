"""Middle-end semantic analysis: FIR -> MIR.

Performs (paper §III-B2):
* symbol-table construction and kernel classification,
* type/arity checking of known operators and builtins,
* the *Property Detector* (reads/writes, index patterns, reduce ops),
* memory planning (buffer per property, host/device placement),
* MIR transforms:
    - read-modify-write normalization (``P[0] = P[0] + x`` -> ``P[0] += x``),
      the unroll-with-reduce transform of §III-C2;
    - RAW decoupling detection (paper Fig. 5 -> Fig. 6): a property read on
      the gather side and reduce-written on the scatter side of one kernel
      is snapshot-decoupled;
    - frontier detection (the *Frontier Check* module of Fig. 4).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set

from . import fir, mir

DEVICE_BUILTINS = {
    "exp": 1, "log": 1, "abs": 1, "sqrt": 1, "sigmoid": 1,
    "leakyrelu": 2, "min": 2, "max": 2, "floor": 1, "pow": 2,
    "to_float": 1, "to_int": 1, "original_id": 1,
}
HOST_BUILTINS = {"load": None, "swap": 2, "print": None, "argv": None}


class SemanticError(Exception):
    """Semantic error carrying the 1-based source ``line``/``col`` of the
    offending FIR node (the parser threads both through every node it
    builds). For programs built by the embedded front-end the line is the
    Python line number of the offending decorated-function statement and
    ``col`` is 0 (Python ASTs are lowered per-statement, not per-token)."""

    def __init__(self, msg: str, line: int = 0, col: int = 0):
        super().__init__(msg)
        self.line = line
        self.col = col


def _serr(msg: str, node) -> SemanticError:
    line = getattr(node, "line", 0) or 0
    col = getattr(node, "col", 0) or 0
    prefix = f"line {line}: " if line else ""
    return SemanticError(prefix + msg, line, col)


def _index_pattern(idx: fir.Expr, k: mir.Kernel, loop_vars: Set[str]) -> mir.IndexPattern:
    if isinstance(idx, fir.IntLit):
        return mir.IndexPattern.CONST
    if isinstance(idx, fir.Ident):
        if idx.name == k.vertex_param:
            return mir.IndexPattern.SELF
        if idx.name == k.src_param:
            return mir.IndexPattern.SRC
        if idx.name == k.dst_param:
            return mir.IndexPattern.DST
        if idx.name in loop_vars:
            return mir.IndexPattern.NEIGHBOR
    return mir.IndexPattern.OTHER


class Analyzer:
    def __init__(self, program: fir.Program):
        self.program = program
        self.module: Optional[mir.Module] = None

    # ------------------------------------------------------------------
    def analyze(self) -> mir.Module:
        prog = self.program
        elements = {e.name for e in prog.elements}
        graph: Optional[mir.GraphInfo] = None
        properties: Dict[str, mir.PropertyInfo] = {}
        scalars: Dict[str, mir.ScalarInfo] = {}
        degree_props: Dict[str, str] = {}
        vertexset_name: Optional[str] = None

        for c in prog.consts:
            t = c.type
            if isinstance(t, fir.EdgesetType):
                if t.element not in elements:
                    raise _serr(f"unknown element {t.element!r}", c)
                load_args: List[fir.Expr] = []
                if isinstance(c.init, fir.Call) and c.init.func == "load":
                    load_args = c.init.args
                graph = mir.GraphInfo(
                    edgeset_name=c.name,
                    vertexset_name=None,
                    weighted=t.weighted,
                    weight_scalar=t.weight,
                    load_args=load_args,
                )
            elif isinstance(t, fir.VertexsetType):
                vertexset_name = c.name
            elif isinstance(t, fir.VectorType):
                if t.element not in elements:
                    raise _serr(f"unknown element {t.element!r}", c)
                is_edge = t.element.lower().startswith("edge")
                properties[c.name] = mir.PropertyInfo(c.name, t.element, t.scalar, is_edge)
                if isinstance(c.init, fir.MethodCall) and c.init.method in (
                    "getOutDegrees",
                    "getInDegrees",
                ):
                    degree_props[c.name] = "out" if c.init.method == "getOutDegrees" else "in"
            elif isinstance(t, fir.ScalarType):
                scalars[c.name] = mir.ScalarInfo(c.name, t.kind, c.init)
            else:
                raise _serr(f"unsupported const type {t}", c)

        if graph is None:
            raise SemanticError("program declares no edgeset")
        graph.vertexset_name = vertexset_name

        module = mir.Module(
            program=prog,
            graph=graph,
            properties=properties,
            scalars=scalars,
            degree_props=degree_props,
        )
        for p in properties.values():
            module.memory.add(p)

        host_funcs: Dict[str, fir.FuncDecl] = {}
        main_func: Optional[fir.FuncDecl] = None
        for f in prog.funcs:
            kind, kernel = self._classify(f, elements, module)
            if kind is mir.KernelKind.HOST:
                if f.name == "main":
                    main_func = f
                else:
                    host_funcs[f.name] = f
            else:
                module.kernels[f.name] = kernel

        if main_func is None:
            raise SemanticError("program has no main()")
        module.host = mir.HostProgram(main=main_func, host_funcs=host_funcs)

        for k in module.kernels.values():
            self._normalize_rmw(k.func.body, module)
            self._detect_properties(k, module)
            self._detect_frontier(k, module)
            self._decouple_raw(k)
        return module

    # ------------------------------------------------------------------
    def _classify(self, f: fir.FuncDecl, elements: Set[str], module: mir.Module):
        ptypes = [p.type for p in f.params]

        def is_vertex(t) -> bool:
            return isinstance(t, fir.ElementType) and t.name in elements and \
                t.name.lower().startswith("vertex")

        if len(f.params) == 0:
            return mir.KernelKind.HOST, None
        if len(f.params) == 1 and is_vertex(ptypes[0]):
            k = mir.Kernel(f.name, mir.KernelKind.VERTEX, f, vertex_param=f.params[0].name)
            return mir.KernelKind.VERTEX, k
        if len(f.params) in (2, 3) and is_vertex(ptypes[0]) and is_vertex(ptypes[1]):
            wp = None
            if len(f.params) == 3:
                t2 = ptypes[2]
                if not (isinstance(t2, fir.ScalarType) and t2.kind in ("int", "float")):
                    raise _serr("edge weight param must be int/float", f)
                if not module.graph.weighted:
                    raise _serr(
                        f"weighted edge function {f.name!r} on an "
                        "unweighted edgeset", f
                    )
                wp = f.params[2].name
            k = mir.Kernel(
                f.name,
                mir.KernelKind.EDGE,
                f,
                src_param=f.params[0].name,
                dst_param=f.params[1].name,
                weight_param=wp,
            )
            return mir.KernelKind.EDGE, k
        raise _serr(
            f"cannot classify function {f.name!r} "
            f"(params must be (Vertex), (Vertex, Vertex[, int|float]), or ())", f
        )

    # ------------------------------------------------------------------
    def _normalize_rmw(self, body: List[fir.Stmt], module: mir.Module):
        """Rewrite ``P[i] = P[i] op x`` into ``P[i] op= x`` (§III-C2).

        This exposes the reduction so the back-end can lower it as a
        conflict-free parallel reduce instead of a serialized RMW.
        """

        def same_index(a: fir.Expr, b: fir.Expr) -> bool:
            if isinstance(a, fir.IntLit) and isinstance(b, fir.IntLit):
                return a.value == b.value
            if isinstance(a, fir.Ident) and isinstance(b, fir.Ident):
                return a.name == b.name
            return False

        for i, st in enumerate(body):
            if isinstance(st, fir.If):
                self._normalize_rmw(st.then_body, module)
                self._normalize_rmw(st.else_body, module)
            elif isinstance(st, (fir.While, fir.For)):
                self._normalize_rmw(st.body, module)
            elif isinstance(st, fir.Assign) and isinstance(st.target, fir.Index):
                tgt = st.target
                if not (isinstance(tgt.base, fir.Ident) and tgt.base.name in module.properties):
                    continue
                v = st.value
                if isinstance(v, fir.BinOp) and v.op in ("+", "*"):
                    for lhs, rhs in ((v.lhs, v.rhs), (v.rhs, v.lhs)):
                        if (
                            isinstance(lhs, fir.Index)
                            and isinstance(lhs.base, fir.Ident)
                            and lhs.base.name == tgt.base.name
                            and same_index(lhs.index, tgt.index)
                        ):
                            body[i] = fir.ReduceAssign(
                                line=st.line, col=st.col, target=tgt,
                                op=v.op, value=rhs,
                            )
                            break

    # ------------------------------------------------------------------
    def _detect_properties(self, k: mir.Kernel, module: mir.Module):
        """The Property Detector: collect every property access."""
        props = module.properties
        loop_vars: Set[str] = set()

        def walk_expr(e: fir.Expr):
            if e is None:
                return
            if isinstance(e, fir.Index) and isinstance(e.base, fir.Ident) and e.base.name in props:
                k.reads.append(
                    mir.PropAccess(e.base.name, _index_pattern(e.index, k, loop_vars))
                )
                walk_expr(e.index)
                return
            if isinstance(e, fir.Ident):
                if e.name in module.scalars:
                    k.scalar_reads.add(e.name)
                return
            if isinstance(e, fir.BinOp):
                walk_expr(e.lhs)
                walk_expr(e.rhs)
            elif isinstance(e, fir.UnaryOp):
                walk_expr(e.operand)
            elif isinstance(e, fir.Index):
                walk_expr(e.base)
                walk_expr(e.index)
            elif isinstance(e, fir.Call):
                if e.func in DEVICE_BUILTINS and DEVICE_BUILTINS[e.func] != len(e.args):
                    raise _serr(
                        f"builtin {e.func}() takes "
                        f"{DEVICE_BUILTINS[e.func]} args, got {len(e.args)}", e
                    )
                for a in e.args:
                    walk_expr(a)
            elif isinstance(e, fir.MethodCall):
                walk_expr(e.obj)
                for a in e.args:
                    walk_expr(a)

        def record_write(target: fir.Expr, op: Optional[str], st: fir.Stmt):
            if isinstance(target, fir.Index) and isinstance(target.base, fir.Ident):
                name = target.base.name
                if name in props:
                    pat = _index_pattern(target.index, k, loop_vars)
                    k.writes.append(mir.PropAccess(name, pat, op))
                    if pat is mir.IndexPattern.CONST:
                        k.accumulators.add(name)
                    walk_expr(target.index)
                    return
            if isinstance(target, fir.Ident):
                if target.name == k.weight_param:
                    k.writes_weight = True
                    return
                return  # local variable
            raise _serr("unsupported write target", st)

        def walk_stmts(body: List[fir.Stmt]):
            for st in body:
                if isinstance(st, fir.Assign):
                    record_write(st.target, None, st)
                    walk_expr(st.value)
                elif isinstance(st, fir.ReduceAssign):
                    record_write(st.target, st.op, st)
                    walk_expr(st.value)
                elif isinstance(st, fir.VarDecl):
                    walk_expr(st.init)
                elif isinstance(st, fir.If):
                    walk_expr(st.cond)
                    walk_stmts(st.then_body)
                    walk_stmts(st.else_body)
                elif isinstance(st, fir.For):
                    if (
                        isinstance(st.iter, fir.MethodCall)
                        and st.iter.method in ("getNeighbors", "getInNeighbors")
                    ):
                        k.has_neighbor_loop = True
                        loop_vars.add(st.var)
                        walk_stmts(st.body)
                        loop_vars.discard(st.var)
                    else:
                        raise _serr(
                            "device for-loops must iterate "
                            "v.getNeighbors()/v.getInNeighbors()", st
                        )
                elif isinstance(st, fir.While):
                    raise _serr("while loops are host-only constructs", st)
                elif isinstance(st, fir.ExprStmt):
                    walk_expr(st.expr)

        walk_stmts(k.func.body)

    # ------------------------------------------------------------------
    def _detect_frontier(self, k: mir.Kernel, module: mir.Module):
        """Frontier Check: single top-level guard reading gather-side props."""
        body = [s for s in k.func.body]
        if len(body) != 1 or not isinstance(body[0], fir.If) or body[0].else_body:
            return
        cond = body[0].cond
        props: Set[str] = set()
        ok = True

        def scan(e: fir.Expr):
            nonlocal ok
            if e is None or not ok:
                return
            if isinstance(e, fir.Index) and isinstance(e.base, fir.Ident) and \
                    e.base.name in module.properties:
                pat = _index_pattern(e.index, k, set())
                if pat in (mir.IndexPattern.SELF, mir.IndexPattern.SRC):
                    props.add(e.base.name)
                else:
                    ok = False
                return
            if isinstance(e, fir.BinOp):
                scan(e.lhs)
                scan(e.rhs)
            elif isinstance(e, fir.UnaryOp):
                scan(e.operand)
            elif isinstance(e, (fir.IntLit, fir.FloatLit, fir.BoolLit, fir.Ident)):
                return
            else:
                ok = False

        scan(cond)
        if ok and props:
            k.frontier = mir.FrontierInfo(cond=cond, props=props)

    # ------------------------------------------------------------------
    def _decouple_raw(self, k: mir.Kernel):
        """RAW decoupling (Fig. 5 -> Fig. 6): snapshot gather-side reads of
        properties that are also scatter-written in the same kernel."""
        gather_reads = {
            r.prop
            for r in k.reads
            if r.pattern in (mir.IndexPattern.SRC, mir.IndexPattern.SELF,
                             mir.IndexPattern.NEIGHBOR)
        }
        scatter_writes = {
            w.prop
            for w in k.writes
            if w.pattern in (mir.IndexPattern.DST, mir.IndexPattern.NEIGHBOR,
                             mir.IndexPattern.OTHER)
        }
        k.snapshot_props = gather_reads & scatter_writes


def analyze(program: fir.Program) -> mir.Module:
    return Analyzer(program).analyze()


def reanalyze_kernel(k: mir.Kernel, module: mir.Module) -> mir.Kernel:
    """Re-run the per-kernel detectors after a pass mutated the body.

    Optimization passes (``repro.core.passes``) rewrite kernel bodies —
    constant folding substitutes literals, dead-property elimination strips
    writes, fusion concatenates bodies. Afterwards the Property Detector
    results, frontier annotation, and RAW decoupling must be recomputed so
    the back-end lowers the *transformed* body, not stale metadata.
    """
    k.reads = []
    k.writes = []
    k.scalar_reads = set()
    k.accumulators = set()
    k.snapshot_props = set()
    k.frontier = None
    k.has_neighbor_loop = False
    k.writes_weight = False
    a = Analyzer(module.program)
    a._normalize_rmw(k.func.body, module)
    a._detect_properties(k, module)
    a._detect_frontier(k, module)
    a._decouple_raw(k)
    return k
