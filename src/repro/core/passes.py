"""MIR optimization pass pipeline (between semantic analysis and lowering).

The FPGA frameworks Graphitron is measured against (HitGraph, ThunderGP,
GraVF-M) bake one fixed hardware pipeline that every algorithm must fit.
Graphitron's claim is the inverse: algorithm-independent optimizations are
*derived per program* by the compiler. This module is that derivation
step — an ordered, introspectable pass manager running over the analyzed
:class:`~repro.core.mir.Module` before any kernel is lowered:

``fold``
    Host constant folding. Scalars bound at compile time via
    ``CompileOptions.scalar_bindings`` are substituted as literals into
    every kernel and host expression, then literal subexpressions are
    simplified (``(1.0 - 0.85)`` -> ``0.15``; ``if (false) ...`` bodies
    drop out entirely). Bound scalars stop being run-time parameters.

``dce``
    Dead property / scalar elimination driven by the
    :class:`~repro.core.mir.MemoryPlan`: properties never accessed by any
    kernel or host statement lose their device buffer (channels are
    renumbered densely), scalars that nothing reads or writes disappear
    (write-only scalars stay — like write-only property buffers they are
    observable results, via ``EngineResult.host_env``), and kernels whose
    bodies folded away to nothing are deleted together with their launch
    statements.

``direction``
    Compile-time push/pull direction selection per edge kernel
    (:class:`~repro.core.mir.Direction`). Frontier guards over props that
    no kernel or host statement ever mutates are loop-invariant — the
    kernel is marked ``DENSE`` and the engine skips host-side frontier
    mask evaluation entirely (PageRank's ``deg[src] > 0``). Real dynamic
    frontiers are marked ``SPARSE`` and always attempt compaction. This
    replaces the engine's runtime-only fallback heuristic with a
    compile-time verdict.

``fuse``
    Kernel fusion. Maximal runs of launch statements with no intervening
    host dependency are grouped: adjacent vertex kernels with the same
    index pattern merge into one body (one lane sweep), and an edge kernel
    followed by the vertex apply over its scatter target becomes a
    :class:`~repro.core.mir.PipelineKernel` — the paper's Fig. 4 single
    pipeline, lowered as ONE jitted launch with stage-boundary commits.
    Edge kernels assigned ``SPARSE`` direction are never fused (fusing
    would forfeit frontier compaction), and a fusion group never extends
    from a vertex kernel into a following edge kernel.

Every transformation appends a line to ``Module.pass_report``; the report
is embedded in ``Module.describe()`` so golden tests pin exactly what the
pipeline did. ``CompileOptions.passes`` selects the pipeline ("default",
"none", or a comma list) and participates in the Program content-hash
cache key, so pass ablations never alias cached artifacts.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from . import fir, mir, semantic


class PassError(Exception):
    """Raised for invalid pass lists or unusable compile-time bindings."""


DEFAULT_PASSES: Tuple[str, ...] = ("fold", "dce", "direction", "fuse")


def parse_pass_list(spec: str) -> Tuple[str, ...]:
    """Parse ``CompileOptions.passes`` into an ordered pass-name tuple."""
    spec = (spec or "").strip()
    if spec in ("none", ""):
        return ()
    if spec in ("default", "all"):
        return DEFAULT_PASSES
    names = tuple(s.strip() for s in spec.split(",") if s.strip())
    unknown = [n for n in names if n not in PASSES]
    if unknown:
        raise PassError(
            f"unknown pass(es) {unknown}; available: {sorted(PASSES)} "
            f"(or 'default' / 'none')"
        )
    return names


@dataclass
class PassContext:
    module: mir.Module
    options: "object"  # CompileOptions (kept untyped: no import cycle)
    changed_kernels: Set[str] = field(default_factory=set)

    def report(self, line: str) -> None:
        self.module.pass_report.append(line)


def run_pipeline(module: mir.Module, options) -> mir.Module:
    """Run the selected passes over a COPY of ``module`` (the analyzed
    base module is cached per-source across all option sets and must stay
    pristine). Returns the input unchanged when no pass is selected."""
    names = parse_pass_list(getattr(options, "passes", "none"))
    if tuple(getattr(options, "scalar_bindings", ()) or ()) and "fold" not in names:
        # silently ignoring a requested specialization would run the program
        # with the scalar's declared default — wrong results, no warning
        raise PassError(
            "CompileOptions.scalar_bindings requires the 'fold' pass, but "
            f"passes={getattr(options, 'passes', None)!r} does not select it"
        )
    if not names:
        return module
    module = copy.deepcopy(module)
    ctx = PassContext(module=module, options=options)
    for name in names:
        PASSES[name](ctx)
        # body-mutating passes invalidate the Property Detector results
        for kname in sorted(ctx.changed_kernels):
            kern = module.kernels.get(kname)
            if kern is not None and isinstance(kern, mir.Kernel):
                semantic.reanalyze_kernel(kern, module)
        ctx.changed_kernels.clear()
    return module


# ---------------------------------------------------------------------------
# FIR walking / rewriting utilities
# ---------------------------------------------------------------------------


def _map_expr(e: Optional[fir.Expr], fn: Callable) -> Optional[fir.Expr]:
    """Bottom-up expression rewrite: children first, then ``fn`` on the node."""
    if e is None:
        return None
    if isinstance(e, fir.BinOp):
        e.lhs = _map_expr(e.lhs, fn)
        e.rhs = _map_expr(e.rhs, fn)
    elif isinstance(e, fir.UnaryOp):
        e.operand = _map_expr(e.operand, fn)
    elif isinstance(e, fir.Index):
        e.base = _map_expr(e.base, fn)
        e.index = _map_expr(e.index, fn)
    elif isinstance(e, fir.Call):
        e.args = [_map_expr(a, fn) for a in e.args]
    elif isinstance(e, fir.MethodCall):
        e.obj = _map_expr(e.obj, fn)
        e.args = [_map_expr(a, fn) for a in e.args]
    return fn(e)


def _map_stmts(stmts: List[fir.Stmt], fn: Callable) -> None:
    """Apply ``fn`` (via :func:`_map_expr`) to every expression position."""
    for st in stmts:
        if isinstance(st, fir.VarDecl):
            st.init = _map_expr(st.init, fn)
        elif isinstance(st, fir.Assign):
            st.target = _map_expr(st.target, fn)
            st.value = _map_expr(st.value, fn)
        elif isinstance(st, fir.ReduceAssign):
            st.target = _map_expr(st.target, fn)
            st.value = _map_expr(st.value, fn)
        elif isinstance(st, fir.If):
            st.cond = _map_expr(st.cond, fn)
            _map_stmts(st.then_body, fn)
            _map_stmts(st.else_body, fn)
        elif isinstance(st, fir.While):
            st.cond = _map_expr(st.cond, fn)
            _map_stmts(st.body, fn)
        elif isinstance(st, fir.For):
            st.iter = _map_expr(st.iter, fn)
            _map_stmts(st.body, fn)
        elif isinstance(st, fir.ExprStmt):
            st.expr = _map_expr(st.expr, fn)


def _walk_exprs(stmts: List[fir.Stmt], fn: Callable) -> None:
    """Read-only visit of every expression (fn receives each node once)."""

    def visit(e):
        fn(e)
        return e

    _map_stmts(stmts, visit)


def _visit_expr(e: Optional[fir.Expr], fn: Callable) -> None:
    """Read-only visit of one expression tree."""

    def visit(x):
        fn(x)
        return x

    _map_expr(e, visit)


def _host_scalar_reads(module: mir.Module) -> Set[str]:
    """Host scalars whose VALUE is observed somewhere in host code.

    A plain-assignment target (``wonly = 5``) is a write, not a read —
    only the value side counts. A reduce-assignment target (``level += 1``)
    reads its current value, and an indexed target (``P[root] = 1``)
    reads whatever its index expression references.
    """
    reads: Set[str] = set()

    def note(e):
        if isinstance(e, fir.Ident) and e.name in module.scalars:
            reads.add(e.name)

    def scan(body: List[fir.Stmt]):
        for st in body:
            if isinstance(st, fir.Assign):
                if isinstance(st.target, fir.Index):
                    _visit_expr(st.target.index, note)
                _visit_expr(st.value, note)
            elif isinstance(st, fir.ReduceAssign):
                _visit_expr(st.target, note)
                _visit_expr(st.value, note)
            elif isinstance(st, fir.VarDecl):
                _visit_expr(st.init, note)
            elif isinstance(st, fir.If):
                _visit_expr(st.cond, note)
                scan(st.then_body)
                scan(st.else_body)
            elif isinstance(st, (fir.While, fir.For)):
                if isinstance(st, fir.While):
                    _visit_expr(st.cond, note)
                else:
                    _visit_expr(st.iter, note)
                scan(st.body)
            elif isinstance(st, fir.ExprStmt):
                _visit_expr(st.expr, note)

    for block in _host_blocks(module):
        scan(block)
    return reads


def _host_blocks(module: mir.Module) -> List[List[fir.Stmt]]:
    blocks = [module.host.main.body]
    blocks += [f.body for f in module.host.host_funcs.values()]
    return blocks


_LIT = (fir.IntLit, fir.FloatLit, fir.BoolLit)


def _lit_value(e: fir.Expr):
    return e.value


def _make_lit(value, line: int) -> fir.Expr:
    if isinstance(value, bool):
        return fir.BoolLit(line=line, value=value)
    if isinstance(value, int):
        return fir.IntLit(line=line, value=value)
    if isinstance(value, float):
        return fir.FloatLit(line=line, value=value)
    raise PassError(f"cannot fold value of type {type(value).__name__}")


_INT32_MIN, _INT32_MAX = -(2**31), 2**31 - 1


def _fold_arith(a, b, op: str):
    """Fold one arithmetic op with DEVICE semantics, or return None.

    Device kernels evaluate int literals as int32 and float literals as
    float32, so folds involving a float are computed in numpy float32 —
    the folded literal is bit-identical to what the lowered kernel would
    compute from its literal operands. Integer folds that leave the int32
    range are refused (the device would wrap; the host would not).
    """
    import numpy as np

    if isinstance(a, float) or isinstance(b, float):
        f32 = {"+": np.add, "-": np.subtract, "*": np.multiply,
               "/": np.divide}[op]
        with np.errstate(all="ignore"):
            return float(f32(np.float32(a), np.float32(b)))
    if op == "/":
        return None  # int/int true division: leave to the device
    res = {"+": a + b, "-": a - b, "*": a * b}[op]
    if not (_INT32_MIN <= res <= _INT32_MAX):
        return None
    return res


def _fold_node(e: fir.Expr) -> fir.Expr:
    """Fold one expression node whose children are already folded."""
    if isinstance(e, fir.UnaryOp) and isinstance(e.operand, _LIT):
        v = _lit_value(e.operand)
        return _make_lit((not v) if e.op == "!" else -v, e.line)
    if isinstance(e, fir.BinOp) and isinstance(e.lhs, _LIT) and isinstance(e.rhs, _LIT):
        a, b = _lit_value(e.lhs), _lit_value(e.rhs)
        try:
            if e.op in ("+", "-", "*", "/"):
                res = _fold_arith(a, b, e.op)
                return e if res is None else _make_lit(res, e.line)
            if e.op in ("==", "!=", "<", "<=", ">", ">="):
                if isinstance(a, float) or isinstance(b, float):
                    # compare with DEVICE semantics (float32 promotion),
                    # exactly like _fold_arith: a float64 comparison could
                    # disagree with the lowered kernel and delete a branch
                    # the device would take
                    import numpy as np

                    a, b = np.float32(a), np.float32(b)
                res = {
                    "==": a == b, "!=": a != b, "<": a < b,
                    "<=": a <= b, ">": a > b, ">=": a >= b,
                }[e.op]
                return _make_lit(bool(res), e.line)
            if e.op == "&":
                return _make_lit(bool(a) and bool(b), e.line)
            if e.op == "|":
                return _make_lit(bool(a) or bool(b), e.line)
        except (ZeroDivisionError, OverflowError):
            return e
    return e


def _simplify_static_ifs(stmts: List[fir.Stmt]) -> Tuple[List[fir.Stmt], int]:
    """Replace ``if (true/false)`` with the taken branch, recursively."""
    out: List[fir.Stmt] = []
    n = 0
    for st in stmts:
        if isinstance(st, fir.If):
            st.then_body, a = _simplify_static_ifs(st.then_body)
            st.else_body, b = _simplify_static_ifs(st.else_body)
            n += a + b
            if isinstance(st.cond, fir.BoolLit):
                out.extend(st.then_body if st.cond.value else st.else_body)
                n += 1
                continue
        elif isinstance(st, (fir.While, fir.For)):
            st.body, a = _simplify_static_ifs(st.body)
            n += a
        out.append(st)
    return out, n


def _collect_local_names(stmts: List[fir.Stmt]) -> Set[str]:
    names: Set[str] = set()
    for st in stmts:
        if isinstance(st, fir.VarDecl):
            names.add(st.name)
        elif isinstance(st, fir.If):
            names |= _collect_local_names(st.then_body)
            names |= _collect_local_names(st.else_body)
        elif isinstance(st, (fir.While, fir.For)):
            if isinstance(st, fir.For):
                names.add(st.var)
            names |= _collect_local_names(st.body)
    return names


def _rename_idents(stmts: List[fir.Stmt], mapping: Dict[str, str]) -> None:
    """Alpha-rename identifiers (params / locals / loop vars) in-place."""

    def sub(e):
        if isinstance(e, fir.Ident) and e.name in mapping:
            e.name = mapping[e.name]
        return e

    def walk(body: List[fir.Stmt]):
        for st in body:
            if isinstance(st, fir.VarDecl) and st.name in mapping:
                st.name = mapping[st.name]
            elif isinstance(st, fir.For) and st.var in mapping:
                st.var = mapping[st.var]
            if isinstance(st, fir.If):
                walk(st.then_body)
                walk(st.else_body)
            elif isinstance(st, (fir.While, fir.For)):
                walk(st.body)

    walk(stmts)
    _map_stmts(stmts, sub)


# ---------------------------------------------------------------------------
# pass: fold — compile-time scalar binding + literal simplification
# ---------------------------------------------------------------------------


def _host_written_names(module: mir.Module) -> Set[str]:
    """Identifiers and property names written by host statements."""
    written: Set[str] = set()

    def scan(body: List[fir.Stmt]):
        for st in body:
            if isinstance(st, (fir.Assign, fir.ReduceAssign)):
                tgt = st.target
                if isinstance(tgt, fir.Ident):
                    written.add(tgt.name)
                elif isinstance(tgt, fir.Index) and isinstance(tgt.base, fir.Ident):
                    written.add(tgt.base.name)
            elif isinstance(st, fir.If):
                scan(st.then_body)
                scan(st.else_body)
            elif isinstance(st, (fir.While, fir.For)):
                scan(st.body)
            elif isinstance(st, fir.ExprStmt):
                e = st.expr
                if isinstance(e, fir.Call) and e.func == "swap":
                    for a in e.args:
                        if isinstance(a, fir.Ident):
                            written.add(a.name)

    for block in _host_blocks(module):
        scan(block)
    return written


_COERCE = {"int": int, "float": float, "bool": bool}


def pass_fold(ctx: PassContext) -> None:
    module = ctx.module
    bindings = tuple(getattr(ctx.options, "scalar_bindings", ()) or ())
    host_written = _host_written_names(module)

    subs: Dict[str, fir.Expr] = {}
    for name, value in bindings:
        info = module.scalars.get(name)
        if info is None:
            raise PassError(
                f"scalar_bindings names {name!r}, which is not a declared "
                f"host scalar (have: {sorted(module.scalars)})"
            )
        if name in host_written:
            raise PassError(
                f"cannot bind scalar {name!r} at compile time: the host "
                f"program assigns it"
            )
        subs[name] = _make_lit(_COERCE[info.scalar](value), 0)

    def substitute(e):
        if isinstance(e, fir.Ident) and e.name in subs:
            return copy.deepcopy(subs[e.name])
        return e

    folds = 0

    def fold(e):
        nonlocal folds
        new = _fold_node(e)
        if new is not e:
            folds += 1
        return new

    for name, kern in list(module.kernels.items()):
        if not isinstance(kern, mir.Kernel):
            continue
        before = folds
        if subs:
            _map_stmts(kern.func.body, substitute)
        _map_stmts(kern.func.body, fold)
        kern.func.body, n_ifs = _simplify_static_ifs(kern.func.body)
        if subs or folds > before or n_ifs:
            ctx.changed_kernels.add(name)
    # Host code gets SUBSTITUTION only, never arithmetic folding: the host
    # interpreter evaluates in Python float64, so folding with the device's
    # float32 semantics could change host control flow. Substituting a
    # bound scalar's (exact) value is semantics-preserving; folding is not.
    if subs:
        for block in _host_blocks(module):
            _map_stmts(block, substitute)
        # surviving scalars may reference a bound scalar in their
        # initializer (evaluated by the engine at construction time)
        for info in module.scalars.values():
            if info.name not in subs:
                info.init = _map_expr(info.init, substitute)

    for name in subs:
        del module.scalars[name]
        ctx.report(f"fold: bound scalar {name} = {_lit_value(subs[name])} "
                   f"(removed from run-time parameters)")
    if folds:
        ctx.report(f"fold: simplified {folds} constant expression(s)")


# ---------------------------------------------------------------------------
# pass: dce — dead property / scalar / kernel elimination
# ---------------------------------------------------------------------------


def _kernel_body_is_empty(kern: mir.Kernel) -> bool:
    def empty(stmts: List[fir.Stmt]) -> bool:
        for st in stmts:
            if isinstance(st, fir.If):
                if not (empty(st.then_body) and empty(st.else_body)):
                    return False
            else:
                return False
        return True

    return empty(kern.func.body)


def _strip_launches(module: mir.Module, names: Set[str]) -> int:
    """Remove host launch statements of the given kernels."""
    removed = 0

    def scan(body: List[fir.Stmt]) -> List[fir.Stmt]:
        nonlocal removed
        out = []
        for st in body:
            k = _launch_target(module, st)
            if k is not None and k[0] in names:
                removed += 1
                continue
            if isinstance(st, fir.If):
                st.then_body = scan(st.then_body)
                st.else_body = scan(st.else_body)
            elif isinstance(st, (fir.While, fir.For)):
                st.body = scan(st.body)
            out.append(st)
        return out

    module.host.main.body = scan(module.host.main.body)
    for f in module.host.host_funcs.values():
        f.body = scan(f.body)
    return removed


def pass_dce(ctx: PassContext) -> None:
    module = ctx.module

    for _round in range(8):
        changed = False

        # -- dead kernels: bodies that folded away to nothing --------------
        dead_kernels = {
            n for n, k in module.kernels.items()
            if isinstance(k, mir.Kernel) and _kernel_body_is_empty(k)
        }
        if dead_kernels:
            _strip_launches(module, dead_kernels)
            for n in sorted(dead_kernels):
                del module.kernels[n]
                ctx.report(f"dce: removed kernel {n} (body folded to nothing)")
            changed = True

        # -- property / scalar use census ----------------------------------
        used_props: Set[str] = set()
        read_scalars: Set[str] = set()
        for kern in module.kernels.values():
            if not isinstance(kern, mir.Kernel):
                continue
            used_props |= {r.prop for r in kern.reads}
            used_props |= {w.prop for w in kern.writes}
            read_scalars |= kern.scalar_reads

        # property uses: ANY host mention keeps a buffer alive — including
        # write targets (write-only properties are observable results) and
        # bare idents (`swap(a, b)`)
        def host_prop_visit(e):
            if (isinstance(e, fir.Index) and isinstance(e.base, fir.Ident)
                    and e.base.name in module.properties):
                used_props.add(e.base.name)
            if isinstance(e, fir.Ident) and e.name in module.properties:
                used_props.add(e.name)

        for block in _host_blocks(module):
            _walk_exprs(block, host_prop_visit)
        # scalar uses: genuine reads in host code, reads from other
        # scalars' initializer expressions (evaluated by the engine at
        # construction), and host writes — a write-only scalar is still an
        # observable result via EngineResult.host_env, exactly like a
        # write-only property buffer
        read_scalars |= _host_scalar_reads(module)
        for info in module.scalars.values():
            _visit_expr(
                info.init,
                lambda e: read_scalars.add(e.name)
                if isinstance(e, fir.Ident) and e.name in module.scalars
                else None,
            )
        read_scalars |= {
            n for n in _host_written_names(module) if n in module.scalars
        }

        # -- never-accessed properties lose their device buffer ------------
        for name in sorted(set(module.properties) - used_props):
            del module.properties[name]
            module.degree_props.pop(name, None)
            ctx.report(f"dce: removed property {name} (never accessed; "
                       f"buffer freed)")
            changed = True

        # -- scalars never accessed at all disappear -----------------------
        dead_scalars = set(module.scalars) - read_scalars
        if dead_scalars:
            for name in sorted(dead_scalars):
                del module.scalars[name]
                ctx.report(f"dce: removed scalar {name} (never accessed)")
            changed = True

        if not changed:
            break

    # -- rebuild the memory plan with dense channel numbering --------------
    old_n = len(module.memory.buffers)
    module.memory = mir.MemoryPlan()
    for p in module.properties.values():
        module.memory.add(p)
    if len(module.memory.buffers) != old_n:
        ctx.report(
            f"dce: memory plan now {len(module.memory.buffers)} buffer(s) "
            f"(was {old_n}); channels renumbered"
        )


# ---------------------------------------------------------------------------
# pass: direction — compile-time push/pull selection per edge kernel
# ---------------------------------------------------------------------------


def pass_direction(ctx: PassContext) -> None:
    module = ctx.module
    mutated: Set[str] = set(_host_written_names(module))
    for kern in module.kernels.values():
        if isinstance(kern, mir.Kernel):
            mutated |= {w.prop for w in kern.writes}
            if kern.writes_weight:
                mutated.add("__weight__")

    compact = getattr(ctx.options, "compact_frontier", True)
    for name, kern in module.kernels.items():
        if not isinstance(kern, mir.Kernel) or kern.kind is not mir.KernelKind.EDGE:
            continue
        if not compact:
            kern.direction = mir.Direction.DENSE
            ctx.report(f"direction: {name} -> dense (frontier compaction disabled)")
        elif kern.frontier is None:
            kern.direction = mir.Direction.DENSE
            ctx.report(f"direction: {name} -> dense (no frontier guard)")
        elif not (kern.frontier.props & mutated):
            kern.direction = mir.Direction.DENSE
            ctx.report(
                f"direction: {name} -> dense (loop-invariant guard on "
                f"{sorted(kern.frontier.props)})"
            )
        else:
            kern.direction = mir.Direction.SPARSE
            ctx.report(
                f"direction: {name} -> sparse (dynamic frontier on "
                f"{sorted(kern.frontier.props)})"
            )


# ---------------------------------------------------------------------------
# pass: fuse — kernel fusion over adjacent launches
# ---------------------------------------------------------------------------


def _launch_target(module: mir.Module, st: fir.Stmt) -> Optional[Tuple[str, str]]:
    """Return (kernel name, launch object name) if ``st`` is a device
    kernel launch (``obj.init(f)`` / ``obj.process(f)``), else None."""
    if not isinstance(st, fir.ExprStmt):
        return None
    e = st.expr
    if not (isinstance(e, fir.MethodCall) and e.method in ("init", "process")):
        return None
    if len(e.args) != 1 or not isinstance(e.args[0], fir.Ident):
        return None
    kname = e.args[0].name
    if kname not in module.kernels:
        return None
    obj = e.obj.name if isinstance(e.obj, fir.Ident) else ""
    return kname, obj


def _fusion_eligible(kern) -> bool:
    if isinstance(kern, mir.PipelineKernel):
        return False
    if kern.kind is mir.KernelKind.VERTEX:
        return True
    if kern.kind is mir.KernelKind.EDGE:
        # SPARSE/AUTO edge kernels keep their standalone launch so the
        # engine can frontier-compact them (fusing forfeits compaction)
        return kern.direction is mir.Direction.DENSE
    return False


def _can_extend_group(group: List[mir.Kernel], nxt: mir.Kernel) -> bool:
    if not _fusion_eligible(nxt):
        return False
    if nxt.kind is mir.KernelKind.EDGE and not any(
        k.kind is mir.KernelKind.EDGE for k in group
    ):
        # a group may only contain an edge kernel if it STARTS with one:
        # the Fig. 4 pipeline shape is edge traversal -> vertex apply,
        # never vertex init -> edge traversal
        return False
    return True


def _touched_props(kern: mir.Kernel) -> Set[str]:
    return {r.prop for r in kern.reads} | {w.prop for w in kern.writes}


def _merge_safe(stages: List[mir.Kernel]) -> bool:
    """True when concatenating the bodies into ONE lane sweep is
    observationally identical to launching the stages in sequence: no
    earlier stage's scattered/accumulator write may be observed (read OR
    overwritten) by a later stage, because scattered writes commit at
    kernel exit while sequential (burst) writes chain lane-locally."""
    if any(k.kind is not mir.KernelKind.VERTEX for k in stages):
        return False
    if any(k.has_neighbor_loop for k in stages):
        return False
    for i, a in enumerate(stages):
        deferred = a.scatter_props | a.accumulators
        for b in stages[i + 1:]:
            if deferred & _touched_props(b):
                return False
    return True


def _build_merged_kernel(
    module: mir.Module, name: str, stages: List[mir.Kernel]
) -> mir.Kernel:
    canon = stages[0].vertex_param
    taken = set(module.properties) | set(module.scalars) | {canon}
    body: List[fir.Stmt] = []
    for i, st_kern in enumerate(stages):
        stage_body = copy.deepcopy(st_kern.func.body)
        mapping: Dict[str, str] = {}
        if st_kern.vertex_param != canon:
            mapping[st_kern.vertex_param] = canon
        for local in sorted(_collect_local_names(stage_body)):
            fresh = f"{local}__s{i}"
            while fresh in taken:
                fresh += "_"
            mapping[local] = fresh
            taken.add(fresh)
        if mapping:
            _rename_idents(stage_body, mapping)
        body.extend(stage_body)
    func = fir.FuncDecl(
        name=name,
        params=[copy.deepcopy(stages[0].func.params[0])],
        body=body,
    )
    kern = mir.Kernel(name, mir.KernelKind.VERTEX, func, vertex_param=canon)
    semantic.reanalyze_kernel(kern, module)
    return kern


def pass_fuse(ctx: PassContext) -> None:
    module = ctx.module
    by_stages: Dict[Tuple[str, ...], str] = {}

    def fused_name(names: Tuple[str, ...]) -> str:
        base = "__".join(names)
        while base in module.kernels:
            base += "_"
        return base

    def materialize(names: Tuple[str, ...]) -> str:
        if names in by_stages:
            return by_stages[names]
        stages = [module.kernels[n] for n in names]
        name = fused_name(names)
        if _merge_safe(stages):
            module.kernels[name] = _build_merged_kernel(module, name, stages)
            how = "merged vertex kernel"
        else:
            module.kernels[name] = mir.PipelineKernel(name=name, stages=stages)
            kinds = [s.kind.value for s in stages]
            how = f"pipeline [{' -> '.join(kinds)}]"
        module.fusion_groups[name] = names
        by_stages[names] = name
        ctx.report(f"fuse: {' + '.join(names)} -> {name} ({how})")
        return name

    def rewrite(body: List[fir.Stmt]) -> List[fir.Stmt]:
        out: List[fir.Stmt] = []
        i = 0
        while i < len(body):
            st = body[i]
            tgt = _launch_target(module, st)
            if tgt is None:
                if isinstance(st, fir.If):
                    st.then_body = rewrite(st.then_body)
                    st.else_body = rewrite(st.else_body)
                elif isinstance(st, (fir.While, fir.For)):
                    st.body = rewrite(st.body)
                out.append(st)
                i += 1
                continue
            # collect the maximal fusable group starting here
            kname, obj = tgt
            group = [module.kernels[kname]]
            names = [kname]
            j = i + 1
            if _fusion_eligible(group[0]):
                while j < len(body):
                    nxt = _launch_target(module, body[j])
                    if nxt is None:
                        break
                    nk = module.kernels[nxt[0]]
                    if not _can_extend_group(group, nk):
                        break
                    group.append(nk)
                    names.append(nxt[0])
                    j += 1
            if len(group) >= 2:
                new = materialize(tuple(names))
                out.append(
                    fir.ExprStmt(
                        line=st.line,
                        expr=fir.MethodCall(
                            line=st.line,
                            obj=fir.Ident(line=st.line, name=obj),
                            method="process",
                            args=[fir.Ident(line=st.line, name=new)],
                        ),
                    )
                )
                i = j
            else:
                out.append(st)
                i += 1
        return out

    module.host.main.body = rewrite(module.host.main.body)
    for f in module.host.host_funcs.values():
        f.body = rewrite(f.body)


PASSES: Dict[str, Callable[[PassContext], None]] = {
    "fold": pass_fold,
    "dce": pass_dce,
    "direction": pass_direction,
    "fuse": pass_fuse,
}


# ---------------------------------------------------------------------------
# incremental-recomputation analysis (streaming path; not in PASSES)
# ---------------------------------------------------------------------------
# Unlike the rewriting passes above, this analysis never mutates the module
# and never contributes to its canonical serialization — program
# fingerprints, cache identities and saved artifacts are untouched. It is
# computed lazily by repro.streaming when the first delta arrives.


def _iter_all_stmts(stmts: List[fir.Stmt]):
    """Yield every statement, descending into nested bodies."""
    for st in stmts:
        yield st
        if isinstance(st, fir.If):
            yield from _iter_all_stmts(st.then_body)
            yield from _iter_all_stmts(st.else_body)
        elif isinstance(st, (fir.While, fir.For)):
            yield from _iter_all_stmts(st.body)


def _prop_index(module: mir.Module, e) -> Optional[Tuple[str, fir.Expr]]:
    """(property name, index expr) when ``e`` is ``P[i]`` for a property."""
    if (isinstance(e, fir.Index) and isinstance(e.base, fir.Ident)
            and e.base.name in module.properties):
        return e.base.name, e.index
    return None


def _ident_name(e) -> Optional[str]:
    return e.name if isinstance(e, fir.Ident) else None


def _const_int(module: mir.Module, e) -> Optional[int]:
    """Fold an expression to a compile-time int (literals, const scalars)."""
    if isinstance(e, fir.IntLit):
        return int(e.value)
    if isinstance(e, fir.UnaryOp) and e.op == "-":
        v = _const_int(module, e.operand)
        return None if v is None else -v
    if isinstance(e, fir.Ident) and e.name in module.scalars:
        init = module.scalars[e.name].init
        return None if init is None else _const_int(module, init)
    return None


def _vertex_init_literal(module: mir.Module,
                         vertex_kernels: List[mir.Kernel],
                         prop: str) -> Optional[int]:
    """The constant a vertex kernel initializes ``prop[v]`` to, if any."""
    for k in vertex_kernels:
        for st in _iter_all_stmts(k.func.body):
            if not isinstance(st, fir.Assign):
                continue
            tgt = _prop_index(module, st.target)
            if tgt and tgt[0] == prop and _ident_name(tgt[1]) == k.vertex_param:
                v = _const_int(module, st.value)
                if v is not None:
                    return v
    return None


def _copy_source(module: mir.Module, vertex_kernels: List[mir.Kernel],
                 dst_prop: str) -> Optional[str]:
    """Find M such that some vertex kernel runs ``dst_prop[v] = M[v]``."""
    for k in vertex_kernels:
        for st in _iter_all_stmts(k.func.body):
            if not isinstance(st, fir.Assign):
                continue
            tgt = _prop_index(module, st.target)
            if not (tgt and tgt[0] == dst_prop
                    and _ident_name(tgt[1]) == k.vertex_param):
                continue
            val = _prop_index(module, st.value)
            if val and _ident_name(val[1]) == k.vertex_param:
                return val[0]
    return None


def _has_vertex_copy(module: mir.Module, vertex_kernels: List[mir.Kernel],
                     dst_prop: str, src_prop: str) -> bool:
    return _copy_source(module, vertex_kernels, dst_prop) == src_prop or any(
        _copy_source(module, [k], dst_prop) == src_prop for k in vertex_kernels
    )


def _match_label(module: mir.Module, edge_kernels: List[mir.Kernel],
                 vertex_kernels: List[mir.Kernel]) -> Optional[mir.IncrementalTemplate]:
    """Connected-components shape: symmetric unguarded min-label exchange."""
    for k in edge_kernels:
        reduces = [s for s in _iter_all_stmts(k.func.body)
                   if isinstance(s, fir.ReduceAssign) and s.op == "min"]
        if len(reduces) != 2:
            continue
        pairs = []
        for s in reduces:
            tgt = _prop_index(module, s.target)
            val = _prop_index(module, s.value)
            if tgt is None or val is None:
                break
            pairs.append((tgt[0], _ident_name(tgt[1]), val[0], _ident_name(val[1])))
        if len(pairs) != 2:
            continue
        (p1, t1, q1, v1), (p2, t2, q2, v2) = pairs
        symmetric = (
            p1 == p2 and q1 == q2
            and {(t1, v1), (t2, v2)}
            == {(k.dst_param, k.src_param), (k.src_param, k.dst_param)}
        )
        if not symmetric:
            continue
        nxt, label = p1, q1  # next[dst] min= label[src] (and mirrored)
        # the apply step must fold improvements back (label := next) and the
        # labels must start as vertex ids — both are what make min-flood
        # repair converge to the same fixpoint as a from-scratch run
        if not _has_vertex_copy(module, vertex_kernels, label, nxt):
            continue
        ids_init = any(
            isinstance(st, fir.Assign)
            and (tgt := _prop_index(module, st.target)) is not None
            and tgt[0] == label and _ident_name(tgt[1]) == k2.vertex_param
            and _ident_name(st.value) == k2.vertex_param
            for k2 in vertex_kernels
            for st in _iter_all_stmts(k2.func.body)
        )
        if not ids_init:
            continue
        return mir.IncrementalTemplate(
            kind="label", dist_prop=label, mirror_props=(nxt,)
        )
    return None


def _match_distance(module: mir.Module, edge_kernels: List[mir.Kernel],
                    vertex_kernels: List[mir.Kernel]) -> Optional[mir.IncrementalTemplate]:
    """BFS / SSSP shapes: guarded ``T[dst] min= dist-ish + step`` relaxation."""
    for k in edge_kernels:
        for st in _iter_all_stmts(k.func.body):
            if not isinstance(st, fir.If):
                continue
            reduces = [s for s in st.then_body
                       if isinstance(s, fir.ReduceAssign) and s.op == "min"]
            if len(reduces) != 1:
                continue
            r = reduces[0]
            tgt = _prop_index(module, r.target)
            if not (tgt and _ident_name(tgt[1]) == k.dst_param):
                continue
            tuple_prop = tgt[0]
            val, cond = r.value, st.cond
            if not (isinstance(val, fir.BinOp) and val.op == "+"):
                continue
            if not (isinstance(cond, fir.BinOp) and cond.op == "=="):
                continue
            guard = _prop_index(module, cond.lhs)
            if not (guard and _ident_name(guard[1]) == k.src_param):
                continue
            # BFS family: `if dist[src] == level: T[dst] min= level + 1`
            rs = _ident_name(cond.rhs)
            if (rs is not None and rs in module.scalars
                    and _ident_name(val.lhs) == rs
                    and isinstance(val.rhs, fir.IntLit) and val.rhs.value == 1):
                dist = guard[0]
                sentinel = _vertex_init_literal(module, vertex_kernels, dist)
                mirror = _copy_source(module, vertex_kernels, dist)
                if sentinel is not None:
                    return mir.IncrementalTemplate(
                        kind="unit_distance", dist_prop=dist,
                        tuple_prop=tuple_prop,
                        mirror_props=(mirror,) if mirror else (),
                        unreached=sentinel, round_scalar=rs,
                    )
            # SSSP family: `if active[src] == 1: T[dst] min= D[src] + w`
            if (isinstance(cond.rhs, fir.IntLit) and cond.rhs.value == 1
                    and k.weight_param is not None
                    and _ident_name(val.rhs) == k.weight_param):
                dsrc = _prop_index(module, val.lhs)
                if not (dsrc and _ident_name(dsrc[1]) == k.src_param):
                    continue
                dist = dsrc[0]
                sentinel = _vertex_init_literal(module, vertex_kernels, dist)
                if sentinel is not None and _has_vertex_copy(
                        module, vertex_kernels, dist, tuple_prop):
                    return mir.IncrementalTemplate(
                        kind="weighted_distance", dist_prop=dist,
                        tuple_prop=tuple_prop, unreached=sentinel,
                    )
    return None


def analyze_incremental(module: mir.Module) -> mir.IncrementalInfo:
    """Monotonicity verdict + repair template for streaming re-convergence.

    A module is *monotone* when every per-edge write to a vertex property
    (SRC/DST/NEIGHBOR/OTHER patterns in edge kernels, scattered patterns in
    vertex kernels) is a ``min=``/``max=`` reduction — const-index
    accumulator cells (host control counters) and sequential vertex-apply
    writes are exempt. For such programs, adding edges can only tighten
    the fixpoint, so re-convergence may be seeded from the delta endpoints
    alone. Non-monotone programs (PageRank's ``+=`` mass flow, weight
    mutation, plain-assign scatters) get ``monotone=False`` and the
    streaming layer transparently falls back to full recompute.
    """
    scattered = (mir.IndexPattern.DST, mir.IndexPattern.NEIGHBOR,
                 mir.IndexPattern.OTHER)
    ops: Set[str] = set()
    reasons: List[str] = []
    monotone = True
    base = [k for k in module.kernels.values()
            if isinstance(k, mir.Kernel) and k.kind is not mir.KernelKind.HOST]
    for k in base:
        if k.writes_weight:
            monotone = False
            reasons.append(f"{k.name}: mutates edge weights")
        for w in k.writes:
            if w.pattern is mir.IndexPattern.CONST:
                continue  # accumulator cell: host control flow, not state
            per_edge = (w.pattern in scattered
                        or (k.kind is mir.KernelKind.EDGE
                            and w.pattern is mir.IndexPattern.SRC))
            if not per_edge:
                continue  # sequential vertex-apply write
            if w.reduce_op in ("min", "max"):
                ops.add(w.reduce_op)
            else:
                monotone = False
                reasons.append(
                    f"{k.name}: per-edge '{w.reduce_op or '='}' write to {w.prop}"
                )
    if not ops:
        monotone = False
        reasons.append("no min=/max= reduction to re-converge through")
    template = None
    if monotone:
        edge_kernels = [k for k in base if k.kind is mir.KernelKind.EDGE]
        vertex_kernels = [k for k in base if k.kind is mir.KernelKind.VERTEX]
        template = (_match_label(module, edge_kernels, vertex_kernels)
                    or _match_distance(module, edge_kernels, vertex_kernels))
    return mir.IncrementalInfo(
        monotone=monotone, reduce_ops=tuple(sorted(ops)),
        reasons=tuple(reasons), template=template,
    )
