"""Target: a structured, hashable description of the execution substrate.

The paper's flow generates an accelerator *for a device*: the back-end
lowers the algorithm against a hardware description (HBM channel count,
URAM budget, pipeline replication factor) once, and the resulting artifact
is deployed. This module is that hardware description re-targeted at the
JAX substrate: everything the lowering needs to know about *where* the
program will run — and nothing about *what* the program computes.

``Target`` absorbs the loose layout/placement fields that used to live on
:class:`~repro.core.options.CompileOptions` (``burst``/``cache``/
``shuffle``/``compact_frontier``/``pallas``/``n_partitions``/
``interpret``); ``CompileOptions`` now carries only front-end / middle-end
concerns (the pass pipeline and compile-time scalar bindings) plus a
compat shim that maps the old kwargs onto ``Target`` overrides.

The split is what makes :class:`~repro.core.accelerator.Accelerator`
artifacts well-defined: ``program.lower(target, shape)`` AOT-compiles
every kernel against (target, shape-bucket) and the result is valid for
*any* graph of that shape on that substrate —

    target  = Target()                          # local, all optimizations
    acc     = program.lower(target, shape=GraphShape(n_vertices=2000,
                                                     n_edges=16000))
    session = acc.bind(graph)                   # shape check only

``Target`` is a frozen dataclass: hashable, usable as a cache key, and
``repr``-stable for content fingerprinting.
"""
from __future__ import annotations

from dataclasses import dataclass, fields, replace
from typing import Optional, Tuple

#: Target fields that CompileOptions used to own; the CompileOptions compat
#: shim accepts these as kwargs and maps them to ``target_overrides``.
LEGACY_OPTION_FIELDS: Tuple[str, ...] = (
    "burst",
    "cache",
    "shuffle",
    "compact_frontier",
    "pallas",
    "n_partitions",
    "interpret",
)

_KINDS = ("local", "distributed")
_DTYPE_POLICIES = ("fp32",)  # the device ABI this reproduction lowers to


@dataclass(frozen=True)
class Target:
    """Execution-substrate description (the accelerator's hardware side).

    Backend placement:

    * ``kind`` — ``"local"`` (one device, the paper's single-accelerator
      system) or ``"distributed"`` (shard_map + all_to_all shuffle
      supersteps across a device mesh).
    * ``n_devices`` / ``axis`` — mesh shape for distributed targets
      (``0`` = every visible device).

    Memory-access optimizations (paper §III-C3, formerly CompileOptions):

    * ``burst`` — partitioned, ascending-src streaming order.
    * ``cache`` — hub-vertex relabeling (dense VMEM-prefix hub cache).
    * ``shuffle`` — dst-binned sorted segment reduction (conflict-free).
    * ``compact_frontier`` — only traverse active edges when the frontier
      is small (direction optimization).
    * ``pallas`` — route scatter-reduce/gather through Pallas TPU kernels.
    * ``n_partitions`` — dst-range partition count (0 = auto from
      ``partition_vertices``).
    * ``partition_vertices`` — VMEM sizing unit: auto-partitioning targets
      one dst-range slice of about this many vertices per partition (the
      analogue of sizing a subpartition to URAM).
    * ``interpret`` — Pallas interpret mode (None = auto: interpreted
      unless a real TPU backend is present).
    * ``dtype_policy`` — device number format policy; ``"fp32"`` is the
      only ABI this reproduction lowers (int32/float32/bool buffers).
    """

    kind: str = "local"
    n_devices: int = 0
    axis: str = "data"
    burst: bool = True
    cache: bool = True
    shuffle: bool = True
    compact_frontier: bool = True
    pallas: bool = False
    n_partitions: int = 0
    partition_vertices: int = 4096
    interpret: Optional[bool] = None
    dtype_policy: str = "fp32"

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown Target.kind {self.kind!r}; expected one of {_KINDS}"
            )
        if self.dtype_policy not in _DTYPE_POLICIES:
            raise ValueError(
                f"unsupported dtype_policy {self.dtype_policy!r}; this "
                f"back-end lowers {_DTYPE_POLICIES} (int32/float32/bool buffers)"
            )
        if self.n_devices < 0:
            raise ValueError("n_devices must be >= 0 (0 = all visible devices)")
        if self.partition_vertices < 1:
            raise ValueError("partition_vertices must be >= 1")
        if self.n_partitions < 0:
            raise ValueError("n_partitions must be >= 0 (0 = auto)")

    # -- resolution -----------------------------------------------------------
    @property
    def interpret_effective(self) -> bool:
        """Resolve ``interpret=None`` to the platform default.

        Pallas kernels must run interpreted on CPU (CI), but interpreting
        on a real TPU would silently deoptimize device runs — so auto
        means "interpret unless jax is actually backed by a TPU".
        """
        if self.interpret is not None:
            return self.interpret
        import jax

        return jax.default_backend() != "tpu"

    @property
    def backend_name(self) -> str:
        """The Session backend registry name this target places onto."""
        return self.kind

    def mesh(self):
        """Build the device mesh for a distributed target."""
        if self.kind != "distributed":
            raise ValueError(f"Target kind {self.kind!r} has no device mesh")
        import jax

        n = self.n_devices or jax.device_count()
        return jax.make_mesh((n,), (self.axis,))

    def auto_partitions(self, n_vertices: int) -> int:
        """Resolve the dst-range partition count for a vertex count."""
        if self.n_partitions:
            return self.n_partitions
        return max(1, n_vertices // self.partition_vertices)

    # -- construction helpers -------------------------------------------------
    @staticmethod
    def from_options(options, kind: str = "local", **overrides) -> "Target":
        """Map a (possibly legacy) CompileOptions onto a Target.

        This is the compat shim's other half: ``CompileOptions(burst=False)``
        records ``("burst", False)`` in ``target_overrides``, and this
        constructor replays those overrides (plus any explicit kwargs) onto
        the Target defaults. Plain objects exposing the legacy attribute
        names (old pickles, duck types) are also accepted.
        """
        vals = {"kind": kind}
        stored = getattr(options, "target_overrides", None)
        if stored is not None:
            for name, value in stored:
                vals[name] = value
        elif options is not None:  # pre-split options object: read attributes
            for name in LEGACY_OPTION_FIELDS:
                if hasattr(options, name):
                    vals[name] = getattr(options, name)
        vals.update(overrides)
        return Target(**vals)

    @staticmethod
    def baseline() -> "Target":
        """Unoptimized reference substrate: random scatter, no
        partitioning/caching (the paper's handcrafted-HLS baseline)."""
        return Target(
            burst=False, cache=False, shuffle=False, compact_frontier=False,
            pallas=False,
        )

    @staticmethod
    def with_only(opt: str) -> "Target":
        """Fig. 9 ablation points: exactly one memory optimization enabled."""
        if opt not in ("burst", "cache", "shuffle"):
            raise ValueError(f"unknown ablation axis {opt!r}")
        return replace(Target.baseline(), **{opt: True})

    # -- serialization --------------------------------------------------------
    def to_dict(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}

    @staticmethod
    def from_dict(d: dict) -> "Target":
        known = {f.name for f in fields(Target)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown Target fields in artifact: {unknown}")
        return Target(**d)

    def describe(self) -> str:
        mesh = f" x{self.n_devices or 'all'}({self.axis})" if self.kind == "distributed" else ""
        opts = ",".join(
            name for name in ("burst", "cache", "shuffle", "compact_frontier", "pallas")
            if getattr(self, name)
        ) or "none"
        return f"{self.kind}{mesh} [{opts}] parts={self.n_partitions or 'auto'}"


#: Default Target: the single source of truth for substrate defaults — the
#: CompileOptions compat properties resolve unset legacy fields against it.
DEFAULT_TARGET = Target()
