"""Structured tracing core: thread-safe Tracer with nestable spans.

The runtime between ``EngineStats`` scalars and the serving-tier
``ServeMetrics`` snapshot is a black box; this module is the data plane
that opens it. A :class:`Tracer` records **spans** — named, timed
intervals with typed attributes (program fingerprint, target, shape
bucket, batch K, graph version, tenant, ...) and parent links — from
which the exporters (:mod:`repro.telemetry.export`) derive Chrome
``trace_event`` JSON, Prometheus-style text, and per-run summaries.

Design constraints, in priority order:

1. **Near-zero cost when disabled.** The module-level default is a
   :class:`NullTracer` whose ``span()`` returns one preallocated no-op
   context manager; instrumented hot loops additionally guard on
   ``tracer.enabled`` so a disabled tracer costs one attribute check per
   launch. ci_bench gates this (``telemetry_overhead``).
2. **Thread-safe, cross-thread trees.** Span nesting rides a
   ``contextvars.ContextVar`` (so concurrent sessions on one tracer do
   not interleave parents); work handed to another thread (the serving
   scheduler, session pools) carries an explicit :class:`SpanContext`
   token captured at submit time and passed as ``parent=``.
3. **Bounded memory.** Finished spans go to a bounded buffer (drops are
   counted, never silent); per-span-name duration histograms reuse the
   serving tier's fixed-bucket :class:`~repro.serving.metrics.
   LatencyHistogram`, so a long-lived traced service aggregates without
   per-sample growth even after the buffer saturates.

Durations use ``time.perf_counter()`` throughout; the tracer records one
wall-clock anchor at construction so exporters can place spans on an
absolute timeline without per-span ``time.time()`` calls.
Head-based **trace sampling** keeps always-on tracing cheap at high QPS:
``Tracer(sample=0.1)`` (or ``repro.telemetry.enable(sample=0.1)``) makes
the keep-or-drop decision once per *root* span — a dropped root installs
a sampled-out marker in the context so every descendant span of that
trace is a preallocated no-op, never a half-recorded tree. Sampling is
seedable for deterministic tests.
"""
from __future__ import annotations

import random
import threading
import time
from contextvars import ContextVar
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "Span",
    "SpanContext",
    "Tracer",
    "NullTracer",
    "NULL_SPAN",
]

# (trace_id, span_id) of the innermost open span in this execution context
_CURRENT: ContextVar[Optional[Tuple[int, int]]] = ContextVar(
    "repro_telemetry_current", default=None
)

# ambient marker installed by a sampled-out root span: descendants see a
# negative trace id and short-circuit to NULL_SPAN (whole-trace drops,
# never partial trees)
_SAMPLED_OUT = (-1, -1)

# distinct span names get their own histogram up to this many; the rest
# aggregate under "other" (guards against unbounded label cardinality)
_MAX_HIST_NAMES = 256


def _new_histogram():
    # deferred: repro.serving imports repro.core which imports telemetry
    from ..serving.metrics import LatencyHistogram

    return LatencyHistogram()


class SpanContext:
    """Immutable handoff token: lets another thread parent under a span."""

    __slots__ = ("trace_id", "span_id")

    def __init__(self, trace_id: int, span_id: int) -> None:
        self.trace_id = trace_id
        self.span_id = span_id

    def __repr__(self) -> str:
        return f"SpanContext(trace={self.trace_id}, span={self.span_id})"


class Span:
    """One named, timed interval. Context manager; reentrant-unsafe.

    ``set(**attrs)`` adds attributes after entry (e.g. a launch records
    its compacted-vs-full decision once it is made). Attribute values
    should be JSON-representable scalars; exporters coerce the rest.
    """

    __slots__ = (
        "name", "span_id", "parent_id", "trace_id", "t_start", "t_end",
        "attrs", "thread_id", "thread_name", "_tracer", "_token",
    )

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 trace_id: int, parent_id: Optional[int],
                 attrs: Dict[str, Any]) -> None:
        self.name = name
        self.span_id = span_id
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.attrs = attrs
        self.t_start = 0.0
        self.t_end = 0.0
        th = threading.current_thread()
        self.thread_id = th.ident or 0
        self.thread_name = th.name
        self._tracer = tracer
        self._token = None

    @property
    def duration_s(self) -> float:
        return max(0.0, self.t_end - self.t_start)

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def context(self) -> SpanContext:
        return SpanContext(self.trace_id, self.span_id)

    def __enter__(self) -> "Span":
        self._token = _CURRENT.set((self.trace_id, self.span_id))
        self.t_start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.t_end = time.perf_counter()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        if self._token is not None:
            _CURRENT.reset(self._token)
            self._token = None
        self._tracer._finish(self)
        return False


class _NullSpan:
    """The no-op span: every operation is a constant-time nothing."""

    __slots__ = ()

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def context(self) -> None:
        return None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _SampledOutSpan:
    """Root span of a dropped trace: records nothing, but installs the
    sampled-out marker so every descendant short-circuits to NULL_SPAN.
    One instance per dropped root (it carries a context token)."""

    __slots__ = ("_token",)

    def __init__(self) -> None:
        self._token = None

    def set(self, **attrs: Any) -> "_SampledOutSpan":
        return self

    def context(self) -> None:
        return None  # nothing to parent under: the trace does not exist

    def __enter__(self) -> "_SampledOutSpan":
        self._token = _CURRENT.set(_SAMPLED_OUT)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._token is not None:
            _CURRENT.reset(self._token)
            self._token = None
        return False


class Tracer:
    """Thread-safe span recorder with bounded retention.

    One tracer instance serves the whole process (installed via
    :func:`repro.telemetry.enable`); concurrent threads append finished
    spans under one lock. The open-span path is lock-free — ids come
    from an atomic counter and nesting state lives in a context var.
    """

    enabled = True

    def __init__(self, max_spans: int = 200_000, *, sample: float = 1.0,
                 seed: Optional[int] = None) -> None:
        if not 0.0 <= sample <= 1.0:
            raise ValueError("sample must be in [0.0, 1.0]")
        self.max_spans = max_spans
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self.dropped = 0
        self._id_lock = threading.Lock()
        self._next_id = 1
        # perf_counter -> wall-clock anchor for absolute-timeline export
        self.epoch_s = time.time() - time.perf_counter()
        self._hist: Dict[str, Any] = {}
        # head-based trace sampling: the keep/drop decision is made once
        # per root span; sampled_out counts dropped *traces* (descendant
        # spans of a dropped trace are no-ops and are not counted)
        self.sample = float(sample)
        self.sampled_out = 0
        self._rng = random.Random(seed)

    # -- id allocation -------------------------------------------------------
    def _alloc_id(self) -> int:
        with self._id_lock:
            i = self._next_id
            self._next_id += 1
            return i

    # -- span lifecycle ------------------------------------------------------
    def span(self, name: str, *, parent: Optional[SpanContext] = None,
             **attrs: Any) -> Span:
        """Open a span. Use as a context manager::

            with tracer.span("launch:bfs", mode="full") as sp:
                ...
                sp.set(edges=n)

        ``parent`` overrides the ambient (context-local) parent — the
        cross-thread handoff path. Without it, the innermost open span in
        this execution context is the parent; a parentless span roots a
        new trace.

        With ``sample < 1.0``, a would-be root span is kept with
        probability ``sample``; a dropped root returns a no-op that marks
        the context, so the *whole* trace (every descendant span) is
        dropped — summaries never see partial trees. Cross-thread work
        parented under a dropped root (its ``context()`` is None, so the
        handoff passes ``parent=None``) makes its own sampling decision.
        """
        if parent is not None:
            trace_id, parent_id = parent.trace_id, parent.span_id
        else:
            cur = _CURRENT.get()
            if cur is not None:
                if cur[0] < 0:  # inside a sampled-out trace
                    return NULL_SPAN
                trace_id, parent_id = cur
            else:
                if self.sample < 1.0 and self._rng.random() >= self.sample:
                    with self._lock:
                        self.sampled_out += 1
                    return _SampledOutSpan()
                trace_id, parent_id = None, None
        sid = self._alloc_id()
        if trace_id is None:
            trace_id = sid
        return Span(self, name, sid, trace_id, parent_id, attrs)

    def record_span(self, name: str, t_start: float, t_end: float, *,
                    parent: Optional[SpanContext] = None,
                    **attrs: Any) -> Span:
        """Record an already-timed interval (perf_counter seconds).

        For phases whose start predates knowing they are interesting —
        e.g. a request's queue wait is only measurable when the request
        leaves the queue, from its recorded submit time.
        """
        sp = self.span(name, parent=parent, **attrs)
        if not isinstance(sp, Span):  # sampled out / inside a dropped trace
            return sp
        sp.t_start = t_start
        sp.t_end = t_end
        self._finish(sp)
        return sp

    def current(self) -> Optional[SpanContext]:
        """The innermost open span's context (for cross-thread handoff).

        Inside a sampled-out trace this is None — handed-off work then
        roots its own trace and makes its own sampling decision."""
        cur = _CURRENT.get()
        if cur is None or cur[0] < 0:
            return None
        return SpanContext(*cur)

    def _finish(self, span: Span) -> None:
        with self._lock:
            if len(self._spans) < self.max_spans:
                self._spans.append(span)
            else:
                self.dropped += 1
            key = span.name if (
                span.name in self._hist or len(self._hist) < _MAX_HIST_NAMES
            ) else "other"
            h = self._hist.get(key)
            if h is None:
                h = self._hist[key] = _new_histogram()
            h.record(span.duration_s)

    # -- readout -------------------------------------------------------------
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def reset(self) -> None:
        with self._lock:
            self._spans.clear()
            self._hist.clear()
            self.dropped = 0
            self.sampled_out = 0

    def histograms(self) -> Dict[str, Any]:
        """Merged copy of the per-span-name duration histograms."""
        with self._lock:
            return {k: _new_histogram().merge(h) for k, h in self._hist.items()}

    def summarize(self, root: Optional[SpanContext] = None) -> Dict[str, Any]:
        """Aggregate finished spans into a compact per-name summary.

        With ``root``, only the subtree under that span is summarized
        (the per-run ``EngineResult.trace`` path); without it, every
        retained span contributes. Returns ``{"spans": {name: {count,
        total_s, max_s}}, "total_s", "span_count", "dropped"}``.
        """
        spans = self.spans()
        if root is not None:
            keep = {root.span_id}
            grew = True
            by_parent: Dict[Optional[int], List[Span]] = {}
            for s in spans:
                by_parent.setdefault(s.parent_id, []).append(s)
            frontier = [root.span_id]
            while grew and frontier:
                grew = False
                nxt: List[int] = []
                for pid in frontier:
                    for s in by_parent.get(pid, ()):
                        if s.span_id not in keep:
                            keep.add(s.span_id)
                            nxt.append(s.span_id)
                            grew = True
                frontier = nxt
            spans = [s for s in spans if s.span_id in keep]
        agg: Dict[str, Dict[str, float]] = {}
        for s in spans:
            a = agg.setdefault(s.name, {"count": 0, "total_s": 0.0, "max_s": 0.0})
            a["count"] += 1
            a["total_s"] += s.duration_s
            a["max_s"] = max(a["max_s"], s.duration_s)
        for a in agg.values():
            a["total_s"] = round(a["total_s"], 6)
            a["max_s"] = round(a["max_s"], 6)
        return {
            "spans": agg,
            "span_count": len(spans),
            "total_s": round(sum(a["total_s"] for a in agg.values()), 6),
            "dropped": self.dropped,
        }

    # -- exporters (delegate to repro.telemetry.export) ----------------------
    def export_chrome(self, path: str) -> int:
        """Write retained spans as Chrome/Perfetto ``trace_event`` JSON;
        returns the number of duration events written."""
        from .export import export_chrome

        return export_chrome(self, path)

    def prometheus_text(self) -> str:
        """Prometheus-style text exposition of the span histograms."""
        from .export import prometheus_text

        return prometheus_text(self)


class NullTracer:
    """The disabled state: accepts the full Tracer API, retains nothing."""

    enabled = False
    dropped = 0
    epoch_s = 0.0

    def span(self, name: str, *, parent: Optional[SpanContext] = None,
             **attrs: Any) -> _NullSpan:
        return NULL_SPAN

    def record_span(self, name: str, t_start: float, t_end: float, *,
                    parent: Optional[SpanContext] = None,
                    **attrs: Any) -> _NullSpan:
        return NULL_SPAN

    def current(self) -> None:
        return None

    def spans(self) -> List[Span]:
        return []

    def reset(self) -> None:
        return None

    def histograms(self) -> Dict[str, Any]:
        return {}

    def summarize(self, root: Optional[SpanContext] = None) -> Dict[str, Any]:
        return {"spans": {}, "span_count": 0, "total_s": 0.0, "dropped": 0}

    def export_chrome(self, path: str) -> int:
        from .export import export_chrome

        return export_chrome(self, path)

    def prometheus_text(self) -> str:
        return ""


NULL_TRACER = NullTracer()
