"""repro.telemetry: end-to-end tracing from compile to serve.

The runtime's structured observability layer — the software analogue of
the per-stage hardware performance counters FPGA graph stacks tune
against. Spans cover the whole pipeline:

=============== ============================================= =========
span            where                                          attrs
=============== ============================================= =========
``compile``     :func:`repro.compile` (front-end + passes)     frontend, cache_hit, fingerprint
``lower``       ``Program.lower`` / ``Accelerator.__init__``   fingerprint, target, bucket
``bind``        ``Accelerator.bind`` / session construction    fingerprint, n_vertices, n_edges
``run``         one ``Engine``/``BatchEngine`` execution       launches, batch K, version
``launch:<k>``  one device-kernel launch                       mode, direction, frontier occupancy
``superstep``   one distributed shuffle superstep              kernel, devices, shuffle elements
``update``      ``StreamingSession.update``                    delta sizes, version
``repair``      incremental recomputation of a cached result   program, version
``schedule``    ``GraphService.submit`` admission              tenant, label, deadline
``queue_wait``  submit -> scheduler pickup                     tenant
``batch_form``  scheduler fill-wait while forming a batch      batch K
``execute``     scheduler running a formed batch               tenant, label, batch K
=============== ============================================= =========

Usage::

    import repro, repro.telemetry as tel

    tracer = tel.enable()            # start recording (process-wide)
    result = repro.run("bfs", graph, root=0)
    print(result.trace)              # per-run summary (hottest kernels)
    tracer.export_chrome("trace.json")   # load in Perfetto / chrome://tracing
    tel.disable()                    # back to the no-op null tracer

Tracing is **off by default**: the module-level tracer is a
:class:`~repro.telemetry.tracer.NullTracer` whose spans are preallocated
no-ops, and instrumentation sites guard on ``tracer.enabled`` — ci_bench
gates the overhead of both states (``telemetry_overhead``).

For always-on production use, ``tel.enable(sample=0.1)`` keeps ~10% of
traces (decided once per root span; kept traces stay complete).
"""
from __future__ import annotations

import threading
from typing import Any, Optional, Union

from .tracer import (  # noqa: F401 - re-exported API
    NULL_SPAN,
    NULL_TRACER,
    NullTracer,
    Span,
    SpanContext,
    Tracer,
)
from .export import chrome_events, export_chrome, prometheus_text  # noqa: F401

__all__ = [
    "Tracer",
    "NullTracer",
    "Span",
    "SpanContext",
    "enable",
    "disable",
    "enabled",
    "get",
    "span",
    "current",
    "export_chrome",
    "chrome_events",
    "prometheus_text",
]

_install_lock = threading.Lock()
_active: Union[Tracer, NullTracer] = NULL_TRACER


def enable(max_spans: int = 200_000, *, sample: Optional[float] = None,
           seed: Optional[int] = None) -> Tracer:
    """Install (or return) the process-wide recording tracer.

    Idempotent: a second ``enable()`` returns the already-active tracer
    (its retained spans intact) so independent layers can call it without
    clobbering each other.

    ``sample`` enables head-based trace sampling: each new *root* span is
    kept with probability ``sample`` (``enable(sample=0.1)`` records ~10%
    of traces); descendants follow their root's decision so kept traces
    stay complete. ``None`` (the default) leaves an already-active
    tracer's rate untouched and means "record everything" on first
    enable. ``seed`` makes the sampling sequence deterministic and only
    applies when the tracer is first created.
    """
    global _active
    with _install_lock:
        if not isinstance(_active, Tracer):
            _active = Tracer(max_spans=max_spans,
                             sample=1.0 if sample is None else sample,
                             seed=seed)
        elif sample is not None:
            if not 0.0 <= sample <= 1.0:
                raise ValueError(f"sample must be in [0, 1], got {sample!r}")
            _active.sample = float(sample)
        return _active


def disable() -> None:
    """Swap back to the null tracer and drop every retained span.

    After ``disable()`` the active tracer retains nothing: ``get().
    spans() == []`` and new spans are no-ops.
    """
    global _active
    with _install_lock:
        if isinstance(_active, Tracer):
            _active.reset()
        _active = NULL_TRACER


def get() -> Union[Tracer, NullTracer]:
    """The active tracer (never None; null tracer when disabled)."""
    return _active


def enabled() -> bool:
    return _active.enabled


def span(name: str, *, parent: Optional[SpanContext] = None, **attrs: Any):
    """Open a span on the active tracer (no-op context when disabled)."""
    return _active.span(name, parent=parent, **attrs)


def current() -> Optional[SpanContext]:
    """Context token of the innermost open span (cross-thread handoff)."""
    return _active.current()
