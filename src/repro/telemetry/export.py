"""Trace exporters: Chrome ``trace_event`` JSON + Prometheus-style text.

Chrome export targets the (stable, documented) JSON Object Format that
both ``chrome://tracing`` and Perfetto load: complete events (``"ph":
"X"``) with microsecond ``ts``/``dur``, grouped by pid/tid, plus
``thread_name`` metadata events so lanes are labeled. Span attributes
ride in ``args`` and parent links are preserved as ``args.span_id`` /
``args.parent_id`` so a tree can be reconstructed from the file alone.

The Prometheus exposition is the pull-model twin, merged into
``GraphService.stats()``: per span name, ``repro_span_count``,
``repro_span_duration_seconds_sum`` / ``_max`` and bucket-derived
``quantile`` samples from the shared fixed-bucket histograms.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List

__all__ = ["export_chrome", "chrome_events", "prometheus_text"]


def _json_safe(v: Any) -> Any:
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    return str(v)


def chrome_events(tracer) -> List[Dict[str, Any]]:
    """Retained spans as a ``traceEvents`` list (complete + metadata)."""
    pid = os.getpid()
    events: List[Dict[str, Any]] = []
    thread_names: Dict[int, str] = {}
    for s in tracer.spans():
        thread_names.setdefault(s.thread_id, s.thread_name)
        args = {k: _json_safe(v) for k, v in s.attrs.items()}
        args["span_id"] = s.span_id
        if s.parent_id is not None:
            args["parent_id"] = s.parent_id
        args["trace_id"] = s.trace_id
        events.append({
            "name": s.name,
            "cat": s.name.split(":", 1)[0],
            "ph": "X",
            "ts": (tracer.epoch_s + s.t_start) * 1e6,
            "dur": s.duration_s * 1e6,
            "pid": pid,
            "tid": s.thread_id,
            "args": args,
        })
    for tid, tname in thread_names.items():
        events.append({
            "name": "thread_name",
            "ph": "M",
            "pid": pid,
            "tid": tid,
            "args": {"name": tname},
        })
    return events


def export_chrome(tracer, path: str) -> int:
    """Write ``{"traceEvents": [...]}`` JSON to ``path``.

    Returns the number of duration (``"ph": "X"``) events written. A
    disabled (null) tracer writes a valid empty trace — callers can
    unconditionally export at shutdown.
    """
    events = chrome_events(tracer)
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    d = os.path.dirname(os.path.abspath(path))
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return sum(1 for e in events if e.get("ph") == "X")


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def prometheus_text(tracer) -> str:
    """Span histograms in the Prometheus text exposition format."""
    hists = tracer.histograms()
    if not hists:
        return ""
    lines = [
        "# TYPE repro_span_count counter",
        "# TYPE repro_span_duration_seconds summary",
    ]
    for name in sorted(hists):
        h = hists[name]
        label = f'span="{_escape_label(name)}"'
        lines.append(f"repro_span_count{{{label}}} {h.total}")
        lines.append(
            f"repro_span_duration_seconds_sum{{{label}}} {h.sum_s:.6f}"
        )
        lines.append(
            f"repro_span_duration_seconds_max{{{label}}} {h.max_s:.6f}"
        )
        for q in (50, 90, 99):
            lines.append(
                f'repro_span_duration_seconds{{{label},quantile="0.{q}"}} '
                f"{h.percentile(q):.6f}"
            )
    dropped = getattr(tracer, "dropped", 0)
    lines.append("# TYPE repro_spans_dropped counter")
    lines.append(f"repro_spans_dropped {dropped}")
    return "\n".join(lines) + "\n"
