"""Convenience runners: thin wrappers over the Program/Session API.

Each runner compiles its algorithm once (``repro.compile`` is keyed by a
content hash of the canonical MIR + options, so repeated calls share one
artifact), binds a session to the caller's graph, and runs it with
explicit parameters. Each returns the algorithm's primary result array
(mapped back to original vertex/edge ids) plus the EngineResult for
stats inspection.

Every runner takes an optional ``source`` override accepting **either
front-end** — a ``.gt`` text string or an embedded
:class:`repro.frontend.GraphProgram` (e.g. the twins in
:mod:`repro.algorithms.embedded`) — as long as it declares the
properties/parameters the runner extracts.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, TYPE_CHECKING, Union

import numpy as np

from ..core import CompileOptions
from ..core.program import compile_program
from ..graph.storage import GraphData
from . import sources

if TYPE_CHECKING:  # pragma: no cover
    from ..frontend import GraphProgram

Source = Union[str, "GraphProgram"]

# immutable: every bind() gets a fresh list (a caller mutating its
# session's argv must not be able to poison subsequent runners)
_ARGV = ("prog", "<graph>")


def _run(
    src: Source,
    graph: GraphData,
    options: Optional[CompileOptions],
    params: Dict,
    backend: str = "local",
):
    session = compile_program(src, options).bind(
        graph, backend=backend, argv=list(_ARGV)
    )
    return session.run(**params)


def run_bfs(
    graph: GraphData,
    root: int = 0,
    options: Optional[CompileOptions] = None,
    backend: str = "local",
    source: Optional[Source] = None,
) -> Tuple[np.ndarray, object]:
    res = _run(source if source is not None else sources.BFS_ECP,
               graph, options, {"root": root}, backend)
    return res.properties["old_level"], res


def run_bfs_hybrid(
    graph: GraphData,
    root: int = 0,
    options: Optional[CompileOptions] = None,
    backend: str = "local",
    source: Optional[Source] = None,
) -> Tuple[np.ndarray, object]:
    res = _run(source if source is not None else sources.BFS_HYBRID,
               graph, options, {"root": root}, backend)
    return res.properties["old_level"], res


def run_pagerank(
    graph: GraphData,
    iters: int = 20,
    options: Optional[CompileOptions] = None,
    backend: str = "local",
    source: Optional[Source] = None,
) -> Tuple[np.ndarray, object]:
    res = _run(source if source is not None else sources.PAGERANK,
               graph, options, {"iters": iters}, backend)
    return res.properties["rank"], res


def run_sssp(
    graph: GraphData,
    root: int = 0,
    options: Optional[CompileOptions] = None,
    backend: str = "local",
    source: Optional[Source] = None,
) -> Tuple[np.ndarray, object]:
    res = _run(source if source is not None else sources.SSSP,
               graph, options, {"root": root}, backend)
    return res.properties["SP"], res


def run_ppr(
    graph: GraphData,
    source: int = 0,
    options: Optional[CompileOptions] = None,
    max_iters: int = 100,
    backend: str = "local",
    program: Optional[Source] = None,
) -> Tuple[np.ndarray, object]:
    # NB: `source` here is the personalization vertex (paper Algorithm 1),
    # so the front-end override parameter is named `program`
    res = _run(
        program if program is not None else sources.PPR,
        graph, options, {"source": source, "max_iters": max_iters}, backend,
    )
    return res.properties["PR_old"], res


def run_cgaw(
    graph: GraphData,
    options: Optional[CompileOptions] = None,
    backend: str = "local",
    source: Optional[Source] = None,
) -> Tuple[np.ndarray, object]:
    res = _run(source if source is not None else sources.CGAW,
               graph, options, {}, backend)
    return res.properties["weight"], res


def run_wcc(
    graph: GraphData,
    options: Optional[CompileOptions] = None,
    backend: str = "local",
    source: Optional[Source] = None,
) -> Tuple[np.ndarray, object]:
    res = _run(source if source is not None else sources.WCC,
               graph, options, {}, backend)
    return res.properties["comp"], res


def run_kcore(
    graph: GraphData,
    k: int = 2,
    options: Optional[CompileOptions] = None,
    backend: str = "local",
    source: Optional[Source] = None,
) -> Tuple[np.ndarray, object]:
    res = _run(source if source is not None else sources.KCORE,
               graph, options, {"k": k}, backend)
    return res.properties["alive"], res


def make_warm_runner(
    src: Source,
    graph: GraphData,
    options: Optional[CompileOptions] = None,
    overrides: Optional[dict] = None,
    backend: str = "local",
    aot: bool = False,
):
    """Deprecated: use ``repro.run(src, graph, **params)`` / ``repro.serve()``.

    The serving tier supersedes this wrapper — ``repro.run`` routes
    through the same resident-session / warm-artifact / cold-compile
    selection with registry-wide reuse, and ``repro.serve()`` adds
    batching, tenants, and deadlines. Kept as a shim for existing
    callers; emits a :class:`DeprecationWarning`.
    """
    import warnings

    warnings.warn(
        "make_warm_runner is deprecated: use repro.run(src, graph, **params) "
        "for one-shot warm execution, or repro.serve() for a long-lived "
        "GraphService (resident sessions, artifact warm starts, batching)",
        DeprecationWarning,
        stacklevel=2,
    )
    return _make_warm_runner(src, graph, options, overrides, backend, aot)


def _make_warm_runner(
    src: Source,
    graph: GraphData,
    options: Optional[CompileOptions] = None,
    overrides: Optional[dict] = None,
    backend: str = "local",
    aot: bool = False,
):
    """Bind a session once (compiling all kernels on the first call) and
    return a zero-arg callable that re-runs it — the "post-synthesis
    accelerator execution" timing mode. ``src`` is text or embedded.

    ``aot=True`` routes through the Accelerator path instead:
    ``program.lower(target, shape).bind(graph)`` — kernels are AOT-compiled
    against the graph's shape bucket before the first run, which is the
    honest analogue of timing a synthesized bitstream (and lets callers
    reuse the accelerator via ``runner.accelerator`` for same-shape
    graphs)."""
    program = compile_program(src, options)
    accelerator = None
    if aot:
        accelerator = program.lower(
            program.options.resolve_target(kind=backend), graph=graph
        )
        session = accelerator.bind(graph, argv=list(_ARGV))
    else:
        session = program.bind(graph, backend=backend, argv=list(_ARGV))
    params = dict(overrides or {})

    def run():
        return session.run(**params)

    run()  # warm: compile (or first-touch) every kernel launch path
    run.accelerator = accelerator
    run.session = session
    return run
