"""Convenience runners: compile + run each algorithm on a GraphData.

Each runner returns the algorithm's primary result array (mapped back to
original vertex/edge ids) plus the EngineResult for stats inspection.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..core import CompileOptions, Engine, compile_source
from ..graph.storage import GraphData
from . import sources

_MODULE_CACHE: dict = {}


def _module(src: str):
    key = id(src)
    if key not in _MODULE_CACHE:
        _MODULE_CACHE[key] = compile_source(src)
    return _MODULE_CACHE[key]


def _run(src: str, graph: GraphData, options: CompileOptions, overrides: dict):
    eng = Engine(_module(src), graph, options, argv=["prog", "<graph>"])
    eng.host_env.update(overrides)
    return eng.run()


def run_bfs(
    graph: GraphData,
    root: int = 0,
    options: CompileOptions = CompileOptions(),
) -> Tuple[np.ndarray, object]:
    res = _run(sources.BFS_ECP, graph, options, {"root": root})
    return res.properties["old_level"], res


def run_bfs_hybrid(
    graph: GraphData,
    root: int = 0,
    options: CompileOptions = CompileOptions(),
) -> Tuple[np.ndarray, object]:
    res = _run(sources.BFS_HYBRID, graph, options, {"root": root})
    return res.properties["old_level"], res


def run_pagerank(
    graph: GraphData,
    iters: int = 20,
    options: CompileOptions = CompileOptions(),
) -> Tuple[np.ndarray, object]:
    res = _run(sources.PAGERANK, graph, options, {"iters": iters})
    return res.properties["rank"], res


def run_sssp(
    graph: GraphData,
    root: int = 0,
    options: CompileOptions = CompileOptions(),
) -> Tuple[np.ndarray, object]:
    res = _run(sources.SSSP, graph, options, {"root": root})
    return res.properties["SP"], res


def run_ppr(
    graph: GraphData,
    source: int = 0,
    options: CompileOptions = CompileOptions(),
    max_iters: int = 100,
) -> Tuple[np.ndarray, object]:
    res = _run(sources.PPR, graph, options, {"source": source, "max_iters": max_iters})
    return res.properties["PR_old"], res


def run_cgaw(
    graph: GraphData,
    options: CompileOptions = CompileOptions(),
) -> Tuple[np.ndarray, object]:
    res = _run(sources.CGAW, graph, options, {})
    return res.properties["weight"], res


def run_wcc(
    graph: GraphData,
    options: CompileOptions = CompileOptions(),
) -> Tuple[np.ndarray, object]:
    res = _run(sources.WCC, graph, options, {})
    return res.properties["comp"], res


def run_kcore(
    graph: GraphData,
    k: int = 2,
    options: CompileOptions = CompileOptions(),
) -> Tuple[np.ndarray, object]:
    res = _run(sources.KCORE, graph, options, {"k": k})
    return res.properties["alive"], res


def make_warm_runner(src: str, graph: GraphData, options: CompileOptions,
                     overrides: Optional[dict] = None):
    """Build an engine once (compiling all kernels on the first call) and
    return a zero-arg callable that resets + re-runs it — the
    "post-synthesis accelerator execution" timing mode."""
    eng = Engine(_module(src), graph, options, argv=["prog", "<graph>"])
    ov = overrides or {}

    def run():
        eng.reset()
        eng.host_env.update(ov)
        return eng.run()

    run()  # warm: jit-compile every kernel launch path
    return run
