"""Graphitron DSL sources for the paper's evaluation algorithms.

BFS follows paper Fig. 1 (top-down, ECP) and Fig. 2 (direction-switching
hybrid). SSSP is the Fig. 5 program — the compiler performs the Fig. 6 RAW
decoupling automatically. PPR and CGAW follow Algorithms 1 and 2. WCC and
k-core are beyond-paper additions demonstrating expressiveness.
"""

# --------------------------------------------------------------------------
# BFS — paper Fig. 1 (top-down, edge-centric)
# --------------------------------------------------------------------------
BFS_ECP = r"""
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const old_level: vector{Vertex}(int);
const new_level: vector{Vertex}(int);
const tuple: vector{Vertex}(int);
const level: int = 1;
const activeVertex: vector{Vertex}(int);
const root: int = 0;

func reset(v: Vertex)
    old_level[v] = -1;
    new_level[v] = -1;
    tuple[v] = 2147483647;
end
func EdgeTraversal(src: Vertex, dst: Vertex)
    if (old_level[src] == level)
        tuple[dst] min= level + 1;
    end
end
func VertexUpdate(v: Vertex)
    if ((tuple[v] == (level + 1)) & (old_level[v] == -1))
        new_level[v] = tuple[v];
        activeVertex[0] = activeVertex[0] + 1;
    end
end
func VertexApply(v: Vertex)
    old_level[v] = new_level[v];
end
func main()
    vertices.init(reset);  % Initialization
    old_level[root] = 1;
    new_level[root] = 1;
    var frontier_size: int = 1;
    while (frontier_size)
        edges.process(EdgeTraversal);
        vertices.process(VertexUpdate);
        vertices.process(VertexApply);
        frontier_size = activeVertex[0];
        activeVertex[0] = 0;
        level += 1;
    end
end
"""

# --------------------------------------------------------------------------
# BFS — paper Fig. 2 (direction-switching hybrid VCP/ECP)
# --------------------------------------------------------------------------
BFS_HYBRID = r"""
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const old_level: vector{Vertex}(int);
const new_level: vector{Vertex}(int);
const tuple: vector{Vertex}(int);
const level: int = 1;
const activeVertex: vector{Vertex}(int);
const root: int = 0;

func reset(v: Vertex)
    old_level[v] = -1;
    new_level[v] = -1;
    tuple[v] = 2147483647;
end
func EdgeTraversal(src: Vertex, dst: Vertex)
    if (old_level[src] == level)
        tuple[dst] min= level + 1;
    end
end
func VertexTraversal(v: Vertex)
    if (old_level[v] == level)
        for ngh in v.getNeighbors()
            tuple[ngh] min= level + 1;
        end
    end
end
func VertexUpdate(v: Vertex)
    if ((tuple[v] == (level + 1)) & (old_level[v] == -1))
        new_level[v] = tuple[v];
        activeVertex[0] = activeVertex[0] + 1;
    end
end
func VertexApply(v: Vertex)
    old_level[v] = new_level[v];
end
func main()
    vertices.init(reset);
    old_level[root] = 1;
    new_level[root] = 1;
    var frontier_size: int = 1;
    while (frontier_size)
        if (frontier_size < 0.05 * vertices.size())
            vertices.process(VertexTraversal);
        else
            edges.process(EdgeTraversal);
        end
        vertices.process(VertexUpdate);
        vertices.process(VertexApply);
        frontier_size = activeVertex[0];
        activeVertex[0] = 0;
        level += 1;
    end
end
"""

# --------------------------------------------------------------------------
# PageRank (edge-centric, fixed iterations)
# --------------------------------------------------------------------------
PAGERANK = r"""
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const rank: vector{Vertex}(float);
const contrib: vector{Vertex}(float);
const deg: vector{Vertex}(int) = edges.getOutDegrees();
const damp: float = 0.85;
const iters: int = 20;

func initRank(v: Vertex)
    rank[v] = 1.0 / to_float(vertices.size());
    contrib[v] = 0.0;
end
func computeContrib(src: Vertex, dst: Vertex)
    if (deg[src] > 0)
        contrib[dst] += rank[src] / to_float(deg[src]);
    end
end
func applyRank(v: Vertex)
    rank[v] = (1.0 - damp) / to_float(vertices.size()) + damp * contrib[v];
    contrib[v] = 0.0;
end
func main()
    vertices.init(initRank);
    var i: int = 0;
    while (i < iters)
        edges.process(computeContrib);
        vertices.process(applyRank);
        i = i + 1;
    end
end
"""

# --------------------------------------------------------------------------
# SSSP — paper Fig. 5 form; the compiler applies the Fig. 6 decoupling
# --------------------------------------------------------------------------
SSSP = r"""
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex, int) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const SP: vector{Vertex}(int);
const tuple: vector{Vertex}(int);
const active: vector{Vertex}(int);
const activeNext: vector{Vertex}(int);
const changed: vector{Vertex}(int);
const root: int = 0;
const INF: int = 1073741823;

func initSP(v: Vertex)
    SP[v] = INF;
    tuple[v] = INF;
    active[v] = 0;
    activeNext[v] = 0;
end
func relax(src: Vertex, dst: Vertex, weight: int)
    if (active[src] == 1)
        tuple[dst] min= (SP[src] + weight);
    end
end
func update(v: Vertex)
    if (tuple[v] < SP[v])
        SP[v] = tuple[v];
        activeNext[v] = 1;
        changed[0] = changed[0] + 1;
    end
end
func advance(v: Vertex)
    active[v] = activeNext[v];
    activeNext[v] = 0;
end
func main()
    vertices.init(initSP);
    SP[root] = 0;
    active[root] = 1;
    var n_changed: int = 1;
    while (n_changed)
        changed[0] = 0;
        edges.process(relax);
        vertices.process(update);
        vertices.process(advance);
        n_changed = changed[0];
    end
end
"""

# --------------------------------------------------------------------------
# PPR — paper Algorithm 1 (personalized PageRank with convergence count)
# --------------------------------------------------------------------------
PPR = r"""
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const PR_old: vector{Vertex}(float);
const PR_new: vector{Vertex}(float);
const contrib: vector{Vertex}(float);
const map: vector{Vertex}(float);
const conv: vector{Vertex}(int);
const deg: vector{Vertex}(int) = edges.getOutDegrees();
const m: float = 0.85;
const eps: float = 0.001;
const source: int = 0;
const max_iters: int = 100;

func initPPR(v: Vertex)
    PR_old[v] = 0.0;
    PR_new[v] = 0.0;
    contrib[v] = 0.0;
    map[v] = 0.0;
end
func spread(src: Vertex, dst: Vertex)
    if (deg[src] > 0)
        contrib[dst] += PR_old[src] / to_float(deg[src]);
    end
end
func applyPPR(v: Vertex)
    PR_new[v] = (1.0 - m) * map[v] + m * contrib[v];
    if (abs(PR_new[v] - PR_old[v]) < eps)
        conv[0] = conv[0] + 1;
    end
    contrib[v] = 0.0;
end
func main()
    vertices.init(initPPR);
    map[source] = 1.0;
    PR_old[source] = 1.0;
    var done: int = 0;
    var it: int = 0;
    while ((done < vertices.size()) & (it < max_iters))
        conv[0] = 0;
        edges.process(spread);
        vertices.process(applyPPR);
        swap(PR_new, PR_old);
        done = conv[0];
        it = it + 1;
    end
end
"""

# --------------------------------------------------------------------------
# CGAW — paper Algorithm 2 (graph attention weights; writes edge weights)
# --------------------------------------------------------------------------
CGAW = r"""
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex, float) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const feat: vector{Vertex}(float);
const expsum: vector{Vertex}(float);

func initFeat(v: Vertex)
    feat[v] = sigmoid(to_float(original_id(v)) * 0.001 - 1.0);
    expsum[v] = 0.0;
end
func score(src: Vertex, dst: Vertex, weight: float)
    weight = leakyrelu(feat[src] + feat[dst], 0.2);
    expsum[dst] += exp(weight);
end
func normalize(src: Vertex, dst: Vertex, weight: float)
    weight = exp(weight) / expsum[dst];
end
func main()
    vertices.init(initFeat);
    edges.process(score);
    edges.process(normalize);
end
"""

# --------------------------------------------------------------------------
# WCC — label propagation (beyond paper; exercises src-side scatter)
# --------------------------------------------------------------------------
WCC = r"""
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const comp: vector{Vertex}(int);
const comp_next: vector{Vertex}(int);
const changed: vector{Vertex}(int);

func initComp(v: Vertex)
    comp[v] = v;
    comp_next[v] = v;
end
func propagate(src: Vertex, dst: Vertex)
    comp_next[dst] min= comp[src];
    comp_next[src] min= comp[dst];
end
func applyComp(v: Vertex)
    if (comp_next[v] < comp[v])
        comp[v] = comp_next[v];
        changed[0] = changed[0] + 1;
    end
end
func main()
    vertices.init(initComp);
    var n_changed: int = 1;
    while (n_changed)
        changed[0] = 0;
        edges.process(propagate);
        vertices.process(applyComp);
        n_changed = changed[0];
    end
end
"""

# --------------------------------------------------------------------------
# k-core — iterative peeling (beyond paper)
# --------------------------------------------------------------------------
KCORE = r"""
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const alive: vector{Vertex}(int);
const degc: vector{Vertex}(int);
const removed: vector{Vertex}(int);
const k: int = 2;

func initAlive(v: Vertex)
    alive[v] = 1;
end
func resetDeg(v: Vertex)
    degc[v] = 0;
end
func countDeg(src: Vertex, dst: Vertex)
    if ((alive[src] == 1) & (alive[dst] == 1))
        degc[src] = degc[src] + 1;
        degc[dst] = degc[dst] + 1;
    end
end
func peel(v: Vertex)
    if ((alive[v] == 1) & (degc[v] < k))
        alive[v] = 0;
        removed[0] = removed[0] + 1;
    end
end
func main()
    vertices.init(initAlive);
    var n_removed: int = 1;
    while (n_removed)
        removed[0] = 0;
        vertices.process(resetDeg);
        edges.process(countDeg);
        vertices.process(peel);
        n_removed = removed[0];
    end
end
"""
