"""The paper's five evaluation algorithms, written in the Graphitron DSL.

Each algorithm is a ``.gt``-style source string (paper Fig. 1/2 syntax)
plus a convenience runner; BFS and PageRank additionally ship as embedded
:class:`~repro.frontend.GraphProgram` twins (:mod:`.embedded`) that
compile to the same cache entry. These are the exact programs used by
the benchmarks and the correctness tests (oracles: networkx / numpy).
"""
from .sources import BFS_ECP, BFS_HYBRID, PAGERANK, SSSP, PPR, CGAW, WCC, KCORE
from .embedded import (
    BFS_ECP_EMBEDDED,
    PAGERANK_EMBEDDED,
    build_bfs_ecp,
    build_pagerank,
)
from .runners import (
    run_bfs,
    run_bfs_hybrid,
    run_pagerank,
    run_sssp,
    run_ppr,
    run_cgaw,
    run_wcc,
    run_kcore,
)

__all__ = [
    "BFS_ECP", "BFS_HYBRID", "PAGERANK", "SSSP", "PPR", "CGAW", "WCC", "KCORE",
    "BFS_ECP_EMBEDDED", "PAGERANK_EMBEDDED", "build_bfs_ecp", "build_pagerank",
    "run_bfs", "run_bfs_hybrid", "run_pagerank", "run_sssp", "run_ppr",
    "run_cgaw", "run_wcc", "run_kcore",
]
