"""Evaluation algorithms authored through the embedded Python front-end.

These are the *exact* twins of their text sources in
:mod:`repro.algorithms.sources` — same declarations in the same order,
same kernel bodies — so ``repro.compile(BFS_ECP_EMBEDDED)`` and
``repro.compile(sources.BFS_ECP)`` produce MIR-hash-identical modules
and resolve to one Program cache entry. The equivalence matrix in
``tests/test_embedded_frontend.py`` pins bit-identical results across
backends and pass configurations.

Use the ``build_*()`` factories for a fresh :class:`GraphProgram` (e.g.
to extend one), or the module-level singletons for direct compilation::

    import repro
    from repro.algorithms.embedded import BFS_ECP_EMBEDDED

    levels = repro.compile(BFS_ECP_EMBEDDED).bind(graph).run(root=0)
"""
from __future__ import annotations

from ..frontend import GraphProgram, to_float


def build_bfs_ecp() -> GraphProgram:
    """Top-down edge-centric BFS (paper Fig. 1), embedded form."""
    p = GraphProgram("bfs_ecp")
    edges = p.edgeset("edges")
    vertices = p.vertexset("vertices")
    old_level = p.vertex_prop("old_level", int)
    new_level = p.vertex_prop("new_level", int)
    tuple_ = p.vertex_prop("tuple", int)
    level = p.scalar("level", int, init=1)
    activeVertex = p.vertex_prop("activeVertex", int)
    root = p.scalar("root", int, init=0)

    @p.vertex_kernel
    def reset(v):
        old_level[v] = -1
        new_level[v] = -1
        tuple_[v] = 2147483647

    @p.edge_kernel
    def EdgeTraversal(src, dst):
        if old_level[src] == level:
            tuple_[dst] = min(tuple_[dst], level + 1)

    @p.vertex_kernel
    def VertexUpdate(v):
        if (tuple_[v] == level + 1) and (old_level[v] == -1):
            new_level[v] = tuple_[v]
            activeVertex[0] = activeVertex[0] + 1

    @p.vertex_kernel
    def VertexApply(v):
        old_level[v] = new_level[v]

    @p.main
    def main():
        vertices.init(reset)
        old_level[root] = 1
        new_level[root] = 1
        frontier_size: int = 1
        while frontier_size:
            edges.process(EdgeTraversal)
            vertices.process(VertexUpdate)
            vertices.process(VertexApply)
            frontier_size = activeVertex[0]
            activeVertex[0] = 0
            level += 1

    return p


def build_pagerank() -> GraphProgram:
    """Edge-centric PageRank with fixed iterations, embedded form."""
    p = GraphProgram("pagerank")
    edges = p.edgeset("edges")
    vertices = p.vertexset("vertices")
    rank = p.vertex_prop("rank", float)
    contrib = p.vertex_prop("contrib", float)
    deg = p.vertex_prop("deg", int, init=edges.out_degrees())
    damp = p.scalar("damp", float, init=0.85)
    iters = p.scalar("iters", int, init=20)

    @p.vertex_kernel
    def initRank(v):
        rank[v] = 1.0 / to_float(vertices.size())
        contrib[v] = 0.0

    @p.edge_kernel
    def computeContrib(src, dst):
        if deg[src] > 0:
            contrib[dst] += rank[src] / to_float(deg[src])

    @p.vertex_kernel
    def applyRank(v):
        rank[v] = (1.0 - damp) / to_float(vertices.size()) + damp * contrib[v]
        contrib[v] = 0.0

    @p.main
    def main():
        vertices.init(initRank)
        i: int = 0
        while i < iters:
            edges.process(computeContrib)
            vertices.process(applyRank)
            i = i + 1

    return p


# ready-to-compile singletons (GraphPrograms are immutable after build:
# to_fir() deep-copies, so sharing them across compiles is safe)
BFS_ECP_EMBEDDED = build_bfs_ecp()
PAGERANK_EMBEDDED = build_pagerank()

__all__ = [
    "build_bfs_ecp",
    "build_pagerank",
    "BFS_ECP_EMBEDDED",
    "PAGERANK_EMBEDDED",
]
