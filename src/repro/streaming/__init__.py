"""Streaming graph updates + incremental recomputation.

``StreamingSession`` serves queries over a graph that mutates in place via
:class:`~repro.graph.storage.GraphDelta`; monotone programs (BFS/SSSP/CC)
repair cached results incrementally instead of recomputing from scratch.
"""
from ..graph.storage import GraphDelta, GraphUpdateError
from .incremental import repair_result
from .session import StreamingSession

__all__ = [
    "GraphDelta",
    "GraphUpdateError",
    "StreamingSession",
    "repair_result",
]
