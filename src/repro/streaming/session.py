"""Versioned serving over a mutating graph: :class:`StreamingSession`.

A StreamingSession wraps the ordinary serving surface (:class:`Session` or
:class:`SessionPool`) with a monotonically increasing *graph version*:

- ``update(delta)`` applies a :class:`~repro.graph.storage.GraphDelta`
  **in place** via :meth:`GraphData.apply_updates` — the physical buffer
  shapes never change, so rebinding the engines is a shape-check-only
  refresh with zero re-lowering — then bumps the version. If the delta
  overflows the padding slack, the graph is transparently re-bucketed
  (:meth:`GraphShape.bucket_for`) and the serving surface rebuilt.
- ``run()``/``submit()`` pin every admitted query to the version current at
  admission; results carry ``result.version`` and concurrent updates wait
  for in-flight queries (a readers-writer gate with writer priority), so a
  query never observes a torn half-updated graph.
- Results are cached per parameter binding. A cache hit at the current
  version is free; a hit at an older version is *incrementally repaired*
  (:mod:`repro.streaming.incremental`) when the program is monotone
  (min=/max= reductions only — BFS/SSSP/CC) and every pending delta is
  additions-only, and falls back to a full re-run otherwise (PageRank-class
  programs always take the full path).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.accelerator import Accelerator, GraphShape
from ..core.engine import EngineResult
from ..core.passes import analyze_incremental
from ..graph.storage import GraphData, GraphDelta, GraphUpdateError
from .incremental import repair_result
from .. import telemetry as tel

__all__ = ["StreamingSession"]


class _RWGate:
    """Readers-writer lock with writer priority.

    Queries hold read slots (possibly across threads: ``submit`` acquires on
    the caller thread and releases when the Future resolves); ``update``
    takes the write side. A waiting writer blocks *new* readers so a steady
    query stream cannot starve updates.
    """

    def __init__(self) -> None:
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self) -> None:
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer or self._readers:
                    self._cond.wait()
                self._writer = True
            finally:
                self._writers_waiting -= 1

    def release_write(self) -> None:
        with self._cond:
            self._writer = False
            self._cond.notify_all()


class StreamingSession:
    """Serve queries over a graph that receives streaming edge updates.

    Parameters
    ----------
    program / graph
        The compiled program and the (padded) graph to serve. ``graph``
        must carry padding slack (``pad_to`` a bucket, e.g. via
        ``GraphShape.bucket_for``) for in-place updates to land in.
    accelerator
        Optional AOT :class:`Accelerator` to bind instead of JIT-lowering
        through ``program``; in-bucket updates keep its executables warm
        (``stats.compile_time_s == 0`` after warm-up).
    pool_size / batch
        ``pool_size >= 1`` serves through a :class:`SessionPool` (enabling
        :meth:`submit`); ``batch > 1`` additionally turns on dynamic
        batching inside the pool.
    """

    def __init__(
        self,
        program,
        graph: GraphData,
        backend: str = "local",
        *,
        accelerator: Optional[Accelerator] = None,
        pool_size: int = 0,
        batch: int = 0,
        cache_results: bool = True,
        cache_size: int = 256,
        compact_every: int = 64,
        delta_log: int = 256,
        argv: Optional[list] = None,
        **backend_opts,
    ) -> None:
        if accelerator is not None:
            program = accelerator.program
        self.program = program
        self.graph = graph
        self.backend = backend if accelerator is None else accelerator.target.kind
        self.version = 0
        self.cache_results = cache_results
        self.cache_size = cache_size
        self.compact_every = compact_every
        self._accelerator = accelerator
        self._pool_size = pool_size
        self._batch = batch
        self._argv = argv
        self._backend_opts = backend_opts
        self._gate = _RWGate()
        self._cache_lock = threading.Lock()
        self._info = None  # lazy analyze_incremental verdict
        self._results: "OrderedDict[Tuple, Tuple[int, EngineResult]]" = OrderedDict()
        # (version, delta) per update; None delta marks a non-repairable
        # event (re-bucketing replaced the physical arrays).
        self._deltas: "deque[Tuple[int, Optional[GraphDelta]]]" = deque(
            maxlen=delta_log
        )
        self.session = None
        self.pool = None
        self._build_sessions()

        # observability
        self.updates = 0
        self.rebuckets = 0
        self.cache_hits = 0
        self.incremental_runs = 0
        self.full_runs = 0
        self.update_apply_s: List[float] = []

    # -- construction --------------------------------------------------------
    def _build_sessions(self) -> None:
        if self.session is not None:
            self.session.close()
        if self.pool is not None:
            self.pool.close()
        acc = self._accelerator
        if self._pool_size >= 1:
            opts = dict(self._backend_opts)
            opts.setdefault("batch", self._batch)
            if acc is not None:
                self.pool = acc.pool(
                    self.graph, size=self._pool_size, argv=self._argv, **opts
                )
            else:
                self.pool = self.program.pool(
                    self.graph, size=self._pool_size, backend=self.backend,
                    argv=self._argv, **opts,
                )
            self.session = None
        else:
            if acc is not None:
                self.session = acc.bind(
                    self.graph, argv=self._argv, **self._backend_opts
                )
            else:
                self.session = self.program.bind(
                    self.graph, backend=self.backend, argv=self._argv,
                    **self._backend_opts,
                )
            self.pool = None

    @property
    def incremental_info(self):
        """The monotonicity verdict for this program (lazy, cached)."""
        if self._info is None:
            self._info = analyze_incremental(self.program.module)
        return self._info

    # -- update path ---------------------------------------------------------
    def update(self, delta: GraphDelta) -> int:
        """Apply ``delta``, rebind the serving surface, bump the version.

        Blocks until in-flight queries drain (writer-priority gate), so no
        query ever runs against a half-applied graph. Returns the new
        version. In-bucket updates are shape-check-only rebinds; a delta
        that overflows the padding slack triggers a transparent re-bucket
        (new lowering unless an artifact for the new bucket is cached).
        """
        t0 = time.perf_counter()
        tr = tel.get()
        sp = tr.span(
            "update", n_added=delta.n_added, program=self.program.fingerprint[:16],
        ) if tr.enabled else tel.NULL_SPAN
        self._gate.acquire_write()
        try:
            with sp:
                rebucketed = False
                try:
                    self.graph.apply_updates(delta)
                except GraphUpdateError:
                    self._rebucket(delta)
                    rebucketed = True
                self.updates += 1
                if (
                    not rebucketed
                    and self.compact_every
                    and self.updates % self.compact_every == 0
                ):
                    self.graph.compact()
                target = self.pool if self.pool is not None else self.session
                target.refresh_graph(self.graph)
                self.version += 1
                self._deltas.append((self.version, None if rebucketed else delta))
                sp.set(version=self.version, rebucketed=rebucketed)
                return self.version
        finally:
            self._gate.release_write()
            self.update_apply_s.append(time.perf_counter() - t0)

    def _rebucket(self, delta: GraphDelta) -> None:
        """Grow into a fresh geometric bucket and replay ``delta`` there."""
        g = self.graph
        real = ~g._free_slot_mask()
        base = GraphData(
            n_vertices=g.n_vertices_logical,
            src=np.asarray(g.src[real][: g.n_edges_logical]),
            dst=np.asarray(g.dst[real][: g.n_edges_logical]),
            weights=(
                np.asarray(g.weights[real][: g.n_edges_logical])
                if g.weights is not None
                else None
            ),
        )
        shape = GraphShape.bucket_for(
            base.n_vertices, base.n_edges + delta.n_added, weighted=g.weighted
        )
        padded = base.pad_to(shape.n_vertices, shape.n_edges)
        padded.apply_updates(delta)
        self.graph = padded
        if self._accelerator is not None:
            # the old artifact is pinned to the old bucket; lower a new one
            self._accelerator = self.program.lower(
                self._accelerator.target, shape
            )
        self._build_sessions()
        self.rebuckets += 1

    # -- query path ----------------------------------------------------------
    def run(self, **params) -> EngineResult:
        """Answer one query at the current graph version (synchronous)."""
        coerced = self.program.validate_params(params)
        key = tuple(sorted(coerced.items()))
        self._gate.acquire_read()
        try:
            served = self._serve_cached(key)
            if served is not None:
                return served
            result = self._run_full(coerced)
            result.version = self.version
            self._store(key, result)
            return result
        finally:
            self._gate.release_read()

    def submit(self, **params) -> "Future[EngineResult]":
        """Async :meth:`run`; requires ``pool_size >= 1`` for true async.

        The read slot taken at admission is held until the Future resolves,
        pinning the query to the version it was admitted under even while
        an :meth:`update` is waiting.
        """
        coerced = self.program.validate_params(params)
        key = tuple(sorted(coerced.items()))
        self._gate.acquire_read()
        try:
            served = self._serve_cached(key)
            if served is None and self.pool is None:
                served = self._run_full(coerced)
                served.version = self.version
                self._store(key, served)
            if served is not None:
                out: "Future[EngineResult]" = Future()
                out.set_result(served)
                self._gate.release_read()
                return out
        except BaseException:
            self._gate.release_read()
            raise
        version = self.version
        out = Future()

        def _resolve(inner: "Future[EngineResult]") -> None:
            try:
                result = inner.result()
            except BaseException as exc:
                self._gate.release_read()
                out.set_exception(exc)
                return
            result.version = version
            self.full_runs += 1
            self._store(key, result, version=version)
            self._gate.release_read()
            out.set_result(result)

        try:
            self.pool.submit(**coerced).add_done_callback(_resolve)
        except BaseException:
            self._gate.release_read()
            raise
        return out

    def warmup(self, **params) -> None:
        """Pre-touch every executable (all pool workers when pooled)."""
        self._gate.acquire_read()
        try:
            if self.pool is not None:
                self.pool.warmup(**params)
            else:
                coerced = self.program.validate_params(params)
                result = self.session.run(**coerced)
                result.version = self.version
                self._store(tuple(sorted(coerced.items())), result)
        finally:
            self._gate.release_read()

    # -- internals -----------------------------------------------------------
    def _serve_cached(self, key: Tuple) -> Optional[EngineResult]:
        """Current-version cache hit, or an incremental repair of an older
        cached result; None when a full run is required."""
        if not self.cache_results:
            return None
        hit = self._results.get(key)
        if hit is None:
            return None
        cached_version, cached = hit
        if cached_version == self.version:
            self.cache_hits += 1
            self._results.move_to_end(key)
            return cached
        added = self._added_since(cached_version)
        if added is None:
            return None
        tr = tel.get()
        sp = tr.span(
            "repair", program=self.program.fingerprint[:16],
            from_version=cached_version, to_version=self.version,
            added_edges=int(len(added)),
        ) if tr.enabled else tel.NULL_SPAN
        with sp:
            result = repair_result(
                self.incremental_info, self.graph, cached, added,
                version=self.version,
            )
        self.incremental_runs += 1
        self._store(key, result)
        return result

    def _added_since(self, version: int) -> Optional[np.ndarray]:
        """Concatenated additions between ``version`` and now, or None when
        the window is not repairable (non-monotone program, trimmed log,
        re-bucket event, or any removal in the window)."""
        if not self.incremental_info.incremental_ok:
            return None
        window = [d for v, d in self._deltas if v > version]
        if len(window) != self.version - version:
            return None  # log trimmed: cannot reconstruct the delta chain
        if any(d is None or not d.additions_only for d in window):
            return None
        if not window:
            return np.zeros((0, 2), dtype=np.int64)
        return np.concatenate([d.added_edges for d in window]).astype(np.int64)

    def _run_full(self, coerced: Dict[str, Any]) -> EngineResult:
        self.full_runs += 1
        if self.pool is not None:
            return self.pool.submit(**coerced).result()
        return self.session.run(**coerced)

    def _store(self, key: Tuple, result: EngineResult,
               version: Optional[int] = None) -> None:
        if not self.cache_results:
            return
        v = self.version if version is None else version
        with self._cache_lock:
            existing = self._results.get(key)
            if existing is not None and existing[0] > v:
                return  # never clobber a newer-version result
            self._results[key] = (v, result)
            self._results.move_to_end(key)
            while len(self._results) > self.cache_size:
                self._results.popitem(last=False)

    # -- lifecycle -----------------------------------------------------------
    @property
    def batch_stats(self):
        return self.pool.batch_stats if self.pool is not None else None

    def close(self) -> None:
        if self.pool is not None:
            self.pool.close()
        if self.session is not None:
            self.session.close()

    def __enter__(self) -> "StreamingSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
