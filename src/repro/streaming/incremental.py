"""Host-side incremental repair for monotone graph programs.

After an additions-only :class:`~repro.graph.storage.GraphDelta`, a cached
result of a *monotone* program (every per-edge write is a ``min=``/``max=``
reduction — BFS, SSSP, connected components) is still a valid over-estimate:
new edges can only *improve* (decrease, for min-space) the fixpoint, never
worsen it. Repair therefore seeds a decrease-only relaxation wave from the
delta's endpoints and runs it to convergence on the host — touching only the
affected region — instead of re-running the accelerator from scratch.

The repaired result is **bit-identical** to a from-scratch run on the updated
graph, including auxiliary properties and host scalars:

- distance templates keep their neighbor-minimum ``tuple`` property exact via
  a final maintenance pass over the out-edges of every changed/new source;
- mirror properties (``new_level``, ``comp_next``) equal the primary at any
  fixpoint, so they are copied from the repaired primary;
- convergence flags/counters are zero at any fixpoint and are taken from the
  cached result unchanged; a BFS-style round scalar is recomputed as
  ``max(finite level) + 1``.

Everything here is plain NumPy over the graph's CSR/CSC views in the
*original* vertex id space (cached results are always translated back to
original ids, and the streaming session's graph is never hub-relabeled), so
repair needs no device work and no re-lowering at all.

Arrays not touched by the repair are shared with the cached result rather
than copied; results are read-only by convention throughout the library.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import numpy as np

from ..core.engine import EngineResult, EngineStats
from ..core.mir import IncrementalInfo, IncrementalTemplate
from ..graph.storage import GraphData

__all__ = ["repair_result"]

# Internal +inf for unit-distance repair: far above any int32 level but with
# headroom so INF + 1 never wraps int64.
_INF = np.int64(1) << 60


def _expand(
    frontier: np.ndarray,
    indptr: np.ndarray,
    indices: np.ndarray,
    perm: Optional[np.ndarray],
) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """Gather the adjacency of ``frontier``: (targets, sources, edge_ids).

    ``sources`` repeats each frontier vertex once per incident slot, so
    ``targets[i]`` is reached from ``sources[i]`` via original edge
    ``perm[slot_i]`` (None when the caller does not need edge ids).
    """
    frontier = frontier.astype(np.int64)
    counts = (indptr[frontier + 1] - indptr[frontier]).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, (z if perm is not None else None)
    starts = indptr[frontier].astype(np.int64)
    # slot index within each vertex's run: 0..count-1
    offs = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(counts) - counts, counts
    )
    eidx = np.repeat(starts, counts) + offs
    targets = indices[eidx].astype(np.int64)
    sources = np.repeat(frontier, counts)
    edges = perm[eidx].astype(np.int64) if perm is not None else None
    return targets, sources, edges


def _relax_wave(
    dist: np.ndarray,
    seeds: np.ndarray,
    indptr: np.ndarray,
    indices: np.ndarray,
    perm: Optional[np.ndarray],
    weights: Optional[np.ndarray],
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Decrease-only relaxation from ``seeds`` to fixpoint.

    Returns (final dist, changed-vertex mask, rounds). Every committed write
    is a strict decrease, so the result is the true min-plus fixpoint over
    the current graph — the same fixpoint the accelerator converges to.
    """
    changed = np.zeros(dist.shape[0], dtype=bool)
    frontier = np.asarray(seeds, dtype=np.int64)
    rounds = 0
    while frontier.size:
        rounds += 1
        targets, sources, edges = _expand(frontier, indptr, indices, perm)
        if targets.size == 0:
            break
        step = weights[edges] if weights is not None else 1
        cand = dist[sources] + step
        nd = dist.copy()
        np.minimum.at(nd, targets, cand)
        frontier = np.flatnonzero(nd < dist)
        dist = nd
        changed[frontier] = True
    return dist, changed, rounds


def _repair_distance(
    template: IncrementalTemplate,
    graph: GraphData,
    cached: EngineResult,
    added: np.ndarray,
    *,
    weighted: bool,
) -> Tuple[dict, int, dict]:
    props = dict(cached.properties)
    dist_arr = np.asarray(props[template.dist_prop])
    dtype = dist_arr.dtype
    dist = dist_arr.astype(np.int64)

    if weighted:
        # The unreached sentinel (~2^30) already behaves as +inf under min;
        # replicate device arithmetic verbatim, no remapping needed.
        reach_limit = np.int64(template.unreached)
    else:
        # BFS marks unreached as a *small* sentinel (-1); lift it to +inf so
        # min-space relaxation is uniform.
        reach_limit = _INF
        dist = np.where(dist == np.int64(template.unreached), _INF, dist)

    indptr, indices, perm = graph.csr
    w_int = (
        np.asarray(graph.weights).astype(np.int64)
        if weighted and graph.weights is not None
        else None
    )

    srcs = np.unique(added[:, 0]).astype(np.int64)
    seeds = srcs[dist[srcs] < reach_limit]
    dist, changed, rounds = _relax_wave(
        dist, seeds, indptr, indices, perm if weighted else None, w_int
    )

    # Neighbor-minimum maintenance: every source whose distance changed (and
    # every reached source of a new edge) re-offers dist+step along ALL its
    # out-edges; min against the cached tuple is exactly the from-scratch
    # value (candidates from unchanged, pre-existing sources are already
    # folded into the cached tuple).
    if template.tuple_prop is not None:
        touched = np.unique(np.concatenate([np.flatnonzero(changed), seeds]))
        tup_arr = np.asarray(props[template.tuple_prop])
        tup = tup_arr.astype(np.int64)
        if touched.size:
            targets, sources, edges = _expand(
                touched, indptr, indices, perm if weighted else None
            )
            if targets.size:
                step = w_int[edges] if w_int is not None else 1
                np.minimum.at(tup, targets, dist[sources] + step)
        props[template.tuple_prop] = tup.astype(dtype)

    if not weighted:
        dist = np.where(dist >= _INF, np.int64(template.unreached), dist)
    dist_out = dist.astype(dtype)
    props[template.dist_prop] = dist_out
    for m in template.mirror_props:
        props[m] = dist_out

    env_updates = {}
    if template.round_scalar is not None:
        finite = dist[dist < reach_limit] if weighted else dist[dist >= 0]
        env_updates[template.round_scalar] = (
            int(finite.max()) + 1 if finite.size else 1
        )
    return props, rounds, env_updates


def _repair_label(
    template: IncrementalTemplate,
    graph: GraphData,
    cached: EngineResult,
    added: np.ndarray,
) -> Tuple[dict, int, dict]:
    props = dict(cached.properties)
    arr = np.asarray(props[template.dist_prop])
    labels = arr.astype(np.int64)
    out_ptr, out_idx, _ = graph.csr
    in_ptr, in_idx, _ = graph.csc

    # Min-label flood, pushed symmetrically (the program's edge kernel
    # relaxes both endpoints): any vertex whose label drops re-enters the
    # frontier and pushes along its out- AND in-edges, so the merged
    # component converges to its global minimum — the from-scratch fixpoint.
    frontier = np.unique(added.reshape(-1)).astype(np.int64)
    rounds = 0
    while frontier.size:
        rounds += 1
        t1, s1, _ = _expand(frontier, out_ptr, out_idx, None)
        t2, s2, _ = _expand(frontier, in_ptr, in_idx, None)
        targets = np.concatenate([t1, t2])
        sources = np.concatenate([s1, s2])
        if targets.size == 0:
            break
        nl = labels.copy()
        np.minimum.at(nl, targets, labels[sources])
        frontier = np.flatnonzero(nl < labels)
        labels = nl

    out = labels.astype(arr.dtype)
    props[template.dist_prop] = out
    for m in template.mirror_props:
        props[m] = out
    return props, rounds, {}


def repair_result(
    info: IncrementalInfo,
    graph: GraphData,
    cached: EngineResult,
    added: np.ndarray,
    *,
    version: int = 0,
) -> EngineResult:
    """Repair ``cached`` against additions ``added`` ([K, 2] int array).

    ``graph`` must be the *updated* graph (additions already applied) in the
    original id space. The caller is responsible for checking
    ``info.incremental_ok`` and that every pending delta is additions-only.
    """
    template = info.template
    if template is None:
        raise ValueError("repair_result requires an incremental template")
    added = np.asarray(added, dtype=np.int64).reshape(-1, 2)
    t0 = time.perf_counter()
    if template.kind == "label":
        props, rounds, env_updates = _repair_label(template, graph, cached, added)
    elif template.kind in ("unit_distance", "weighted_distance"):
        props, rounds, env_updates = _repair_distance(
            template, graph, cached, added,
            weighted=template.kind == "weighted_distance",
        )
    else:  # pragma: no cover - analyze_incremental only emits the kinds above
        raise ValueError(f"unknown incremental template kind: {template.kind}")

    # Additions recycle padding slots, so the physical weight array changed
    # in-place; a from-scratch run would surface the new values.
    if "weight" in props and graph.weights is not None:
        props["weight"] = np.asarray(graph.weights).astype(props["weight"].dtype)

    host_env = dict(cached.host_env)
    host_env.update(env_updates)
    elapsed = time.perf_counter() - t0
    stats = EngineStats()
    stats.host_iterations = rounds
    stats.wall_time_s = elapsed
    stats.run_time_s = elapsed  # pure host work: zero compile time by design
    return EngineResult(
        properties=props, host_env=host_env, stats=stats, version=version
    )
