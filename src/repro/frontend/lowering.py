"""Python-AST -> FIR lowering for the embedded front-end.

Decorated kernel/host functions are **never executed**: their source is
re-read (``inspect.getsourcelines``), parsed with :mod:`ast`, and each
statement is lowered into the same FIR dataclasses the ``.gt`` text
parser produces (:mod:`repro.core.fir`). The supported surface is exactly
the text grammar's expression/statement set:

=====================================  =====================================
Python                                 Graphitron
=====================================  =====================================
``P[v] = e``                           ``P[v] = e;``
``P[dst] += e`` / ``-=`` / ``*=``      ``P[dst] += e;`` ...
``P[dst] = min(P[dst], e)``            ``P[dst] min= e;`` (same for max)
``if c: ... elif/else: ...``           ``if (c) ... else ... end``
``x: int = e``                         ``var x: int = e;``
``while c: ...`` (main only)           ``while (c) ... end``
``for n in v.getNeighbors(): ...``     ``for n in v.getNeighbors() ... end``
``a and b`` / ``a or b`` / ``not a``   ``a & b`` / ``a | b`` / ``!a``
``edges.process(k)`` etc.              ``edges.process(k);``
``to_float(x)``, ``exp(x)``, ...       the device builtins, verbatim
=====================================  =====================================

Anything outside that surface raises :class:`FrontendError` carrying the
**Python file and line number** of the offending construct — the embedded
analogue of the text parser's line/column diagnostics.

Name resolution: a ``Name`` is looked up as (1) a function parameter, (2)
a previously declared kernel-local / loop variable, (3) a handle or plain
``int``/``float``/``bool`` constant captured from the function's
globals/closure, (4) a declared symbol of the owning
:class:`~repro.frontend.builder.GraphProgram` with the same name.
Handles lower to the *declared* DSL name (so ``tuple_`` in Python can
back a property named ``tuple``); captured Python number constants are
inlined as literals — host-language parameterization for free.
"""
from __future__ import annotations

import ast
import contextlib
import inspect
import textwrap
from typing import List, Optional, Sequence, Tuple

from ..core import fir
from ..core.semantic import DEVICE_BUILTINS, HOST_BUILTINS


class FrontendError(Exception):
    """Embedded front-end error, located at a Python ``filename:lineno``."""

    def __init__(self, msg: str, filename: Optional[str] = None,
                 lineno: Optional[int] = None):
        loc = ""
        if filename:
            loc = f"{filename}:{lineno}: " if lineno else f"{filename}: "
        super().__init__(loc + msg)
        self.filename = filename
        self.lineno = lineno


# pythonic aliases for the DSL's camelCase set/element methods
_METHOD_ALIASES = {
    "neighbors": "getNeighbors",
    "in_neighbors": "getInNeighbors",
    "out_degrees": "getOutDegrees",
    "in_degrees": "getInDegrees",
    "vertices": "getVertices",
}

_BIN_OPS = {
    ast.Add: "+", ast.Sub: "-", ast.Mult: "*", ast.Div: "/",
}
_CMP_OPS = {
    ast.Eq: "==", ast.NotEq: "!=", ast.Lt: "<", ast.LtE: "<=",
    ast.Gt: ">", ast.GtE: ">=",
}
_REDUCE_OPS = {ast.Add: "+", ast.Sub: "-", ast.Mult: "*"}

# names callable inside kernels/main even when not importable stubs
_CALLABLE_NAMES = set(DEVICE_BUILTINS) | set(HOST_BUILTINS) - {"argv"}


def function_ast(fn) -> Tuple[ast.FunctionDef, str]:
    """The FunctionDef node of ``fn`` with absolute (file) line numbers."""
    filename = fn.__code__.co_filename
    try:
        src_lines, start = inspect.getsourcelines(fn)
    except (OSError, TypeError) as e:
        raise FrontendError(
            "cannot read the source of the decorated function (source "
            "unavailable — e.g. defined in a REPL); embedded kernels must "
            "live in a real file",
            filename=filename,
        ) from e
    tree = ast.parse(textwrap.dedent("".join(src_lines)))
    ast.increment_lineno(tree, start - 1)
    fdef = tree.body[0]
    if not isinstance(fdef, ast.FunctionDef):
        raise FrontendError(
            "decorator target must be a plain `def` function",
            filename=filename, lineno=getattr(fdef, "lineno", None),
        )
    return fdef, filename


def capture_env(fn) -> dict:
    """The function's globals merged with its closure cells.

    This is the environment handle names resolve in. Python does *not*
    create closure cells for names the function only assigns (``level +=
    1`` makes ``level`` a local), so assigned-but-undeclared names fall
    back to the owning program's declared-symbol table by DSL name.
    """
    env = dict(fn.__globals__)
    if fn.__closure__:
        for name, cell in zip(fn.__code__.co_freevars, fn.__closure__):
            with contextlib.suppress(ValueError):  # still-empty cell
                env[name] = cell.cell_contents
    return env


class Lowerer:
    """Lower one decorated function body into a list of FIR statements."""

    def __init__(self, program, fn, fdef: ast.FunctionDef, filename: str,
                 params: Sequence[str]):
        self.program = program  # GraphProgram (late import avoids a cycle)
        self.fn = fn
        self.fdef = fdef
        self.filename = filename
        self.params = list(params)
        self.locals: set = set()
        self.env = capture_env(fn)

    # -- diagnostics --------------------------------------------------------
    def err(self, msg: str, node) -> FrontendError:
        return FrontendError(
            msg, filename=self.filename, lineno=getattr(node, "lineno", None)
        )

    # -- name resolution ----------------------------------------------------
    def _lookup(self, name: str):
        """A handle/constant for ``name``, or None for params/locals/misses."""
        if name in self.env:
            return self.env[name]
        sym = self.program.symbol(name)
        return sym

    def _check_owned(self, val, name: str, node):
        """Reject handles captured from a *different* GraphProgram: they
        would silently lower by DSL name into this program's namespace."""
        owner = getattr(val, "_program", None)
        if owner is not None and owner is not self.program:
            raise self.err(
                f"handle {name!r} belongs to GraphProgram {owner.name!r}, "
                f"not {self.program.name!r}: kernels can only reference "
                "handles declared on their own program", node,
            )

    def _name_to_ident(self, node: ast.Name) -> fir.Expr:
        from .builder import Handle  # deferred: builder imports this module

        name = node.id
        ln = node.lineno
        if name in self.params or name in self.locals:
            return fir.Ident(line=ln, name=name)
        val = self._lookup(name)
        if isinstance(val, Handle):
            self._check_owned(val, name, node)
            return fir.Ident(line=ln, name=val.name)
        if isinstance(val, bool):
            return fir.BoolLit(line=ln, value=val)
        if isinstance(val, int):
            return fir.IntLit(line=ln, value=val)
        if isinstance(val, float):
            return fir.FloatLit(line=ln, value=val)
        raise self.err(
            f"unknown name {name!r}: not a kernel parameter, a declared "
            f"local (`{name}: int = ...`), a program handle, or a captured "
            f"int/float/bool constant", node,
        )

    # -- expressions --------------------------------------------------------
    def lower_expr(self, e: ast.expr) -> fir.Expr:
        ln = getattr(e, "lineno", 0)
        if isinstance(e, ast.Constant):
            v = e.value
            if isinstance(v, bool):
                return fir.BoolLit(line=ln, value=v)
            if isinstance(v, int):
                return fir.IntLit(line=ln, value=v)
            if isinstance(v, float):
                return fir.FloatLit(line=ln, value=v)
            if isinstance(v, str):
                return fir.StrLit(line=ln, value=v)
            raise self.err(f"unsupported literal {v!r}", e)
        if isinstance(e, ast.Name):
            return self._name_to_ident(e)
        if isinstance(e, ast.BinOp):
            op = _BIN_OPS.get(type(e.op))
            if op is None:
                raise self.err(
                    f"unsupported operator {type(e.op).__name__}: the DSL "
                    "has + - * / only", e,
                )
            return fir.BinOp(line=ln, op=op,
                             lhs=self.lower_expr(e.left),
                             rhs=self.lower_expr(e.right))
        if isinstance(e, ast.Compare):
            if len(e.ops) != 1:
                raise self.err(
                    "chained comparisons are not supported; split with `and`", e
                )
            op = _CMP_OPS.get(type(e.ops[0]))
            if op is None:
                raise self.err(
                    f"unsupported comparison {type(e.ops[0]).__name__}", e
                )
            return fir.BinOp(line=ln, op=op,
                             lhs=self.lower_expr(e.left),
                             rhs=self.lower_expr(e.comparators[0]))
        if isinstance(e, ast.BoolOp):
            op = "&" if isinstance(e.op, ast.And) else "|"
            out = self.lower_expr(e.values[0])
            for v in e.values[1:]:
                out = fir.BinOp(line=ln, op=op, lhs=out, rhs=self.lower_expr(v))
            return out
        if isinstance(e, ast.UnaryOp):
            if isinstance(e.op, ast.USub):
                return fir.UnaryOp(line=ln, op="-",
                                   operand=self.lower_expr(e.operand))
            if isinstance(e.op, ast.Not):
                return fir.UnaryOp(line=ln, op="!",
                                   operand=self.lower_expr(e.operand))
            if isinstance(e.op, ast.UAdd):
                return self.lower_expr(e.operand)
            raise self.err(f"unsupported unary {type(e.op).__name__}", e)
        if isinstance(e, ast.Subscript):
            return fir.Index(line=ln,
                             base=self.lower_expr(e.value),
                             index=self.lower_expr(e.slice))
        if isinstance(e, ast.Call):
            return self._lower_call(e)
        raise self.err(
            f"unsupported Python expression {type(e).__name__} in an "
            "embedded kernel", e,
        )

    def _builtin_name(self, e: ast.Call) -> Optional[str]:
        """DSL builtin name for a plain-name call, or None."""
        from .builder import KernelHandle

        if not isinstance(e.func, ast.Name):
            return None
        fname = e.func.id
        val = self._lookup(fname)
        if val is not None:
            dsl = getattr(val, "_dsl_builtin", None)
            if dsl is not None:
                return dsl
            if isinstance(val, KernelHandle):
                if not val.decl.params:  # zero-arg host helper: `helper();`
                    self._check_owned(val, fname, e)
                    return val.name
                raise self.err(
                    f"kernel {val.name!r} cannot be called directly; launch "
                    "it with vertices.init(k) / edges.process(k)", e,
                )
            if val in (min, max, abs, pow, print):
                return val.__name__
            raise self.err(
                f"{fname!r} is not a DSL builtin; kernels can only call "
                f"the builtins {', '.join(sorted(_CALLABLE_NAMES))} and "
                "zero-arg host helpers", e,
            )
        if fname in _CALLABLE_NAMES:
            return fname
        raise self.err(
            f"unknown function {fname!r}; kernels can only call the DSL "
            f"builtins ({', '.join(sorted(_CALLABLE_NAMES))}) and zero-arg "
            "host helpers", e,
        )

    def _lower_call(self, e: ast.Call) -> fir.Expr:
        ln = e.lineno
        if e.keywords:
            raise self.err("keyword arguments are not supported in the DSL", e)
        args = [self.lower_expr(a) for a in e.args]
        if isinstance(e.func, ast.Attribute):
            method = _METHOD_ALIASES.get(e.func.attr, e.func.attr)
            return fir.MethodCall(line=ln, obj=self.lower_expr(e.func.value),
                                  method=method, args=args)
        return fir.Call(line=ln, func=self._builtin_name(e), args=args)

    # -- statements ---------------------------------------------------------
    def lower_body(self) -> List[fir.Stmt]:
        body = self.fdef.body
        # skip a leading docstring
        if body and isinstance(body[0], ast.Expr) and \
                isinstance(body[0].value, ast.Constant) and \
                isinstance(body[0].value.value, str):
            body = body[1:]
        return self._lower_stmts(body)

    def _lower_stmts(self, stmts: Sequence[ast.stmt]) -> List[fir.Stmt]:
        out: List[fir.Stmt] = []
        for s in stmts:
            out.extend(self.lower_stmt(s))
        return out

    def _assign_target(self, t: ast.expr) -> fir.Expr:
        """Lower an assignment target (Name or Subscript) to an lvalue."""
        from .builder import (
            Handle, PropertyHandle, ScalarHandle,
        )

        if isinstance(t, ast.Subscript):
            return self.lower_expr(t)
        if isinstance(t, ast.Name):
            name = t.id
            if name in self.params or name in self.locals:
                return fir.Ident(line=t.lineno, name=name)
            val = self._lookup(name)
            if isinstance(val, Handle):
                self._check_owned(val, name, t)
            if isinstance(val, ScalarHandle):
                return fir.Ident(line=t.lineno, name=val.name)
            if isinstance(val, PropertyHandle):
                raise self.err(
                    f"property {val.name!r} needs an index to be written: "
                    f"`{name}[v] = ...`", t,
                )
            if isinstance(val, Handle):
                raise self.err(f"cannot assign to {type(val).__name__} "
                               f"{val.name!r}", t)
            raise self.err(
                f"assignment to undeclared name {name!r}; declare a "
                f"kernel-local with an annotation: `{name}: int = ...`", t,
            )
        raise self.err("unsupported assignment target", t)

    def _min_max_reduce(self, target: fir.Expr,
                        value: ast.expr) -> Optional[fir.ReduceAssign]:
        """``P[i] = min(P[i], e)`` / ``max`` -> ``P[i] min= e`` (the
        Pythonic spelling of the DSL's min=/max= reduction)."""
        if not (isinstance(value, ast.Call) and isinstance(value.func, ast.Name)
                and len(value.args) == 2 and not value.keywords):
            return None
        fname = value.func.id
        val = self._lookup(fname)
        dsl = getattr(val, "_dsl_builtin", None) if val is not None else None
        if val is not None and dsl is None and val in (min, max):
            dsl = val.__name__
        if val is None and fname in ("min", "max"):
            dsl = fname
        if dsl not in ("min", "max"):
            return None
        tgt_dump = fir.dump(target)
        lowered = [self.lower_expr(a) for a in value.args]
        for i in (0, 1):
            if fir.dump(lowered[i]) == tgt_dump:
                return fir.ReduceAssign(line=value.lineno, target=target,
                                        op=dsl, value=lowered[1 - i])
        return None

    def lower_stmt(self, s: ast.stmt) -> List[fir.Stmt]:
        ln = getattr(s, "lineno", 0)
        if isinstance(s, ast.Pass):
            return []
        if isinstance(s, ast.Expr):
            if isinstance(s.value, ast.Constant):
                return []  # stray docstring/ellipsis
            if not isinstance(s.value, ast.Call):
                raise self.err(
                    "expression statements must be calls "
                    "(e.g. edges.process(kernel))", s,
                )
            return [fir.ExprStmt(line=ln, expr=self.lower_expr(s.value))]
        if isinstance(s, ast.Assign):
            if len(s.targets) != 1:
                raise self.err("multiple assignment targets are not "
                               "supported", s)
            target = self._assign_target(s.targets[0])
            reduce = self._min_max_reduce(target, s.value)
            if reduce is not None:
                return [reduce]
            return [fir.Assign(line=ln, target=target,
                               value=self.lower_expr(s.value))]
        if isinstance(s, ast.AnnAssign):
            if not isinstance(s.target, ast.Name):
                raise self.err("annotated declarations must target a plain "
                               "name", s)
            ann = s.annotation
            ann_name = ann.id if isinstance(ann, ast.Name) else None
            if ann_name not in ("int", "float", "bool"):
                raise self.err(
                    "local declarations must be annotated int/float/bool "
                    f"(got {ast.dump(ann) if ann_name is None else ann_name})",
                    s,
                )
            if s.value is None:
                raise self.err(
                    f"local declaration {s.target.id!r} needs an "
                    f"initializer: `{s.target.id}: {ann_name} = ...`", s,
                )
            init = self.lower_expr(s.value)
            self.locals.add(s.target.id)
            return [fir.VarDecl(line=ln, name=s.target.id,
                                type=fir.ScalarType(ann_name), init=init)]
        if isinstance(s, ast.AugAssign):
            op = _REDUCE_OPS.get(type(s.op))
            if op is None:
                raise self.err(
                    f"unsupported in-place operator {type(s.op).__name__}: "
                    "the DSL has += -= *= (and min=/max= via "
                    "`P[i] = min(P[i], e)`)", s,
                )
            return [fir.ReduceAssign(line=ln,
                                     target=self._assign_target(s.target),
                                     op=op, value=self.lower_expr(s.value))]
        if isinstance(s, ast.If):
            return [fir.If(line=ln, cond=self.lower_expr(s.test),
                           then_body=self._lower_stmts(s.body),
                           else_body=self._lower_stmts(s.orelse))]
        if isinstance(s, ast.While):
            if s.orelse:
                raise self.err("while/else is not supported", s)
            return [fir.While(line=ln, cond=self.lower_expr(s.test),
                              body=self._lower_stmts(s.body))]
        if isinstance(s, ast.For):
            if s.orelse:
                raise self.err("for/else is not supported", s)
            if not isinstance(s.target, ast.Name):
                raise self.err("loop target must be a plain name", s)
            it = s.iter
            if not (isinstance(it, ast.Call) and
                    isinstance(it.func, ast.Attribute)):
                raise self.err(
                    "for-loops must iterate a neighbor method: "
                    "`for n in v.getNeighbors():`", s,
                )
            iter_expr = self.lower_expr(it)
            var = s.target.id
            fresh = var not in self.locals
            self.locals.add(var)
            try:
                body = self._lower_stmts(s.body)
            finally:
                if fresh:
                    self.locals.discard(var)
            return [fir.For(line=ln, var=var, iter=iter_expr, body=body)]
        if isinstance(s, ast.Return):
            raise self.err(
                "kernels and main() cannot return values; results live in "
                "properties and host scalars", s,
            )
        raise self.err(
            f"unsupported Python statement {type(s).__name__} in an "
            "embedded kernel", s,
        )
