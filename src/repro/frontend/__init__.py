"""Embedded Python front-end for the Graphitron DSL.

The second of the compiler's two front-ends (the first is the ``.gt``
text parser): author graph algorithms as decorated Python functions over
typed handles, get the **identical MIR** — and therefore the identical
passes/lowering/backends — as the textual program. ``repro.compile``
accepts either form; see :mod:`repro.frontend.builder` for the authoring
surface and :mod:`repro.frontend.lowering` for the supported grammar.

The names below (``to_float``, ``exp``, ...) are *import-for-IDE* stubs
of the DSL device builtins: importing them gives linters and completion
something real to resolve, but kernel bodies are lowered from the AST,
so the stubs are never executed (calling one at module scope raises).
Python's own ``min``/``max``/``abs``/``pow`` are recognized directly.
"""
from .builder import (
    EdgesetHandle,
    GraphProgram,
    Handle,
    InitExpr,
    KernelHandle,
    PropertyHandle,
    ScalarHandle,
    VertexsetHandle,
)
from .lowering import FrontendError


def _builtin_stub(name: str, arity: int, doc: str):
    def stub(*args):
        raise FrontendError(
            f"{name}() is a Graphitron device builtin: it can only appear "
            "inside @vertex_kernel/@edge_kernel/@main decorated bodies "
            "(which are lowered from the AST, never executed)"
        )

    stub.__name__ = name
    stub.__qualname__ = name
    stub.__doc__ = f"{doc} (DSL builtin, {arity} arg{'s' if arity != 1 else ''})."
    stub._dsl_builtin = name
    return stub


exp = _builtin_stub("exp", 1, "e**x")
log = _builtin_stub("log", 1, "natural logarithm")
sqrt = _builtin_stub("sqrt", 1, "square root")
sigmoid = _builtin_stub("sigmoid", 1, "logistic sigmoid")
leakyrelu = _builtin_stub("leakyrelu", 2, "leaky ReLU with negative slope")
floor = _builtin_stub("floor", 1, "round toward -inf")
to_float = _builtin_stub("to_float", 1, "int -> float cast")
to_int = _builtin_stub("to_int", 1, "float -> int cast")
original_id = _builtin_stub("original_id", 1, "pre-relabeling vertex id")
swap = _builtin_stub("swap", 2, "host-side O(1) buffer swap")

__all__ = [
    "GraphProgram",
    "FrontendError",
    "Handle",
    "PropertyHandle",
    "ScalarHandle",
    "VertexsetHandle",
    "EdgesetHandle",
    "KernelHandle",
    "InitExpr",
    # DSL builtin stubs
    "exp", "log", "sqrt", "sigmoid", "leakyrelu", "floor",
    "to_float", "to_int", "original_id", "swap",
]
