"""The embedded authoring surface: :class:`GraphProgram` and its handles.

A :class:`GraphProgram` is built in ordinary Python and compiles through
the exact pipeline the ``.gt`` text parser feeds — it constructs FIR
directly, so ``repro.compile(program)`` and ``repro.compile(text_twin)``
produce MIR-hash-identical modules and share one cache entry::

    from repro.frontend import GraphProgram, to_float

    p = GraphProgram("pagerank")
    edges    = p.edgeset("edges")
    vertices = p.vertexset("vertices")
    rank     = p.vertex_prop("rank", float)
    deg      = p.vertex_prop("deg", int, init=edges.out_degrees())
    iters    = p.scalar("iters", int, init=20)

    @p.vertex_kernel
    def initRank(v):
        rank[v] = 1.0 / to_float(vertices.size())

    @p.edge_kernel
    def push(src, dst):
        if deg[src] > 0:
            rank[dst] += rank[src] / to_float(deg[src])

    @p.main
    def main():
        vertices.init(initRank)
        i: int = 0
        while i < iters:
            edges.process(push)
            i = i + 1

    session = repro.compile(p).bind(graph)

Handles are *typed names*: inside decorated functions they are never
executed — the body is lowered from the Python AST
(:mod:`repro.frontend.lowering`) — so indexing/calling a handle at
module scope raises a :class:`FrontendError` pointing that out.
:meth:`GraphProgram.to_source` emits the equivalent ``.gt`` text
(``parse(p.to_source())`` round-trips to the same MIR hash).
"""
from __future__ import annotations

import copy
import keyword
from typing import Dict, List, Optional, Union

from ..core import fir
from ..core.lexer import KEYWORDS as _DSL_KEYWORDS
from .lowering import FrontendError, Lowerer, function_ast

_SCALAR_NAMES = {
    int: "int", float: "float", bool: "bool",
    "int": "int", "float": "float", "bool": "bool",
}

ScalarLike = Union[type, str]


def _scalar_name(dtype: ScalarLike, *, what: str, allow=("int", "float", "bool")):
    name = _SCALAR_NAMES.get(dtype)
    if name is None or name not in allow:
        raise FrontendError(
            f"{what} must be one of {'/'.join(allow)} (python types int/"
            f"float/bool or their names), got {dtype!r}"
        )
    return name


class InitExpr:
    """A declaration-time initializer expression (e.g. ``edges.out_degrees()``)."""

    def __init__(self, expr: fir.Expr):
        self.expr = expr


class Handle:
    """Base of all typed handles: a declared DSL name inside one program."""

    def __init__(self, program: "GraphProgram", name: str):
        self._program = program
        self.name = name

    def _only_in_kernels(self, action: str):
        raise FrontendError(
            f"{action} {type(self).__name__} {self.name!r} outside a "
            "decorated kernel: handles are lowered from the AST of "
            "@vertex_kernel/@edge_kernel/@main functions and are not "
            "executable Python values"
        )

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


class PropertyHandle(Handle):
    """A ``vector{Element}(scalar)`` property; index it inside kernels."""

    def __init__(self, program, name, element, scalar):
        super().__init__(program, name)
        self.element = element
        self.scalar = scalar

    def __getitem__(self, idx):
        self._only_in_kernels("reading")

    def __setitem__(self, idx, value):
        self._only_in_kernels("writing")


class ScalarHandle(Handle):
    """A host scalar — a declared run-time parameter of the Program."""

    def __init__(self, program, name, scalar, required):
        super().__init__(program, name)
        self.scalar = scalar
        self.required = required

    def __bool__(self):
        self._only_in_kernels("testing")

    def __add__(self, other):
        self._only_in_kernels("using")

    __radd__ = __sub__ = __rsub__ = __mul__ = __rmul__ = __add__


class VertexsetHandle(Handle):
    """The program's vertexset; ``init``/``process``/``size`` in kernels."""

    def init(self, kernel):
        self._only_in_kernels("calling init() on")

    def process(self, kernel):
        self._only_in_kernels("calling process() on")

    def size(self):
        self._only_in_kernels("calling size() on")


class EdgesetHandle(Handle):
    """The program's edgeset. ``out_degrees()``/``in_degrees()`` are
    declaration-time initializers; ``process()`` is kernel-only."""

    def __init__(self, program, name, weighted, weight_scalar):
        super().__init__(program, name)
        self.weighted = weighted
        self.weight_scalar = weight_scalar

    def process(self, kernel):
        self._only_in_kernels("calling process() on")

    # -- declaration-time initializer expressions --------------------------
    def _method_init(self, method: str) -> InitExpr:
        return InitExpr(fir.MethodCall(obj=fir.Ident(name=self.name),
                                       method=method, args=[]))

    def get_vertices(self) -> InitExpr:
        return self._method_init("getVertices")

    def out_degrees(self) -> InitExpr:
        return self._method_init("getOutDegrees")

    def in_degrees(self) -> InitExpr:
        return self._method_init("getInDegrees")

    # camelCase twins of the .gt spellings
    getVertices = get_vertices
    getOutDegrees = out_degrees
    getInDegrees = in_degrees


class KernelHandle(Handle):
    """A lowered device/host function; reference it in main()'s
    ``set.init(k)`` / ``set.process(k)`` calls."""

    def __init__(self, program, name, decl: fir.FuncDecl, fn):
        super().__init__(program, name)
        self.decl = decl
        self.fn = fn  # the original Python function (for introspection)

    def __call__(self, *args, **kwargs):
        raise FrontendError(
            f"kernel {self.name!r} is not directly callable: launch it from "
            "main() with vertices.init(k) / edges.process(k), or run the "
            "compiled program via repro.compile(program).bind(graph).run()"
        )


class GraphProgram:
    """Declarative builder for one Graphitron program.

    Declaration order is preserved into the FIR (and thus into
    :meth:`to_source` and the canonical MIR hash), exactly like the order
    of ``const``/``func`` declarations in a ``.gt`` file.
    """

    def __init__(self, name: str = "program", *, vertex_element: str = "Vertex",
                 edge_element: str = "Edge"):
        self.name = name
        self.vertex_element = vertex_element
        self.edge_element = edge_element
        self._consts: List[fir.ConstDecl] = []
        self._funcs: List[fir.FuncDecl] = []
        self._symbols: Dict[str, Handle] = {}
        self._edgeset: Optional[EdgesetHandle] = None
        self._vertexset: Optional[VertexsetHandle] = None
        self._has_main = False
        # compile memo set by repro.core.program: (MIR fingerprint, .gt
        # source); any further declaration invalidates it
        self._identity = None

    # -- symbol bookkeeping -------------------------------------------------
    def symbol(self, name: str) -> Optional[Handle]:
        """The declared handle named ``name`` (DSL name), or None."""
        return self._symbols.get(name)

    def _check_name(self, name: str):
        if not isinstance(name, str) or not name.isidentifier():
            raise FrontendError(
                f"invalid DSL identifier {name!r} in program {self.name!r}"
            )
        if name in _DSL_KEYWORDS or keyword.iskeyword(name):
            raise FrontendError(
                f"{name!r} is a keyword and cannot name a declaration "
                f"(program {self.name!r})"
            )
        if name in self._symbols:
            raise FrontendError(
                f"duplicate declaration {name!r} in program {self.name!r}"
            )

    def _declare(self, handle: Handle, decl: fir.ConstDecl) -> Handle:
        self._check_name(handle.name)
        self._symbols[handle.name] = handle
        self._consts.append(decl)
        self._identity = None
        return handle

    # -- graph declarations -------------------------------------------------
    def edgeset(self, name: str = "edges", *, weight: Optional[ScalarLike] = None,
                path: Optional[str] = None) -> EdgesetHandle:
        """Declare the program's edgeset (``const name: edgeset{Edge}(...)``).

        ``weight=int``/``float`` declares weighted edges. The default
        initializer is ``load(argv[1])`` (the graph comes from the bound
        session); ``path`` switches to ``load("path")``.
        """
        if self._edgeset is not None:
            raise FrontendError(
                f"program {self.name!r} already declares edgeset "
                f"{self._edgeset.name!r} (one edgeset per program)"
            )
        if path is not None and ('"' in path or "\n" in path):
            raise FrontendError(
                f"edgeset path {path!r} cannot contain '\"' or newlines "
                "(the DSL string syntax has no escapes)"
            )
        wt = None if weight is None else _scalar_name(
            weight, what="edge weight", allow=("int", "float"))
        ty = fir.EdgesetType(self.edge_element, self.vertex_element,
                             self.vertex_element, wt)
        arg = fir.StrLit(value=path) if path is not None else \
            fir.Index(base=fir.Ident(name="argv"), index=fir.IntLit(value=1))
        init = fir.Call(func="load", args=[arg])
        handle = EdgesetHandle(self, name, weighted=wt is not None,
                               weight_scalar=wt)
        self._declare(handle, fir.ConstDecl(name=name, type=ty, init=init))
        self._edgeset = handle
        return handle

    def vertexset(self, name: str = "vertices",
                  of: Optional[EdgesetHandle] = None) -> VertexsetHandle:
        """Declare the vertexset (``const name: vertexset{Vertex} =
        edges.getVertices();``). ``of`` defaults to the declared edgeset."""
        of = of if of is not None else self._edgeset
        if of is None:
            raise FrontendError(
                f"program {self.name!r}: declare the edgeset before the "
                "vertexset (it is derived via getVertices())"
            )
        init = fir.MethodCall(obj=fir.Ident(name=of.name),
                              method="getVertices", args=[])
        handle = VertexsetHandle(self, name)
        self._declare(handle, fir.ConstDecl(
            name=name, type=fir.VertexsetType(self.vertex_element), init=init))
        self._vertexset = handle
        return handle

    # -- data declarations --------------------------------------------------
    def _prop(self, name: str, element: str, dtype: ScalarLike,
              init) -> PropertyHandle:
        scalar = _scalar_name(dtype, what=f"property {name!r} type")
        init_expr = None
        if isinstance(init, InitExpr):
            init_expr = init.expr
        elif init is not None:
            raise FrontendError(
                f"property {name!r}: init must be a declaration-time "
                "expression like edges.out_degrees() (properties are "
                "zero-initialized; set values in an init kernel)"
            )
        handle = PropertyHandle(self, name, element, scalar)
        self._declare(handle, fir.ConstDecl(
            name=name, type=fir.VectorType(element, scalar), init=init_expr))
        return handle

    def vertex_prop(self, name: str, dtype: ScalarLike,
                    init=None) -> PropertyHandle:
        """Declare ``const name: vector{Vertex}(dtype);`` — a |V|-length
        device buffer. ``init=edges.out_degrees()`` maps the degree vector."""
        return self._prop(name, self.vertex_element, dtype, init)

    def edge_prop(self, name: str, dtype: ScalarLike,
                  init=None) -> PropertyHandle:
        """Declare ``const name: vector{Edge}(dtype);`` — an |E|-length
        device buffer."""
        return self._prop(name, self.edge_element, dtype, init)

    def scalar(self, name: str, dtype: ScalarLike, init=None) -> ScalarHandle:
        """Declare a host scalar — a run-time parameter of the compiled
        Program. ``init=None`` makes it required at ``session.run()``."""
        scalar = _scalar_name(dtype, what=f"scalar {name!r} type")
        init_expr = None
        if init is not None:
            if isinstance(init, bool) and scalar == "bool":
                init_expr = fir.BoolLit(value=init)
            elif scalar == "int" and isinstance(init, int) and \
                    not isinstance(init, bool):
                init_expr = fir.IntLit(value=init)
            elif scalar == "float" and isinstance(init, (int, float)) and \
                    not isinstance(init, bool):
                init_expr = fir.FloatLit(value=float(init))
            else:
                raise FrontendError(
                    f"scalar {name!r}: initializer {init!r} does not match "
                    f"declared type {scalar}"
                )
        handle = ScalarHandle(self, name, scalar, required=init is None)
        self._declare(handle, fir.ConstDecl(
            name=name, type=fir.ScalarType(scalar), init=init_expr))
        return handle

    # -- function decorators ------------------------------------------------
    def _register_func(self, handle: KernelHandle) -> KernelHandle:
        self._check_name(handle.name)
        self._symbols[handle.name] = handle
        self._funcs.append(handle.decl)
        self._identity = None
        return handle

    def _lower(self, fn, fdef, filename, param_names) -> List[fir.Stmt]:
        return Lowerer(self, fn, fdef, filename, param_names).lower_body()

    @staticmethod
    def _param_names(fdef, filename) -> List[str]:
        a = fdef.args
        if a.vararg or a.kwarg or a.kwonlyargs or a.posonlyargs or \
                a.defaults or a.kw_defaults:
            raise FrontendError(
                "kernel parameters must be plain positional names "
                "(no defaults, *args, **kwargs, or keyword-only)",
                filename=filename, lineno=fdef.lineno,
            )
        return [arg.arg for arg in a.args]

    def vertex_kernel(self, fn) -> KernelHandle:
        """Lower ``def k(v)`` into a vertex kernel (``func k(v: Vertex)``)."""
        fdef, filename = function_ast(fn)
        names = self._param_names(fdef, filename)
        if len(names) != 1:
            raise FrontendError(
                f"@vertex_kernel {fn.__name__!r} must take exactly one "
                f"vertex parameter, got {len(names)}",
                filename=filename, lineno=fdef.lineno,
            )
        params = [fir.Param(name=names[0],
                            type=fir.ElementType(self.vertex_element))]
        body = self._lower(fn, fdef, filename, names)
        decl = fir.FuncDecl(line=fdef.lineno, name=fn.__name__,
                            params=params, body=body)
        return self._register_func(KernelHandle(self, fn.__name__, decl, fn))

    def edge_kernel(self, fn) -> KernelHandle:
        """Lower ``def k(src, dst[, weight])`` into an edge kernel."""
        fdef, filename = function_ast(fn)
        names = self._param_names(fdef, filename)
        if len(names) not in (2, 3):
            raise FrontendError(
                f"@edge_kernel {fn.__name__!r} must take (src, dst) or "
                f"(src, dst, weight), got {len(names)} parameter(s)",
                filename=filename, lineno=fdef.lineno,
            )
        params = [
            fir.Param(name=names[0], type=fir.ElementType(self.vertex_element)),
            fir.Param(name=names[1], type=fir.ElementType(self.vertex_element)),
        ]
        if len(names) == 3:
            if self._edgeset is None or not self._edgeset.weighted:
                raise FrontendError(
                    f"@edge_kernel {fn.__name__!r} takes a weight parameter "
                    "but the program's edgeset is unweighted (declare it "
                    "with edgeset(weight=int) first)",
                    filename=filename, lineno=fdef.lineno,
                )
            params.append(fir.Param(
                name=names[2],
                type=fir.ScalarType(self._edgeset.weight_scalar)))
        body = self._lower(fn, fdef, filename, names)
        decl = fir.FuncDecl(line=fdef.lineno, name=fn.__name__,
                            params=params, body=body)
        return self._register_func(KernelHandle(self, fn.__name__, decl, fn))

    def _host_func(self, fn, name: str) -> KernelHandle:
        fdef, filename = function_ast(fn)
        names = self._param_names(fdef, filename)
        if names:
            raise FrontendError(
                f"host function {name!r} must take no parameters "
                "(host scalars are read by name)",
                filename=filename, lineno=fdef.lineno,
            )
        body = self._lower(fn, fdef, filename, names)
        decl = fir.FuncDecl(line=fdef.lineno, name=name, params=[], body=body)
        return self._register_func(KernelHandle(self, name, decl, fn))

    def main(self, fn) -> KernelHandle:
        """Lower the decorated zero-arg function into the program's
        ``main()`` host control flow (while / process / init / scalar
        updates), whatever the Python function is called."""
        if self._has_main:
            raise FrontendError(
                f"program {self.name!r} already has a @main function"
            )
        handle = self._host_func(fn, "main")
        self._has_main = True
        return handle

    def host(self, fn) -> KernelHandle:
        """Lower a zero-arg host helper function (callable from main)."""
        return self._host_func(fn, fn.__name__)

    # -- exports ------------------------------------------------------------
    def to_fir(self) -> fir.Program:
        """A fresh FIR Program (deep-copied: semantic analysis normalizes
        kernel bodies in place, and the builder's masters stay pristine)."""
        if not self._has_main:
            raise FrontendError(
                f"program {self.name!r} has no @main function; decorate the "
                "host control flow with @program.main"
            )
        if self._edgeset is None:
            raise FrontendError(
                f"program {self.name!r} declares no edgeset"
            )
        return fir.Program(
            elements=[fir.ElementDecl(name=self.vertex_element),
                      fir.ElementDecl(name=self.edge_element)],
            consts=copy.deepcopy(self._consts),
            funcs=copy.deepcopy(self._funcs),
        )

    def to_source(self) -> str:
        """Equivalent ``.gt`` text: ``parse(p.to_source())`` analyzes to a
        MIR-hash-identical module (the round-trip tests pin this)."""
        return fir.dump(self.to_fir()) + "\n"

    def fingerprint(self) -> str:
        """Canonical MIR content hash (the front-end-independent cache
        identity; equals the text twin's hash)."""
        from ..core import mir, semantic

        return mir.fingerprint(semantic.analyze(self.to_fir()))

    def __repr__(self) -> str:
        kernels = [f.name for f in self._funcs]
        return (f"GraphProgram({self.name!r}, consts={len(self._consts)}, "
                f"funcs={kernels})")
