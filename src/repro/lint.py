"""``python -m repro.lint``: the command-line face of :func:`repro.analyze`.

    python -m repro.lint file.gt [more.gt ...]
    python -m repro.lint --json mypackage.programs:PAGERANK
    python -m repro.lint --builtins          # all 8 shipped algorithms,
                                             # text AND embedded twins

Targets are ``.gt`` files or ``module:attr`` specs where the attribute is
DSL source text, an embedded :class:`~repro.frontend.GraphProgram`, or a
zero-argument callable returning either. Exit status is 1 when any target
carries an error-level diagnostic (the same gate ``strict=`` compiles and
``GraphService.submit`` enforce), 0 otherwise — lint is CI-ready as-is.

``--json`` emits one machine-readable document for the whole run (the
shape CI archives as a job artifact); the default output is the human
``Diagnostic.format()`` rendering with caret excerpts / file:lineno
provenance per front-end.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
from typing import Any, List, Tuple

from .analysis import AnalysisResult, analyze


def _load_spec(spec: str) -> Tuple[str, Any]:
    """Resolve one CLI target to (display name, analyzable object)."""
    if spec.endswith(".gt"):
        with open(spec, "r") as f:
            return spec, f.read()
    if ":" not in spec:
        raise SystemExit(
            f"repro.lint: target {spec!r} is neither a .gt file nor a "
            f"module:attr spec"
        )
    mod_name, attr = spec.split(":", 1)
    try:
        mod = importlib.import_module(mod_name)
    except ImportError as e:
        raise SystemExit(f"repro.lint: cannot import {mod_name!r}: {e}") from e
    try:
        obj = getattr(mod, attr)
    except AttributeError as e:
        raise SystemExit(
            f"repro.lint: module {mod_name!r} has no attribute {attr!r}"
        ) from e
    if callable(obj) and not hasattr(obj, "to_fir"):
        obj = obj()
    return spec, obj


def _builtin_targets() -> List[Tuple[str, Any]]:
    """All 8 shipped algorithms: text sources plus their embedded twins."""
    from .serving.service import _named_algorithms

    targets: List[Tuple[str, Any]] = [
        (f"builtin:{name}", src)
        for name, src in sorted(_named_algorithms().items())
    ]
    try:
        from .algorithms import embedded
    except ImportError:
        return targets
    for name in getattr(embedded, "__all__", []):
        obj = getattr(embedded, name)
        # ready-built singletons only; their build_* factories would lint
        # the same programs twice
        if hasattr(obj, "to_fir"):
            targets.append((f"embedded:{name}", obj))
    return targets


def _report_text(name: str, result: AnalysisResult) -> str:
    lines = [f"== {name} =="]
    for d in result.diagnostics:
        lines.append(d.format())
    lines.append(
        f"   {len(result.errors)} error(s), {len(result.warnings)} "
        f"warning(s); determinism: {result.certificate}"
    )
    return "\n".join(lines)


def main(argv: List[str] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Static analysis / lint for Graphitron programs.",
    )
    parser.add_argument(
        "targets", nargs="*",
        help=".gt files or module:attr specs (source text, GraphProgram, "
             "or a zero-arg factory of either)",
    )
    parser.add_argument(
        "--json", action="store_true", dest="as_json",
        help="emit one JSON document for the whole run",
    )
    parser.add_argument(
        "--builtins", action="store_true",
        help="lint the shipped algorithm table (text + embedded twins)",
    )
    args = parser.parse_args(argv)

    targets: List[Tuple[str, Any]] = []
    if args.builtins:
        targets.extend(_builtin_targets())
    for spec in args.targets:
        targets.append(_load_spec(spec))
    if not targets:
        parser.error("no targets: pass .gt files, module:attr specs, "
                     "or --builtins")

    results = [(name, analyze(obj)) for name, obj in targets]
    failed = any(res.errors for _, res in results)

    if args.as_json:
        doc = {
            "ok": not failed,
            "targets": {name: res.to_dict() for name, res in results},
        }
        print(json.dumps(doc, indent=2, sort_keys=True))
    else:
        for name, res in results:
            print(_report_text(name, res))
        n_err = sum(len(r.errors) for _, r in results)
        n_warn = sum(len(r.warnings) for _, r in results)
        print(f"lint: {len(results)} target(s), {n_err} error(s), "
              f"{n_warn} warning(s)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
