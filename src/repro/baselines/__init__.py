from . import thundergp

__all__ = ["thundergp"]
