"""ThunderGP-style template engine (the paper's comparison system).

Faithful to ThunderGP's design constraints (paper §II-B, Table III):
* gather-apply-scatter (GAS) model, **edge-centric only** — every superstep
  streams ALL edges regardless of frontier size (no direction switching);
* a fixed template: one user ``scatter_func`` (per-edge update value), one
  ``gather_func`` (associative reduce), one ``apply_func`` (per-vertex);
* a fixed property set: ONE vertex property array + the out-degree
  auxiliary (their template's documented extension) — algorithms needing
  more properties (PPR) or edge-weight writes (CGAW) raise
  ``TemplateLimitation``, reproducing Table III's x entries;
* weights are template *pseudo-weights* (random constants, not loadable,
  not writable).

The memory path is ThunderGP-optimized (dst-sorted segment reduction +
degree-relabeled layout) so the performance comparison against Graphitron
is between two tuned systems, as in the paper.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.storage import GraphData


class TemplateLimitation(NotImplementedError):
    """The algorithm does not fit the GAS template (paper Table III)."""


@dataclass
class GASTemplate:
    scatter_func: Callable  # (src_prop, pseudo_weight) -> update value
    gather_func: str  # '+', 'min', 'max'
    apply_func: Callable  # (old_prop, accumulated, aux) -> new_prop
    init: Callable  # (n_vertices, out_degree) -> prop array
    needs_extra_properties: int = 0
    writes_edge_weights: bool = False


@dataclass
class ThunderGPStats:
    supersteps: int = 0
    edges_traversed: int = 0
    wall_time_s: float = 0.0


class ThunderGPEngine:
    def __init__(self, template: GASTemplate, graph: GraphData, max_weight: int = 64):
        if template.needs_extra_properties > 1:
            raise TemplateLimitation(
                "ThunderGP's template supports one vertex property (+ out-degree)"
            )
        if template.writes_edge_weights:
            raise TemplateLimitation("ThunderGP edge weights are read-only constants")
        self.t = template
        # ThunderGP's own layout optimizations
        self.graph, _ = graph.relabel_by_degree()
        g = self.graph
        self.perm = jnp.asarray(g.dst_sort_perm)
        self.src_s = jnp.asarray(g.src[g.dst_sort_perm])
        self.dst_s = jnp.asarray(g.dst[g.dst_sort_perm])
        rng = np.random.default_rng(0)
        # pseudo weights (random values — paper §IV-C2)
        self.w_s = jnp.asarray(
            rng.integers(1, max_weight, g.n_edges).astype(np.float32)[g.dst_sort_perm]
        )
        self.out_deg = jnp.asarray(g.out_degree.astype(np.int32))
        self.stats = ThunderGPStats()
        self._step = jax.jit(self._superstep)

    def _superstep(self, prop):
        t = self.t
        vals = t.scatter_func(prop[self.src_s], self.w_s)
        seg = {
            "+": jax.ops.segment_sum,
            "min": jax.ops.segment_min,
            "max": jax.ops.segment_max,
        }[t.gather_func]
        acc = seg(vals, self.dst_s, self.graph.n_vertices, indices_are_sorted=True)
        return t.apply_func(prop, acc, self.out_deg)

    def run(self, n_supersteps: int = 0, until_unchanged: bool = False, max_steps: int = 10_000):
        g = self.graph
        prop = jnp.asarray(self.t.init(g.n_vertices, np.asarray(self.out_deg)))
        t0 = time.perf_counter()
        steps = 0
        if until_unchanged:
            while steps < max_steps:
                new = self._step(prop)
                steps += 1
                self.stats.edges_traversed += g.n_edges
                if bool(jnp.all(new == prop)):
                    prop = new
                    break
                prop = new
        else:
            for _ in range(n_supersteps):
                prop = self._step(prop)
                steps += 1
                self.stats.edges_traversed += g.n_edges
        self.stats.supersteps = steps
        self.stats.wall_time_s = time.perf_counter() - t0
        return np.asarray(prop)  # relabeled ids; see run_original_ids

    def run_original_ids(self, orig: GraphData, **kw):
        out = self.run(**kw)
        # self.graph was relabeled from `orig`: old2new = argsort order
        old2new = np.empty(orig.n_vertices, np.int32)
        old2new[orig.degree_rank] = np.arange(orig.n_vertices, dtype=np.int32)
        return out[old2new]


# --------------------------------------------------------------------------
# the three algorithms ThunderGP's template can express
# --------------------------------------------------------------------------


def pagerank_template(damp: float = 0.85) -> GASTemplate:
    return GASTemplate(
        scatter_func=lambda sp, w: sp,
        gather_func="+",
        apply_func=lambda old, acc, deg: (1 - damp) + damp * acc,
        init=lambda n, deg: np.full(n, 1.0, np.float32),
    )


def pagerank_run(graph: GraphData, iters: int = 20) -> np.ndarray:
    """PageRank with contribution pre-division folded into apply (the
    ThunderGP formulation: prop stores rank/deg)."""
    damp = 0.85
    t = GASTemplate(
        scatter_func=lambda sp, w: sp,
        gather_func="+",
        apply_func=lambda old, acc, deg: (
            ((1 - damp) / deg.shape[0] + damp * acc) / jnp.maximum(deg, 1)
        ).astype(jnp.float32),
        init=lambda n, deg: (np.full(n, 1.0 / n, np.float32) / np.maximum(deg, 1)),
    )
    eng = ThunderGPEngine(t, graph)
    out = eng.run(n_supersteps=iters)
    deg = np.asarray(eng.out_deg)
    res = out * np.maximum(deg, 1)  # undo the /deg storage
    old2new = np.empty(graph.n_vertices, np.int32)
    old2new[graph.degree_rank] = np.arange(graph.n_vertices, dtype=np.int32)
    return res[old2new], eng.stats


def bfs_run(graph: GraphData, root: int = 0):
    INF = np.int32(2**30)
    t = GASTemplate(
        scatter_func=lambda sp, w: sp + 1,
        gather_func="min",
        apply_func=lambda old, acc, deg: jnp.minimum(old, acc).astype(jnp.int32),
        init=lambda n, deg: np.full(n, INF, np.int32),
    )
    eng = ThunderGPEngine(t, graph)
    old2new = np.empty(graph.n_vertices, np.int32)
    old2new[graph.degree_rank] = np.arange(graph.n_vertices, dtype=np.int32)
    # seed the root then iterate to fixpoint (full edge sweeps — no
    # frontier, the template's documented inefficiency on traversal algos)
    prop = jnp.full((graph.n_vertices,), INF, jnp.int32).at[int(old2new[root])].set(0)
    t0 = time.perf_counter()
    steps = 0
    while steps < graph.n_vertices:
        new = eng._step(prop)
        new = jnp.minimum(new, prop)
        steps += 1
        eng.stats.edges_traversed += graph.n_edges
        if bool(jnp.all(new == prop)):
            break
        prop = new
    eng.stats.supersteps = steps
    eng.stats.wall_time_s = time.perf_counter() - t0
    return np.asarray(prop)[old2new], eng.stats


def sssp_run(graph: GraphData, root: int = 0):
    """SSSP on template *pseudo-weights* (ThunderGP cannot load real
    weights — paper §IV-C2); distances are over the pseudo-weighted graph."""
    INF = np.float32(2**30)
    t = GASTemplate(
        scatter_func=lambda sp, w: sp + w,
        gather_func="min",
        apply_func=lambda old, acc, deg: jnp.minimum(old, acc),
        init=lambda n, deg: np.full(n, INF, np.float32),
    )
    eng = ThunderGPEngine(t, graph)
    old2new = np.empty(graph.n_vertices, np.int32)
    old2new[graph.degree_rank] = np.arange(graph.n_vertices, dtype=np.int32)
    prop = jnp.full((graph.n_vertices,), INF, jnp.float32).at[int(old2new[root])].set(0.0)
    t0 = time.perf_counter()
    steps = 0
    while steps < graph.n_vertices:
        new = jnp.minimum(eng._step(prop), prop)
        steps += 1
        eng.stats.edges_traversed += graph.n_edges
        if bool(jnp.all(new == prop)):
            break
        prop = new
    eng.stats.supersteps = steps
    eng.stats.wall_time_s = time.perf_counter() - t0
    return np.asarray(prop)[old2new], eng.stats


def ppr_run(graph: GraphData, source: int = 0):
    raise TemplateLimitation(
        "PPR needs per-vertex personalization + convergence properties — "
        "beyond the template's fixed property set (paper Table III)"
    )


def cgaw_run(graph: GraphData):
    raise TemplateLimitation(
        "CGAW writes edge weights — ThunderGP weights are read-only "
        "pseudo-constants (paper Table III)"
    )


# --------------------------------------------------------------------------
# warm runners (engine + jit built once; timing covers execution only)
# --------------------------------------------------------------------------


def make_warm_pagerank(graph: GraphData, iters: int = 20):
    damp = 0.85
    t = GASTemplate(
        scatter_func=lambda sp, w: sp,
        gather_func="+",
        apply_func=lambda old, acc, deg: (
            ((1 - damp) / deg.shape[0] + damp * acc) / jnp.maximum(deg, 1)
        ).astype(jnp.float32),
        init=lambda n, deg: (np.full(n, 1.0 / n, np.float32) / np.maximum(deg, 1)),
    )
    eng = ThunderGPEngine(t, graph)

    def run():
        eng.stats = ThunderGPStats()
        return eng.run(n_supersteps=iters)

    run()  # warm
    return run


def _warm_fixpoint(graph: GraphData, t: GASTemplate, root: int, seed_val, dtype):
    eng = ThunderGPEngine(t, graph)
    old2new = np.empty(graph.n_vertices, np.int32)
    old2new[graph.degree_rank] = np.arange(graph.n_vertices, dtype=np.int32)
    INF = dtype(2 ** 30)

    def run():
        eng.stats = ThunderGPStats()
        prop = jnp.full((graph.n_vertices,), INF).at[int(old2new[root])].set(seed_val)
        steps = 0
        while steps < graph.n_vertices:
            new = jnp.minimum(eng._step(prop), prop)
            steps += 1
            eng.stats.edges_traversed += graph.n_edges
            if bool(jnp.all(new == prop)):
                break
            prop = new
        eng.stats.supersteps = steps
        return np.asarray(prop)[old2new], eng.stats

    run()  # warm
    return run


def make_warm_bfs(graph: GraphData, root: int = 0):
    t = GASTemplate(
        scatter_func=lambda sp, w: sp + 1,
        gather_func="min",
        apply_func=lambda old, acc, deg: jnp.minimum(old, acc).astype(jnp.int32),
        init=lambda n, deg: np.full(n, np.int32(2 ** 30), np.int32),
    )
    return _warm_fixpoint(graph, t, root, 0, np.int32)


def make_warm_sssp(graph: GraphData, root: int = 0):
    t = GASTemplate(
        scatter_func=lambda sp, w: sp + w,
        gather_func="min",
        apply_func=lambda old, acc, deg: jnp.minimum(old, acc),
        init=lambda n, deg: np.full(n, np.float32(2 ** 30), np.float32),
    )
    return _warm_fixpoint(graph, t, root, 0.0, np.float32)
