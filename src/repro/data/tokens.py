"""Synthetic LM data pipeline.

Deterministic, shardable, restart-safe: batch contents are a pure function
of (seed, step, shard), so a restarted job regenerates exactly the batches
it would have seen — the data-side half of fault tolerance (no data-loader
checkpoint needed).

The stream is a learnable-structure synthetic corpus: an order-1 Markov
chain over a Zipf-distributed vocabulary (models can actually reduce loss
on it, unlike uniform noise), built per-seed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

from ..configs.base import ArchConfig


@dataclass
class SyntheticLM:
    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64  # Markov states (kept small so structure is learnable)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.cfg.vocab_size
        # Zipf unigram over the vocab, shared across states
        ranks = np.arange(1, v + 1, dtype=np.float64)
        base = ranks ** -1.1
        base /= base.sum()
        # each Markov state skews toward a band of the vocabulary
        self.state_bias = rng.integers(0, v, self.n_states)
        self.base = base
        self.trans = rng.integers(0, self.n_states, (self.n_states, 8)).astype(np.int64)

    def _tokens(self, step: int, shard: int, shards: int) -> np.ndarray:
        b_local = self.global_batch // shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard
        )
        v = self.cfg.vocab_size
        out = np.empty((b_local, self.seq_len + 1), np.int32)
        state = rng.integers(0, self.n_states, b_local)
        for t in range(self.seq_len + 1):
            # banded zipf: shift the distribution by the state bias
            u = rng.random(b_local)
            # inverse-cdf sampling on the shared base via searchsorted
            cdf = np.cumsum(self.base)
            tok = np.searchsorted(cdf, u)
            out[:, t] = (tok + self.state_bias[state]) % v
            state = self.trans[state, rng.integers(0, 8, b_local)]
        return out

    def batch(self, step: int, shard: int = 0, shards: int = 1) -> Dict[str, np.ndarray]:
        toks = self._tokens(step, shard, shards)
        if self.cfg.frontend != "none":
            # modality frontend stub: deterministic pseudo-embeddings + labels
            rng = np.random.default_rng(self.seed * 7 + step)
            b_local = self.global_batch // shards
            emb = rng.standard_normal(
                (b_local, self.seq_len, self.cfg.d_model)
            ).astype(np.float32)
            return {"embeds": emb, "labels": toks[:, 1:] % self.cfg.vocab_size}
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
