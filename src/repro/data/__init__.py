from .tokens import SyntheticLM

__all__ = ["SyntheticLM"]
