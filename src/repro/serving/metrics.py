"""Serving metrics: counters + latency histograms, exported as JSON.

One :class:`ServeMetrics` instance backs a :class:`~repro.serving.service.
GraphService`. Everything is in-process and lock-protected — the serving
tier's observability contract is a *snapshot*, not a push pipeline:
``snapshot()`` returns a plain JSON-serializable dict with

* global and per-tenant / per-program query counters (submitted,
  completed, errors, overloaded rejections, deadline rejections,
  deadline misses, tuned-config hits) and latency percentiles,
* batch-formation accounting (batches, queries, occupancy against the
  scheduler's ``max_batch``),
* registry traffic (resident hits, warm artifact loads, cold lowerings,
  evictions, quarantined artifacts, single-flight shared builds).

Latency percentiles come from :class:`LatencyHistogram` — fixed
geometric buckets (no per-sample storage, bounded memory for long-lived
services); a reported percentile is the upper bound of its bucket, so it
errs pessimistic by at most the bucket ratio (~1.35x).
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["LatencyHistogram", "ServeMetrics"]

# geometric bucket boundaries: 0.1ms * 1.35^i — 48 buckets span ~0.1ms to
# ~180s, far wider than any sane graph-query latency
_BUCKET_BASE_S = 1e-4
_BUCKET_RATIO = 1.35
_N_BUCKETS = 48


def _bucket_bounds() -> List[float]:
    bounds = []
    b = _BUCKET_BASE_S
    for _ in range(_N_BUCKETS):
        bounds.append(b)
        b *= _BUCKET_RATIO
    return bounds


_BOUNDS = _bucket_bounds()


class LatencyHistogram:
    """Fixed-bucket latency histogram with percentile readout.

    Not thread-safe on its own; :class:`ServeMetrics` serializes access.
    """

    def __init__(self) -> None:
        self.counts = [0] * (_N_BUCKETS + 1)  # +1 overflow bucket
        self.total = 0
        self.sum_s = 0.0
        self.max_s = 0.0

    def record(self, seconds: float) -> None:
        seconds = max(0.0, float(seconds))
        lo, hi = 0, _N_BUCKETS
        while lo < hi:  # first bucket whose upper bound >= seconds
            mid = (lo + hi) // 2
            if _BOUNDS[mid] >= seconds:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.total += 1
        self.sum_s += seconds
        self.max_s = max(self.max_s, seconds)

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Fold ``other`` into this histogram in place (and return self).

        Bucket boundaries are module constants, so elementwise addition is
        exact — this is how multi-service / multi-worker snapshots (and the
        telemetry layer's per-span duration histograms) aggregate without
        per-sample storage.
        """
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.total += other.total
        self.sum_s += other.sum_s
        self.max_s = max(self.max_s, other.max_s)
        return self

    def percentile(self, q: float) -> float:
        """Upper bound (seconds) of the bucket holding the q-th percentile."""
        if not self.total:
            return 0.0
        rank = max(1, int(q / 100.0 * self.total + 0.9999))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return _BOUNDS[i] if i < _N_BUCKETS else self.max_s
        return self.max_s  # pragma: no cover - rank <= total by construction

    def snapshot(self) -> Dict[str, float]:
        mean = self.sum_s / self.total if self.total else 0.0
        return {
            "count": self.total,
            "mean_ms": round(mean * 1e3, 3),
            "p50_ms": round(self.percentile(50) * 1e3, 3),
            "p90_ms": round(self.percentile(90) * 1e3, 3),
            "p99_ms": round(self.percentile(99) * 1e3, 3),
            "max_ms": round(self.max_s * 1e3, 3),
        }


class _Group:
    """Counter bundle for one key (a tenant or a program label)."""

    __slots__ = (
        "submitted", "completed", "errors", "rejected_overloaded",
        "rejected_deadline", "rejections_analysis", "deadline_misses",
        "tuned_hits", "latency",
    )

    def __init__(self) -> None:
        self.submitted = 0
        self.completed = 0
        self.errors = 0
        self.rejected_overloaded = 0
        self.rejected_deadline = 0
        self.rejections_analysis = 0
        self.deadline_misses = 0
        self.tuned_hits = 0
        self.latency = LatencyHistogram()

    def snapshot(self) -> Dict[str, Any]:
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "errors": self.errors,
            "rejected_overloaded": self.rejected_overloaded,
            "rejected_deadline": self.rejected_deadline,
            "rejections_analysis": self.rejections_analysis,
            "deadline_misses": self.deadline_misses,
            "tuned_hits": self.tuned_hits,
            "latency_ms": self.latency.snapshot(),
        }


_REGISTRY_EVENTS = (
    "resident_hits",
    "artifact_hits",
    "cold_lowerings",
    "evictions",
    "quarantined",
    "single_flight_shared",
)


class ServeMetrics:
    """Thread-safe counters + histograms for one serving instance."""

    def __init__(self, max_batch: int = 1) -> None:
        self._lock = threading.Lock()
        self._t0 = time.monotonic()
        self.max_batch = max_batch
        self._global = _Group()
        self._tenants: Dict[str, _Group] = {}
        self._programs: Dict[str, _Group] = {}
        self._batches = 0
        self._batched_queries = 0
        self._registry = {k: 0 for k in _REGISTRY_EVENTS}
        # filled by the service so snapshots carry instantaneous depth
        self.queue_depth_fn: Optional[Callable[[], int]] = None

    def _groups(self, tenant: str, label: str) -> List[_Group]:
        return [
            self._global,
            self._tenants.setdefault(tenant, _Group()),
            self._programs.setdefault(label, _Group()),
        ]

    # -- request path --------------------------------------------------------
    def submitted(self, tenant: str, label: str) -> None:
        with self._lock:
            for g in self._groups(tenant, label):
                g.submitted += 1

    def rejected(self, tenant: str, label: str, kind: str) -> None:
        """kind: 'overloaded' (queue full) | 'deadline' (expired in queue)
        | 'analysis' (static analysis rejected the program at admission)."""
        field = {
            "overloaded": "rejected_overloaded",
            "deadline": "rejected_deadline",
            "analysis": "rejections_analysis",
        }.get(kind, "rejected_deadline")
        with self._lock:
            for g in self._groups(tenant, label):
                setattr(g, field, getattr(g, field) + 1)

    def completed(self, tenant: str, label: str, latency_s: float,
                  deadline_missed: bool = False) -> None:
        with self._lock:
            for g in self._groups(tenant, label):
                g.completed += 1
                g.latency.record(latency_s)
                if deadline_missed:
                    g.deadline_misses += 1

    def error(self, tenant: str, label: str) -> None:
        with self._lock:
            for g in self._groups(tenant, label):
                g.errors += 1

    def tuned_hit(self, tenant: str, label: str) -> None:
        """A submission resolved its Target from the TuningCache."""
        with self._lock:
            for g in self._groups(tenant, label):
                g.tuned_hits += 1

    # -- batch formation -----------------------------------------------------
    def batch(self, size: int) -> None:
        with self._lock:
            self._batches += 1
            self._batched_queries += size

    # -- registry traffic ----------------------------------------------------
    def registry_event(self, kind: str, n: int = 1) -> None:
        if kind not in self._registry:
            raise ValueError(f"unknown registry event {kind!r}")
        with self._lock:
            self._registry[kind] += n

    # -- export --------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            occupancy = (
                self._batched_queries / (self._batches * self.max_batch)
                if self._batches and self.max_batch else 0.0
            )
            snap: Dict[str, Any] = {
                "uptime_s": round(time.monotonic() - self._t0, 3),
                "queries": self._global.snapshot(),
                "tenants": {t: g.snapshot() for t, g in self._tenants.items()},
                "programs": {p: g.snapshot() for p, g in self._programs.items()},
                "batches": {
                    "batches": self._batches,
                    "queries": self._batched_queries,
                    "max_batch": self.max_batch,
                    "occupancy": round(occupancy, 4),
                },
                "registry": dict(self._registry),
            }
        fn = self.queue_depth_fn
        snap["queue_depth"] = int(fn()) if fn is not None else 0
        return snap

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)
