"""Artifact registry: bounded, fingerprint-keyed accelerator + session store.

The serving tier answers `submit(program, graph, **params)` for many
programs x shape buckets concurrently. This module owns the resolution
ladder behind that call:

1. **resident** — a live :class:`ResidentEntry` (bound Session + lazy
   BatchSession) for the exact (program, target, bucket, graph) already
   exists: reuse it, zero compile cost.
2. **warm artifact** — no resident entry, but the on-disk store (the
   ``~/.cache/repro-artifacts`` layout introduced with ``save`` /
   :func:`~repro.core.accelerator.load_accelerator`) holds the
   accelerator: load it (AOT executables deserialize where the backend
   supports it) and bind — no front-end, no pass pipeline, usually no
   XLA compile.
3. **cold compile** — lower a fresh :class:`Accelerator` and save it
   back best-effort.

Three serving-grade behaviors distinguish this from bare
:func:`~repro.core.accelerator.load_or_lower`:

* **LRU eviction with pin counts** — at most ``max_resident`` live
  entries; eviction *defers* teardown until every in-flight query
  releases its pin, so a size-1 registry under churn never yanks device
  state out from under a running query.
* **single-flight lowering** — concurrent requests for the same
  (program, bucket, target) share ONE load-or-lower; followers block on
  the leader's flight instead of compiling N copies.
* **negative entries + quarantine** — a store path that failed its load
  check is renamed aside (:func:`~repro.core.accelerator.
  quarantine_artifact`) and remembered for ``negative_ttl_s``; requests
  go straight to cold compile instead of re-probing the corrupt bytes
  on every miss (retry-storm guard). A successful fresh save clears the
  negative entry — the path holds known-good content again.
"""
from __future__ import annotations

import contextlib
import os
import threading
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from ..core.accelerator import (
    Accelerator,
    GraphShape,
    accelerator_fingerprint,
    load_accelerator,
    quarantine_artifact,
)
from ..core.target import Target
from ..streaming.session import _RWGate
from .metrics import ServeMetrics

__all__ = ["ArtifactRegistry", "ResidentEntry", "default_artifact_dir"]


def default_artifact_dir() -> str:
    """The shared artifact store (same resolution as ci_bench warm-start)."""
    return os.environ.get(
        "REPRO_ARTIFACT_DIR", os.path.expanduser("~/.cache/repro-artifacts")
    )


class _Flight:
    """One in-progress build that concurrent requesters wait on."""

    __slots__ = ("event", "value", "error")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.value: Any = None
        self.error: Optional[BaseException] = None


class ResidentEntry:
    """A live binding: one accelerator bound to one graph, query-ready.

    Holds a :class:`~repro.core.session.Session` (single queries) and a
    lazily-built :class:`~repro.core.session.BatchSession` (grouped
    queries), guarded by a readers-writer gate so streaming graph
    updates (:meth:`update`) wait for in-flight queries and block new
    ones — every result carries the graph ``version`` it observed.

    Lifecycle is pin-counted: the registry pins an entry per in-flight
    request and :meth:`close` (LRU eviction, registry shutdown) only
    tears the sessions down once the last pin is released.
    """

    def __init__(self, key: Tuple, accelerator: Accelerator, graph,
                 *, max_batch: int = 16) -> None:
        self.key = key
        self.accelerator = accelerator
        self.graph = graph
        self.version = 0
        self.queries = 0
        self._max_batch = max_batch
        self._gate = _RWGate()
        self._lock = threading.Lock()
        self._refs = 0
        self._closed = False
        self._torn_down = False
        self.session = accelerator.bind(graph)
        self._batch = None

    # -- pin counting --------------------------------------------------------
    def try_pin(self) -> bool:
        with self._lock:
            if self._closed:
                return False
            self._refs += 1
            return True

    def release(self) -> None:
        with self._lock:
            self._refs -= 1
            teardown = self._closed and self._refs == 0 and not self._torn_down
            if teardown:
                self._torn_down = True
        if teardown:
            self._teardown()

    def close(self) -> None:
        """Mark evicted; teardown happens when the last pin releases."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            teardown = self._refs == 0 and not self._torn_down
            if teardown:
                self._torn_down = True
        if teardown:
            self._teardown()

    def _teardown(self) -> None:
        self.session.close()
        if self._batch is not None:
            self._batch.close()

    # -- execution -----------------------------------------------------------
    def _ensure_batch(self):
        with self._lock:
            if self._batch is None:
                self._batch = self.accelerator.bind_batch(
                    self.graph, max_batch=self._max_batch
                )
            return self._batch

    def run(self, params: Dict[str, Any]):
        self._gate.acquire_read()
        try:
            result = self.session.run(**params)
            result.version = self.version
            self.queries += 1
            return result
        finally:
            self._gate.release_read()

    def run_many(self, param_sets: List[Dict[str, Any]]):
        if len(param_sets) == 1:
            return [self.run(param_sets[0])]
        self._gate.acquire_read()
        try:
            out = self._ensure_batch().run_many(param_sets)
            for r in out:
                r.version = self.version
            self.queries += len(param_sets)
            return out
        finally:
            self._gate.release_read()

    def update(self, delta) -> int:
        """Apply a graph delta in place and rebind; returns new version.

        Writer-priority: waits for in-flight queries, blocks new ones.
        The delta must fit the graph's padding slack
        (:meth:`GraphData.apply_updates` raises otherwise) — re-bucketing
        belongs to :class:`~repro.streaming.StreamingSession`.
        """
        self._gate.acquire_write()
        try:
            self.graph.apply_updates(delta)
            self.session.refresh_graph(self.graph)
            if self._batch is not None:
                self._batch.refresh_graph(self.graph)
            self.version += 1
            return self.version
        finally:
            self._gate.release_write()

    def __repr__(self) -> str:
        return (
            f"ResidentEntry({self.accelerator.fingerprint[:12]}, "
            f"v{self.version}, queries={self.queries})"
        )


class ArtifactRegistry:
    """Bounded resident-session + accelerator store over the artifact dir.

    ``acquire(program, graph, target)`` returns a **pinned**
    :class:`ResidentEntry`; the caller must :meth:`ResidentEntry.release`
    it after use. Accelerators (the expensive part) are cached separately
    from resident entries (the graph-bound part), so evicting a binding
    under ``max_resident`` pressure does not throw away its lowering.
    """

    def __init__(self, store_dir: Optional[str] = None, *,
                 max_resident: int = 8, max_accelerators: int = 32,
                 max_batch: int = 16, negative_ttl_s: float = 300.0,
                 metrics: Optional[ServeMetrics] = None) -> None:
        if max_resident < 1:
            raise ValueError("max_resident must be >= 1")
        if max_accelerators < 1:
            raise ValueError("max_accelerators must be >= 1")
        self.store_dir = store_dir
        self.max_resident = max_resident
        self.max_accelerators = max_accelerators
        self.max_batch = max_batch
        self.negative_ttl_s = negative_ttl_s
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.lowerings = 0  # accelerators actually built (not loaded)
        self._lock = threading.Lock()
        self._residents: "OrderedDict[Tuple, ResidentEntry]" = OrderedDict()
        self._accelerators: "OrderedDict[str, Accelerator]" = OrderedDict()
        self._negative: Dict[str, float] = {}  # acc fingerprint -> expiry
        self._entry_flights: Dict[Tuple, _Flight] = {}
        self._acc_flights: Dict[str, _Flight] = {}
        self._closed = False

    # -- single-flight -------------------------------------------------------
    def _single_flight(self, table: Dict, key, build):
        """Run ``build`` once per key across concurrent callers.

        Returns ``(value, leader)``; followers observe the leader's value
        (or re-raise its exception) and are counted as shared builds.
        """
        with self._lock:
            flight = table.get(key)
            leader = flight is None
            if leader:
                flight = _Flight()
                table[key] = flight
        if not leader:
            flight.event.wait()
            self.metrics.registry_event("single_flight_shared")
            if flight.error is not None:
                raise flight.error
            return flight.value, False
        try:
            flight.value = build()
            return flight.value, True
        except BaseException as e:
            flight.error = e
            raise
        finally:
            flight.event.set()
            with self._lock:
                table.pop(key, None)

    # -- accelerator resolution (warm artifact vs cold compile) --------------
    def _negative_active(self, acc_key: str) -> bool:
        expiry = self._negative.get(acc_key)
        if expiry is None:
            return False
        if time.monotonic() >= expiry:
            self._negative.pop(acc_key, None)
            return False
        return True

    def _resolve_accelerator(self, acc_key: str, program, target: Target,
                             shape: GraphShape) -> Accelerator:
        path = (
            os.path.join(self.store_dir, acc_key[:24])
            if self.store_dir else None
        )
        if path and os.path.isdir(path):
            with self._lock:
                skip = self._negative_active(acc_key)
            if not skip:
                try:
                    acc = load_accelerator(path)
                    self.metrics.registry_event("artifact_hits")
                    return acc
                except Exception:
                    # corrupt/stale content: move it aside and remember,
                    # so the miss path is taken without re-probing
                    with self._lock:
                        self._negative[acc_key] = (
                            time.monotonic() + self.negative_ttl_s
                        )
                    quarantine_artifact(path)
                    self.metrics.registry_event("quarantined")
        acc = Accelerator(program, target, shape)
        with self._lock:
            self.lowerings += 1
        self.metrics.registry_event("cold_lowerings")
        if path:
            # unwritable store: cold result is still valid
            with contextlib.suppress(OSError):
                acc.save(path)
                with self._lock:
                    # the path holds known-good content again: let the
                    # next process warm-start from it
                    self._negative.pop(acc_key, None)
        return acc

    def _accelerator_for(self, program, target: Target,
                         shape: GraphShape) -> Accelerator:
        acc_key = accelerator_fingerprint(program.fingerprint, target, shape)
        with self._lock:
            acc = self._accelerators.get(acc_key)
            if acc is not None:
                self._accelerators.move_to_end(acc_key)
                return acc
        acc, _ = self._single_flight(
            self._acc_flights, acc_key,
            lambda: self._resolve_accelerator(acc_key, program, target, shape),
        )
        with self._lock:
            self._accelerators[acc_key] = acc
            self._accelerators.move_to_end(acc_key)
            while len(self._accelerators) > self.max_accelerators:
                self._accelerators.popitem(last=False)
        return acc

    # -- resident entries ----------------------------------------------------
    def _build_entry(self, key: Tuple, program, graph, target: Target,
                     shape: GraphShape) -> ResidentEntry:
        acc = self._accelerator_for(program, target, shape)
        entry = ResidentEntry(key, acc, graph, max_batch=self.max_batch)
        entry.try_pin()  # born pinned for the building request
        evicted: List[ResidentEntry] = []
        with self._lock:
            self._residents[key] = entry
            self._residents.move_to_end(key)
            while len(self._residents) > self.max_resident:
                _, old = self._residents.popitem(last=False)
                evicted.append(old)
        for old in evicted:
            old.close()  # deferred while pinned
            self.metrics.registry_event("evictions")
        return entry

    def acquire(self, program, graph, target: Target) -> ResidentEntry:
        """Pin and return the resident entry for (program, graph, target).

        Transparently resolves resident -> warm artifact -> cold compile.
        The entry is keyed on the *identity* of ``graph`` (the registry
        keeps a strong reference, so the id is stable while resident):
        two distinct same-shape graphs get two bindings over one shared
        accelerator. Callers must ``release()`` the entry when done.
        """
        if self._closed:
            raise RuntimeError("ArtifactRegistry is closed")
        shape = GraphShape.of(graph)
        key = (program.fingerprint, target, shape, id(graph))
        while True:
            with self._lock:
                entry = self._residents.get(key)
                if entry is not None:
                    if entry.try_pin():
                        self._residents.move_to_end(key)
                        self.metrics.registry_event("resident_hits")
                        return entry
                    self._residents.pop(key, None)  # closed husk
            built, leader = self._single_flight(
                self._entry_flights, key,
                lambda: self._build_entry(key, program, graph, target, shape),
            )
            if leader:
                return built  # born pinned
            if built.try_pin():
                return built
            # the shared entry was evicted (and fully closed) before this
            # follower could pin it — rebuild

    # -- introspection / lifecycle -------------------------------------------
    def info(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "store_dir": self.store_dir,
                "resident": len(self._residents),
                "max_resident": self.max_resident,
                "accelerators": len(self._accelerators),
                "max_accelerators": self.max_accelerators,
                "lowerings": self.lowerings,
                "negative_entries": len(self._negative),
            }

    def close(self) -> None:
        with self._lock:
            self._closed = True
            entries = list(self._residents.values())
            self._residents.clear()
            self._accelerators.clear()
        for e in entries:
            e.close()
