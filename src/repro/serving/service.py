"""`repro.serve()`: the one-call serving surface over compiled graph programs.

    import repro

    service = repro.serve()                       # or repro.serve(dir)
    fut = service.submit("bfs", graph, root=3)    # async, batched
    res = service.run("pagerank", graph, iters=20)  # sync one-shot

``submit`` accepts a program by **name** (the built-in algorithm table),
as ``.gt`` source text, as an embedded
:class:`~repro.frontend.GraphProgram`, or as an already-compiled
:class:`~repro.core.program.Program` — and transparently picks the
cheapest execution path: an already-resident session, a warm on-disk
accelerator artifact, or a cold compile (which is saved back for the
next process). Multi-tenant policies (bounded queues with typed
:class:`~repro.serving.scheduler.Overloaded` shedding, weighted
fairness, per-request deadlines) ride on every call via ``tenant=`` /
``deadline_s=``; ``service.stats()`` exports the metrics snapshot.
Unless a Target is pinned, every submission resolves its Target through
the :mod:`repro.autotune` TuningCache (lookup only) — offline-tuned
configs apply transparently and count as ``tuned_hits``.

``repro.run(src_or_program, graph, **params)`` is the module-level
one-shot convenience: it routes through a process-wide default
:class:`GraphService`, so repeated calls reuse resident sessions and
warm artifacts exactly like a long-lived service would.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple, Union

from .. import telemetry as tel
from ..core.program import Program, compile_program
from ..core.session import ServiceClosed
from ..core.target import Target
from .metrics import ServeMetrics
from .registry import ArtifactRegistry, default_artifact_dir
from .scheduler import RequestScheduler, ServingError

__all__ = ["GraphService", "ProgramRejected", "serve", "run", "NAMED_ALGORITHMS"]


class ProgramRejected(ServingError):
    """Static analysis found error-level diagnostics at admission.

    Raised by :meth:`GraphService.submit` *before* the program reaches the
    scheduler or registry — a racy or otherwise broken program never
    occupies queue or artifact capacity. ``diagnostics`` carries the
    error-level :class:`~repro.analysis.Diagnostic` objects.
    """

    def __init__(self, label: str, diagnostics) -> None:
        self.label = label
        self.diagnostics = tuple(diagnostics)
        detail = "; ".join(
            f"{d.code} {d.message.splitlines()[0]}" for d in self.diagnostics
        )
        super().__init__(
            f"program {label!r} rejected by static analysis "
            f"({len(self.diagnostics)} error(s)): {detail}"
        )


def _named_algorithms() -> Dict[str, str]:
    from ..algorithms import sources

    return {
        "bfs": sources.BFS_ECP,
        "bfs_hybrid": sources.BFS_HYBRID,
        "pagerank": sources.PAGERANK,
        "sssp": sources.SSSP,
        "ppr": sources.PPR,
        "cgaw": sources.CGAW,
        "wcc": sources.WCC,
        "kcore": sources.KCORE,
    }


class _Named(dict):
    """Lazy name -> .gt source table (avoids import work at module load)."""

    def _fill(self) -> None:
        if not self:
            self.update(_named_algorithms())

    def __missing__(self, key):
        self._fill()
        if key in self:
            return self[key]
        raise KeyError(key)

    def __contains__(self, key) -> bool:
        self._fill()
        return dict.__contains__(self, key)


NAMED_ALGORITHMS = _Named()


class GraphService:
    """A long-lived, multi-tenant serving instance.

    Parameters
    ----------
    registry_dir
        On-disk artifact store for warm cross-process starts. Defaults to
        ``$REPRO_ARTIFACT_DIR`` / ``~/.cache/repro-artifacts``; pass
        ``registry_dir=False`` for a memory-only registry.
    backend / target
        ``backend`` picks the substrate kind per program (resolved from
        each program's options); an explicit ``target`` pins one
        :class:`~repro.core.target.Target` for every submission.
    autotune
        When True (the default) and no explicit ``target`` is pinned,
        each submission's Target is resolved through the
        :class:`~repro.autotune.TuningCache` colocated with the artifact
        store — a pure lookup keyed on (MIR fingerprint x shape bucket),
        never a search. Hits are counted per tenant/program as
        ``tuned_hits`` in :meth:`stats`.
    workers / max_batch / max_wait_s / max_queue / tenant_weights
        Scheduler shape: executor width, batch-formation cap and
        fill-wait, per-tenant admission bound, fairness weights
        (unlisted tenants weigh 1.0).
    max_resident / max_accelerators
        Registry bounds: live bindings (LRU, pin-safe eviction) and
        cached lowerings.
    """

    def __init__(
        self,
        registry_dir: Union[str, None, bool] = None,
        *,
        backend: str = "local",
        target: Optional[Target] = None,
        workers: int = 2,
        max_batch: int = 8,
        max_wait_s: float = 0.002,
        max_queue: int = 128,
        tenant_weights: Optional[Dict[str, float]] = None,
        max_resident: int = 8,
        max_accelerators: int = 32,
        autotune: bool = True,
        options=None,
    ) -> None:
        from ..autotune import TuningCache, tuning_dir_for

        if registry_dir is None:
            store: Optional[str] = default_artifact_dir()
        elif registry_dir is False:
            store = None
        else:
            store = str(registry_dir)
        self.backend = backend
        self.options = options
        self._target = target
        self.autotune = bool(autotune)
        # memory-only when the registry is (store=None): tuned configs
        # still apply within the process once something puts them there
        self.tuning = TuningCache(tuning_dir_for(store))
        self.metrics = ServeMetrics(max_batch=max_batch)
        self.registry = ArtifactRegistry(
            store, max_resident=max_resident,
            max_accelerators=max_accelerators, max_batch=max_batch,
            metrics=self.metrics,
        )
        self.scheduler = RequestScheduler(
            self._execute, workers=workers, max_batch=max_batch,
            max_wait_s=max_wait_s, max_queue=max_queue,
            tenant_weights=tenant_weights, metrics=self.metrics,
        )
        self.metrics.queue_depth_fn = lambda: self.scheduler.queue_depth
        self._closed = False

    # -- program resolution --------------------------------------------------
    def _resolve_program(self, program_or_name) -> Tuple[Program, str]:
        """(Program, metrics label) for a name / source / Program input."""
        if isinstance(program_or_name, Program):
            return program_or_name, program_or_name.fingerprint[:12]
        if isinstance(program_or_name, str) and program_or_name in NAMED_ALGORITHMS:
            program = compile_program(
                NAMED_ALGORITHMS[program_or_name], self.options
            )
            return program, program_or_name
        # .gt text or an embedded GraphProgram: the Program cache
        # (content-hash keyed) makes repeated resolution cheap
        program = compile_program(program_or_name, self.options)
        label = getattr(program_or_name, "name", None)
        return program, str(label) if label else program.fingerprint[:12]

    def _target_for(self, program: Program,
                    graph=None) -> Tuple[Target, bool]:
        """(Target, tuned) for one submission.

        An explicit pinned target always wins (the operator opted out of
        tuning); otherwise a TuningCache hit for (program MIR x graph
        shape bucket x backend) swaps in the tuned Target — lookup only,
        zero search trials.
        """
        if self._target is not None:
            return self._target, False
        resolved = program.options.resolve_target(kind=self.backend)
        if self.autotune and graph is not None:
            from ..autotune import program_mir_fingerprint, shape_bucket

            cfg = self.tuning.get(
                program_mir_fingerprint(program), shape_bucket(graph=graph),
                kind=self.backend,
            )
            if cfg is not None:
                return cfg.target, True
        return resolved, False

    # -- execution (called by scheduler workers) -----------------------------
    def _execute(self, job, param_sets):
        program, graph, target = job
        entry = self.registry.acquire(program, graph, target)
        try:
            return entry.run_many(param_sets)
        finally:
            entry.release()

    # -- public API ----------------------------------------------------------
    def submit(self, program_or_name, graph, *, tenant: str = "default",
               deadline_s: Optional[float] = None, **params):
        """Async: admit one query, get a Future.

        Raises :class:`~repro.serving.scheduler.Overloaded` when the
        tenant's queue is full, :class:`ProgramRejected` when static
        analysis finds error-level diagnostics (counted per-tenant as
        ``rejections_analysis`` in :meth:`stats`), and
        :class:`ServiceClosed` after :meth:`close`; parameter validation
        fails fast on the caller.
        """
        if self._closed:
            raise ServiceClosed("GraphService is closed")
        tr = tel.get()
        if not tr.enabled:
            return self._submit_impl(
                program_or_name, graph, tenant, deadline_s, params,
                tel.NULL_SPAN,
            )
        # root span of this request's trace: queue_wait / batch_form /
        # execute spans recorded on scheduler threads parent to it via
        # the Request's captured SpanContext
        with tr.span("schedule", tenant=tenant) as sp:
            return self._submit_impl(
                program_or_name, graph, tenant, deadline_s, params, sp
            )

    def _submit_impl(self, program_or_name, graph, tenant, deadline_s,
                     params, sp):
        program, label = self._resolve_program(program_or_name)
        sp.set(program=label, fingerprint=program.fingerprint[:16])
        analysis = program.diagnostics()
        if analysis.errors:
            self.metrics.rejected(tenant, label, "analysis")
            raise ProgramRejected(label, analysis.errors)
        coerced = program.validate_params(params)
        target, tuned = self._target_for(program, graph)
        if tuned:
            self.metrics.tuned_hit(tenant, label)
            sp.set(tuned=True)
        job = (program, graph, target)
        group_key = (
            program.fingerprint, id(graph), target, frozenset(coerced)
        )
        return self.scheduler.submit(
            job, coerced, group_key=group_key, tenant=tenant, label=label,
            deadline_s=deadline_s,
        )

    def run(self, program_or_name, graph, *, tenant: str = "default",
            deadline_s: Optional[float] = None, **params):
        """Sync one-shot: ``submit`` + wait."""
        return self.submit(
            program_or_name, graph, tenant=tenant, deadline_s=deadline_s,
            **params
        ).result()

    def update(self, program_or_name, graph, delta) -> int:
        """Apply a streaming delta to a served graph binding in place.

        Waits for in-flight queries on that binding (readers-writer gate,
        writer priority), applies the delta into the graph's padding
        slack, refreshes the binding, and bumps its version — subsequent
        results carry ``result.version`` of the updated graph. Returns
        the new version.
        """
        if self._closed:
            raise ServiceClosed("GraphService is closed")
        program, _ = self._resolve_program(program_or_name)
        # updates must land on the binding queries run against: resolve
        # through the same tuned-target lookup as the submit path
        target, _ = self._target_for(program, graph)
        entry = self.registry.acquire(program, graph, target)
        try:
            return entry.update(delta)
        finally:
            entry.release()

    def stats(self) -> Dict[str, Any]:
        """JSON-serializable metrics snapshot (see serving/metrics.py)."""
        snap = self.metrics.snapshot()
        snap["registry"] = {**snap["registry"], **self.registry.info()}
        snap["tuning"] = {
            "enabled": self.autotune, "store_dir": self.tuning.store_dir,
            **self.tuning.stats(),
        }
        tr = tel.get()
        if tr.enabled:
            snap["telemetry"] = tr.prometheus_text()
        return snap

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self, wait: bool = True) -> None:
        if self._closed:
            return
        self._closed = True
        self.scheduler.close(wait=wait)
        self.registry.close()

    def __enter__(self) -> "GraphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        info = self.registry.info()
        return (
            f"GraphService(resident={info['resident']}, "
            f"store={info['store_dir']!r}, "
            f"closed={self._closed})"
        )


def serve(registry_dir: Union[str, None, bool] = None, **config) -> GraphService:
    """Start a :class:`GraphService` over an artifact registry.

    The redesigned deployment surface in one call: resident sessions,
    warm artifact starts, cold compiles, dynamic batching, multi-tenant
    admission/fairness/deadlines, and a metrics snapshot — see
    :class:`GraphService` for the knobs.
    """
    return GraphService(registry_dir, **config)


_default_service: Optional[GraphService] = None
_default_lock = threading.Lock()


def default_service() -> GraphService:
    """The process-wide service backing :func:`run` (created on demand)."""
    global _default_service
    with _default_lock:
        if _default_service is None or _default_service.closed:
            _default_service = GraphService()
        return _default_service


def reset_default_service() -> None:
    """Close and forget the process-wide service (tests, env changes)."""
    global _default_service
    with _default_lock:
        svc, _default_service = _default_service, None
    if svc is not None and not svc.closed:
        svc.close()


def run(program_or_name, graph, **params):
    """One-shot convenience: serve one query through the default service.

    Routes through the same resident -> warm artifact -> cold compile
    selection as :meth:`GraphService.submit`, so the second call with the
    same (program, graph) pays zero compile time. Supersedes
    :func:`repro.algorithms.runners.make_warm_runner` for ad-hoc use.
    """
    return default_service().run(program_or_name, graph, **params)
