"""Serving tier: multi-tenant artifact registry + async SLO scheduler.

One entry point — ``repro.serve(registry_dir)`` -> :class:`GraphService`
— over three layers:

* :mod:`repro.serving.registry` — :class:`ArtifactRegistry`: bounded,
  fingerprint-keyed resident sessions + accelerators over the on-disk
  artifact store; LRU eviction with pin-safe teardown, single-flight
  lowering, quarantine + negative entries against stale-artifact retry
  storms.
* :mod:`repro.serving.scheduler` — :class:`RequestScheduler`: bounded
  per-tenant queues with typed :class:`Overloaded` shedding, weighted
  fairness, per-request deadlines propagated into batch formation.
* :mod:`repro.serving.metrics` — :class:`ServeMetrics`: per-tenant /
  per-program counters and latency histograms exported as JSON
  snapshots (``service.stats()``).
"""
from .metrics import LatencyHistogram, ServeMetrics
from .registry import ArtifactRegistry, ResidentEntry, default_artifact_dir
from .scheduler import (
    DeadlineExceeded,
    Overloaded,
    RequestScheduler,
    ServingError,
)
from .service import (
    GraphService,
    NAMED_ALGORITHMS,
    ProgramRejected,
    default_service,
    reset_default_service,
    run,
    serve,
)

__all__ = [
    "ArtifactRegistry",
    "DeadlineExceeded",
    "GraphService",
    "LatencyHistogram",
    "NAMED_ALGORITHMS",
    "Overloaded",
    "ProgramRejected",
    "RequestScheduler",
    "ResidentEntry",
    "ServeMetrics",
    "ServingError",
    "default_artifact_dir",
    "default_service",
    "reset_default_service",
    "run",
    "serve",
]
