"""Async request scheduler: admission control, fairness, deadlines, batching.

The serving front door accepts queries one at a time; the execution tier
wants them grouped (one :class:`~repro.core.session.BatchSession` launch
answers K queries). :class:`RequestScheduler` fuses the
:class:`~repro.batch.dynamic.DynamicBatcher` collection idea with the
policies a multi-tenant service needs:

* **admission control** — per-tenant bounded queues; a full queue sheds
  load with a typed :class:`Overloaded` (callers retry elsewhere/later
  instead of piling onto an unbounded backlog). In-flight work is
  bounded too (``workers * max_batch``), so backpressure keeps excess
  requests in the tenant queues where admission policies apply.
* **weighted fairness** — batch formation picks the tenant minimizing
  ``served / weight`` among non-empty queues: a weight-3 tenant gets ~3x
  the service of a weight-1 tenant under contention, and an idle
  tenant's unused share flows to the others.
* **deadlines** — ``deadline_s`` is propagated to batch formation: the
  fill-wait for stragglers never sleeps past the earliest deadline in
  the forming batch, and a request that expires while queued is failed
  with :class:`DeadlineExceeded` *without* occupying an execution slot.
  A request that completes past its deadline still returns its result
  (the caller may use it) but is counted as a deadline miss.
* **batching** — within one tenant pick, requests sharing a group key
  (same program x graph x parameter-key signature) coalesce up to
  ``max_batch``; the executor answers them with one batched run.

The scheduler is execution-agnostic: it calls
``execute(job, param_sets) -> results`` (the service maps ``job`` to a
registry entry); tests drive it with plain callables.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from .. import telemetry as tel
from ..core.session import ServiceClosed
from .metrics import ServeMetrics

__all__ = [
    "DeadlineExceeded",
    "Overloaded",
    "Request",
    "RequestScheduler",
    "ServingError",
]


class ServingError(Exception):
    """Base class for serving-tier request failures."""


class Overloaded(ServingError):
    """Admission refused: the tenant's queue is full (load shedding)."""


class DeadlineExceeded(ServingError):
    """The request's deadline expired before execution began."""


class Request:
    """One admitted query waiting for batch formation."""

    __slots__ = (
        "job", "params", "group_key", "tenant", "label",
        "deadline", "future", "t_submit", "t_submit_pc", "t_join_pc", "ctx",
    )

    def __init__(self, job: Any, params: Dict[str, Any], group_key: Any,
                 tenant: str, label: str,
                 deadline: Optional[float]) -> None:
        self.job = job
        self.params = params
        self.group_key = group_key
        self.tenant = tenant
        self.label = label
        self.deadline = deadline  # absolute time.monotonic(), or None
        self.future: "Future[Any]" = Future()
        self.t_submit = time.monotonic()
        self.t_submit_pc = time.perf_counter()
        self.t_join_pc = 0.0  # set when the request joins a forming batch
        # span context of the submitting thread: batch formation and
        # execution happen on other threads, so their spans parent here
        self.ctx = tel.current()


class RequestScheduler:
    """Admit, order, batch, and dispatch requests to an execute callable."""

    def __init__(
        self,
        execute: Callable[[Any, List[Dict[str, Any]]], List[Any]],
        *,
        workers: int = 2,
        max_batch: int = 8,
        max_wait_s: float = 0.002,
        max_queue: int = 128,
        tenant_weights: Optional[Dict[str, float]] = None,
        metrics: Optional[ServeMetrics] = None,
    ) -> None:
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self._execute = execute
        self.workers = workers
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_queue = max_queue  # per tenant
        self.metrics = metrics if metrics is not None else ServeMetrics(max_batch)
        self.metrics.max_batch = max_batch
        self._weights = {
            t: float(w) for t, w in (tenant_weights or {}).items()
        }
        self._served: Dict[str, int] = {}  # queries dispatched per tenant
        self._queues: Dict[str, Deque[Request]] = {}
        self._cond = threading.Condition()
        self._in_flight = 0  # queries dispatched, not yet resolved
        self._closed = False
        self._executor = ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="repro-serve"
        )
        self._collector = threading.Thread(
            target=self._loop, name="repro-serve-collector", daemon=True
        )
        self._collector.start()

    # -- admission -----------------------------------------------------------
    def weight(self, tenant: str) -> float:
        return self._weights.get(tenant, 1.0)

    def submit(self, job: Any, params: Dict[str, Any], *, group_key: Any,
               tenant: str = "default", label: str = "?",
               deadline_s: Optional[float] = None) -> "Future[Any]":
        deadline = (
            time.monotonic() + deadline_s if deadline_s is not None else None
        )
        req = Request(job, dict(params), group_key, tenant, label, deadline)
        with self._cond:
            if self._closed:
                raise ServiceClosed("RequestScheduler is closed")
            q = self._queues.setdefault(tenant, deque())
            if len(q) >= self.max_queue:
                self.metrics.rejected(tenant, label, "overloaded")
                raise Overloaded(
                    f"tenant {tenant!r} queue is full "
                    f"({self.max_queue} requests waiting)"
                )
            q.append(req)
            self.metrics.submitted(tenant, label)
            self._cond.notify_all()
        return req.future

    @property
    def queue_depth(self) -> int:
        """Requests queued (all tenants) + dispatched but unresolved."""
        with self._cond:
            return sum(len(q) for q in self._queues.values()) + self._in_flight

    # -- batch formation -----------------------------------------------------
    def _drop_expired_locked(self, now: float) -> None:
        """Fail queued requests whose deadline already passed (head-of-queue
        scan per tenant: queues are FIFO per tenant, but deadlines are not
        necessarily ordered, so scan the whole queue)."""
        for tenant, q in self._queues.items():
            if not q:
                continue
            keep: Deque[Request] = deque()
            for req in q:
                if req.deadline is not None and now >= req.deadline:
                    self.metrics.rejected(req.tenant, req.label, "deadline")
                    req.future.set_exception(DeadlineExceeded(
                        f"deadline expired after "
                        f"{now - req.t_submit:.3f}s in queue"
                    ))
                else:
                    keep.append(req)
            self._queues[tenant] = keep

    def _pick_tenant_locked(self) -> Optional[str]:
        """Weighted fairness: argmin served/weight over non-empty queues."""
        best, best_score = None, None
        for tenant, q in self._queues.items():
            if not q:
                continue
            score = self._served.get(tenant, 0) / self.weight(tenant)
            if best_score is None or score < best_score:
                best, best_score = tenant, score
        return best

    def _earliest_deadline_locked(self) -> Optional[float]:
        earliest = None
        for q in self._queues.values():
            for req in q:
                if req.deadline is not None:
                    earliest = (
                        req.deadline if earliest is None
                        else min(earliest, req.deadline)
                    )
        return earliest

    def _take_batch(self) -> Optional[List[Request]]:
        """Block until a batch can be formed; None when closed and drained."""
        with self._cond:
            while True:
                self._drop_expired_locked(time.monotonic())
                have = any(self._queues.values())
                room = self._in_flight < self.workers * self.max_batch
                if have and room:
                    break
                if self._closed and not have:
                    return None
                # sleep until new work / freed slot — but never past the
                # earliest queued deadline (those must be failed on time)
                timeout = None
                earliest = self._earliest_deadline_locked()
                if earliest is not None:
                    timeout = max(0.0, earliest - time.monotonic()) + 1e-4
                self._cond.wait(timeout=timeout)
            tenant = self._pick_tenant_locked()
            q = self._queues[tenant]
            head = q.popleft()
            head.t_join_pc = time.perf_counter()
            batch = [head]
            if self.max_batch > 1:
                # wait briefly for same-group stragglers — capped by the
                # forming batch's earliest deadline (SLO beats occupancy)
                limit = time.monotonic() + self.max_wait_s
                if head.deadline is not None:
                    limit = min(limit, head.deadline)
                while len(batch) < self.max_batch:
                    while q and q[0].group_key == head.group_key:
                        straggler = q.popleft()
                        straggler.t_join_pc = time.perf_counter()
                        batch.append(straggler)
                        if len(batch) >= self.max_batch:
                            break
                    if len(batch) >= self.max_batch or self._closed:
                        break
                    remaining = limit - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
            self._in_flight += len(batch)
            self._served[tenant] = self._served.get(tenant, 0) + len(batch)
        tr = tel.get()
        if tr.enabled:
            # fill-wait: head pop -> batch sealed (the head pays it all)
            tr.record_span(
                "batch_form", head.t_join_pc, time.perf_counter(),
                parent=head.ctx, tenant=tenant, batch=len(batch),
            )
            for req in batch:
                tr.record_span(
                    "queue_wait", req.t_submit_pc, req.t_join_pc,
                    parent=req.ctx, tenant=req.tenant, label=req.label,
                )
        return batch

    # -- dispatch ------------------------------------------------------------
    def _loop(self) -> None:
        while True:
            batch = self._take_batch()
            if batch is None:
                return
            try:
                self._executor.submit(self._run_batch, batch)
            except RuntimeError:
                # executor already shut down (close raced the collector):
                # fail the batch instead of dropping it silently
                exc = ServiceClosed("RequestScheduler is closed")
                for req in batch:
                    req.future.set_exception(exc)
                self._settle(len(batch))

    def _run_batch(self, batch: List[Request]) -> None:
        self.metrics.batch(len(batch))
        head = batch[0]
        tr = tel.get()
        # live span on the worker thread, parented to the head request's
        # submit-side context: engine spans opened inside _execute nest
        # under it, keeping one connected tree per request
        sp = (
            tr.span("execute", parent=head.ctx, tenant=head.tenant,
                    label=head.label, batch=len(batch))
            if tr.enabled else tel.NULL_SPAN
        )
        try:
            with sp:
                results = self._execute(
                    batch[0].job, [r.params for r in batch]
                )
        except BaseException as exc:
            for req in batch:
                self.metrics.error(req.tenant, req.label)
                req.future.set_exception(exc)
            self._settle(len(batch))
            return
        if tr.enabled and len(batch) > 1:
            # stragglers share the head's execution interval: mirror it
            # into each request's own tree so every tree carries the
            # full queue-wait vs execution split
            for req in batch[1:]:
                tr.record_span(
                    "execute", sp.t_start, sp.t_end, parent=req.ctx,
                    tenant=req.tenant, label=req.label,
                    batch=len(batch), shared=True,
                )
        now = time.monotonic()
        for req, res in zip(batch, results):
            missed = req.deadline is not None and now > req.deadline
            self.metrics.completed(
                req.tenant, req.label, now - req.t_submit,
                deadline_missed=missed,
            )
            req.future.set_result(res)
        self._settle(len(batch))

    def _settle(self, n: int) -> None:
        with self._cond:
            self._in_flight -= n
            self._cond.notify_all()

    # -- lifecycle -----------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until nothing is queued or in flight; True on success."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while any(self._queues.values()) or self._in_flight:
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    return False
                self._cond.wait(timeout=remaining)
            return True

    def close(self, wait: bool = True) -> None:
        """Stop admissions; drain what is already queued."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        if wait:
            self._collector.join(timeout=300)
        self._executor.shutdown(wait=wait)
