"""Fault-tolerant checkpointing: atomic, async, integrity-checked, elastic.

Layout:  <dir>/step_<N>/
             shard_<k>.npz        flattened param/opt arrays
             MANIFEST.json        tree structure + shapes + per-file sha256
         <dir>/LATEST             name of the newest *complete* checkpoint

Guarantees:
* **atomic**: written to ``step_<N>.tmp`` then renamed — a crash mid-write
  never corrupts the visible checkpoint;
* **integrity**: restore verifies manifest hashes; a damaged checkpoint is
  skipped and the previous one loads instead (``restore_latest`` walks
  backwards);
* **async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a daemon thread — training continues;
* **elastic**: checkpoints store *logical* (unsharded) arrays; restore
  re-shards onto whatever mesh the restarted job has (N may differ).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, shards: int = 1):
        """Synchronous atomic save."""
        self.wait()  # never race a pending async write
        flat = _flatten(tree)
        self._write(step, flat, jax.tree_util.tree_structure(tree), shards)

    def save_async(self, step: int, tree: Any, shards: int = 1):
        """Snapshot now, write in the background."""
        self.wait()
        flat = _flatten(tree)  # device->host copy happens here
        treedef = jax.tree_util.tree_structure(tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, treedef, shards), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray], treedef, shards: int):
        name = f"step_{step:08d}"
        tmp = self.dir / (name + ".tmp")
        final = self.dir / name
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        keys = sorted(flat)
        shard_files: List[str] = []
        manifest: Dict[str, Any] = {
            "step": step,
            "treedef": str(treedef),
            "keys": keys,
            "shapes": {k: list(flat[k].shape) for k in keys},
            "dtypes": {k: str(flat[k].dtype) for k in keys},
            "time": time.time(),
        }
        for sh in range(shards):
            part = {k: flat[k] for i, k in enumerate(keys) if i % shards == sh}
            fn = tmp / f"shard_{sh}.npz"
            np.savez(fn, **{k.replace(SEP, "|"): v for k, v in part.items()})
            shard_files.append(fn.name)
        manifest["files"] = {f: _sha256(tmp / f) for f in shard_files}
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        (self.dir / "LATEST.tmp").write_text(name)
        os.replace(self.dir / "LATEST.tmp", self.dir / "LATEST")
        self._gc()

    def _gc(self):
        ckpts = sorted(d for d in self.dir.iterdir() if d.is_dir() and d.name.startswith("step_") and not d.name.endswith(".tmp"))
        for d in ckpts[: -self.keep]:
            shutil.rmtree(d, ignore_errors=True)

    # ------------------------------------------------------------------
    def available_steps(self) -> List[int]:
        out = []
        for d in sorted(self.dir.iterdir()):
            if d.is_dir() and d.name.startswith("step_") and not d.name.endswith(".tmp"):
                out.append(int(d.name.split("_")[1]))
        return out

    def _verify(self, d: Path) -> bool:
        mf = d / "MANIFEST.json"
        if not mf.exists():
            return False
        try:
            manifest = json.loads(mf.read_text())
            for f, digest in manifest["files"].items():
                if _sha256(d / f) != digest:
                    return False
            return True
        except Exception:
            return False

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        d = self.dir / f"step_{step:08d}"
        if not self._verify(d):
            raise IOError(f"checkpoint {d} failed integrity check")
        manifest = json.loads((d / "MANIFEST.json").read_text())
        flat: Dict[str, np.ndarray] = {}
        for f in manifest["files"]:
            with np.load(d / f) as z:
                for k in z.files:
                    flat[k.replace("|", SEP)] = z[k]
        # rebuild in `like`'s structure, re-sharding onto the current mesh
        leaves_with_path = jax.tree_util.tree_leaves_with_path(like)
        sh_leaves = (
            jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "mesh") or x is None
            )
            if shardings is not None
            else [None] * len(leaves_with_path)
        )
        out = []
        for (path, leaf), sh in zip(leaves_with_path, sh_leaves):
            key = SEP.join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                for p in path
            )
            arr = flat[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like: Any, shardings: Any = None) -> Tuple[Optional[int], Any]:
        """Walk back from the newest checkpoint until one verifies."""
        for step in sorted(self.available_steps(), reverse=True):
            try:
                return step, self.restore(step, like, shardings)
            except Exception:
                continue
        return None, like
