"""Training driver: config -> data -> sharded train loop -> checkpoints.

Production posture (works identically on a CPU host for smoke scale):
* mesh + logical-rule sharding, pjit'd train step;
* deterministic restart-safe data (batch = f(seed, step));
* atomic async checkpointing with auto-resume from the latest valid step;
* straggler monitor wired to step timing;
* metrics printed as CSV (step, loss, grad_norm, lr, step_time).

Example (CPU smoke):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --ckpt-dir /tmp/ck --seq-len 128 --global-batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs import ARCH_IDS, get_config, smoke_config
from ..data import SyntheticLM
from ..distributed import sharding as shardlib
from ..distributed.compression import StragglerMonitor
from ..models import Model
from ..models.layers import set_sharding_rules
from ..train import OptConfig, init_state, make_train_step
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", type=str, default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--data-shards", type=int, default=1)
    ap.add_argument("--model-shards", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(data=args.data_shards, model=args.model_shards)
    set_sharding_rules(
        {k: shardlib._present(mesh, v) for k, v in shardlib.LOGICAL_RULES.items()},
        dict(mesh.shape),
    )
    model = Model(cfg, dtype=jnp.float32 if args.smoke else jnp.bfloat16, remat=True)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                        total_steps=args.steps)

    key = jax.random.PRNGKey(args.seed)
    with mesh:
        params = model.init(key)
        pspecs = shardlib.param_pspecs(mesh, jax.eval_shape(lambda: params), model.param_specs())
        psh = shardlib.shardings_of(mesh, pspecs)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh)
        opt_state = init_state(params, opt_cfg)

        start_step = 0
        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir)
            step_found, state = mgr.restore_latest({"params": params, "opt": opt_state})
            if step_found is not None:
                params, opt_state = state["params"], state["opt"]
                params = jax.tree.map(lambda x, s: jax.device_put(np.asarray(x), s), params, psh)
                start_step = step_found
                print(f"# resumed from step {start_step}")

        step_fn = jax.jit(
            make_train_step(model, opt_cfg, n_microbatches=args.microbatches),
            donate_argnums=(0, 1),
        )
        data = SyntheticLM(cfg, args.seq_len, args.global_batch, seed=args.seed)
        monitor = StragglerMonitor()
        print("step,loss,grad_norm,lr,step_time_s")
        t_last = time.perf_counter()
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                t_now = time.perf_counter()
                dt = t_now - t_last
                t_last = t_now
                monitor.record(dt)
                print(f"{step},{loss:.4f},{float(metrics['grad_norm']):.3f},"
                      f"{float(metrics['lr']):.2e},{dt:.3f}")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save_async(step + 1, {"params": params, "opt": opt_state})
        if mgr:
            mgr.save(args.steps, {"params": params, "opt": opt_state})
            mgr.wait()
        if monitor.flags:
            print(f"# straggler events: {monitor.flags}")
    set_sharding_rules(None)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
