import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell this lowers + compiles
the production step function — ``train_step`` for train shapes, ``forward``
for prefill, ``decode_step`` for decode — against ShapeDtypeStruct inputs
(no allocation), prints ``memory_analysis()`` / ``cost_analysis()``, and
records the collective schedule parsed from the partitioned HLO.

Two phases per cell:
* ``gate``     — the full-depth scanned model: compile MUST succeed; this
                 is the pass/fail dry-run artifact (memory numbers come
                 from here: scan keeps while-body buffers counted once).
* ``roofline`` — two unrolled reduced-depth compiles (1 and 2 layer-units)
                 whose cost_analysis difference gives the exact marginal
                 per-layer FLOPs/bytes/collective-bytes; the full-depth
                 totals are linear compositions (methodology: EXPERIMENTS.md
                 §Roofline). Unrolling exposes every layer to the HLO cost
                 model, which scan hides (a while body is costed once).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k --mesh single --phase all
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, SHAPES, get_config, shape_supported
from ..configs.base import ArchConfig, ShapeSpec
from ..distributed import sharding as shardlib
from ..models import Model
from ..models.layers import set_sharding_rules
from ..train import OptConfig, init_state, make_train_step
from .mesh import make_production_mesh

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8, "s32": 4,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(tok: Tuple[str, str]) -> int:
    dt, dims = tok
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective wire-bytes from partitioned HLO text.

    Heuristics (documented in EXPERIMENTS.md): all-reduce counts 2x its
    (per-device) buffer (ring send+recv), all-gather / all-to-all /
    collective-permute count the result buffer, reduce-scatter counts its
    operand buffer. ``-start`` variants are counted, ``-done`` skipped.
    """
    per_op = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        m = re.search(r"=\s+[^=]*?\b(" + "|".join(COLLECTIVES) + r")(-start)?\(", ls)
        if not m:
            continue
        if re.search(r"\b(all-reduce|all-gather|all-to-all|collective-permute|reduce-scatter)-done\b", ls):
            continue
        op = m.group(1)
        shapes = _SHAPE_RE.findall(ls)
        if not shapes:
            continue
        lhs_end = ls.index("=")
        rhs = ls[lhs_end:]
        rhs_shapes = _SHAPE_RE.findall(rhs)
        result_b = sum(_shape_bytes(s) for s in _SHAPE_RE.findall(ls[:lhs_end])) or (
            _shape_bytes(rhs_shapes[0]) if rhs_shapes else 0
        )
        paren = ls[ls.index("(", lhs_end) :] if "(" in ls[lhs_end:] else ""
        operand_shapes = _SHAPE_RE.findall(paren)
        operand_b = sum(_shape_bytes(s) for s in operand_shapes)
        if op == "all-reduce":
            wire = 2 * result_b
        elif op == "reduce-scatter":
            wire = operand_b or result_b
        else:
            wire = result_b
        per_op[op] += wire
        counts[op] += 1
    return {"wire_bytes": per_op, "counts": counts,
            "total_wire_bytes": sum(per_op.values())}


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        if cfg.frontend != "none":
            return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.frontend != "none":
        out = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)}
    else:
        out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def _micro_batches(cfg: ArchConfig, shape: ShapeSpec, n_batch_shards: int,
                   tok_target: int = 16_384) -> int:
    """Largest power-of-two microbatch count such that each microbatch still
    covers every DP shard; stop once per-shard tokens <= tok_target."""
    b = shape.global_batch
    best = 1
    m = 1
    while True:
        if b % m or (b // m) % n_batch_shards:
            break
        best = m
        if (b // m) * shape.seq_len // n_batch_shards <= tok_target:
            break
        m *= 2
    return best


def _reduced_cfg(cfg: ArchConfig, units: int) -> ArchConfig:
    """Same width, reduced depth: ``units`` layer-units (see dryrun doc)."""
    if cfg.xlstm and cfg.slstm_every:
        return cfg.scaled(n_layers=cfg.slstm_every * units)
    if cfg.ssm and cfg.attn_every:
        return cfg.scaled(n_layers=cfg.attn_every * units)
    if cfg.moe:
        return cfg.scaled(n_layers=cfg.first_dense_layers + units)
    return cfg.scaled(n_layers=units)


def _layer_units(cfg: ArchConfig) -> int:
    if cfg.xlstm and cfg.slstm_every:
        return cfg.n_layers // cfg.slstm_every
    if cfg.ssm and cfg.attn_every:
        return cfg.n_layers // cfg.attn_every
    if cfg.moe:
        return cfg.n_layers - cfg.first_dense_layers
    return cfg.n_layers


# --------------------------------------------------------------------------
# lower + compile one cell
# --------------------------------------------------------------------------


def _build(model: Model, cfg: ArchConfig, shape: ShapeSpec, mesh, n_micro: int):
    """Returns (fn, arg_sds, in_shardings, donate)."""
    batch_sds = input_specs(cfg, shape)
    params_sds = model.abstract_params()
    pspecs = shardlib.param_pspecs(mesh, params_sds, model.param_specs())
    param_sh = shardlib.shardings_of(mesh, pspecs)
    batch_sh = shardlib.shardings_of(mesh, shardlib.batch_pspecs(mesh, batch_sds))

    if shape.kind == "train":
        big = cfg.param_count() > 3e11
        opt_cfg = OptConfig(quantized=big, acc_dtype="bfloat16" if big else "float32")
        opt_sds = init_state(params_sds, opt_cfg, abstract=True)
        opt_specs = shardlib.opt_state_pspecs(mesh, opt_sds, pspecs)
        opt_sh = shardlib.shardings_of(mesh, opt_specs)
        step = make_train_step(model, opt_cfg, n_microbatches=n_micro, remat=True)
        return (
            step,
            (params_sds, opt_sds, batch_sds),
            (param_sh, opt_sh, batch_sh),
            (0, 1),
            (param_sh, opt_sh, None),  # out_shardings: alias params/opt
        )
    if shape.kind == "prefill":
        def fn(params, batch):
            logits, _ = model.forward(params, batch)
            return logits[:, -1, :]

        return fn, (params_sds, batch_sds), (param_sh, batch_sh), (), None
    # decode
    cache_sds = model.init_cache(shape.global_batch, shape.seq_len, abstract=True)
    cache_specs = shardlib.cache_pspecs(
        mesh, cfg, cache_sds, shape.global_batch,
        seq_shard=getattr(model, "_cache_seq_shard", False),
    )
    cache_sh = shardlib.shardings_of(mesh, cache_specs)

    def fn(params, cache, batch):
        tok = batch.get("tokens", batch.get("embeds"))
        return model.decode_step(params, cache, tok)

    return (fn, (params_sds, cache_sds, batch_sds), (param_sh, cache_sh, batch_sh),
            (1,), (None, cache_sh))


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    phase: str = "all",
    verbose: bool = True,
    opt_flags: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """opt_flags (perf-loop toggles, EXPERIMENTS.md §Perf):
        attn_impl: 'naive'|'chunked'; decode_batch_parallel: bool;
        moe_token_ep: bool (tokens-move expert sharding)."""
    opt_flags = opt_flags or {}
    cfg = get_config(arch)
    if opt_flags.get("moe_capacity_factor"):
        cfg = cfg.scaled(moe_capacity_factor=opt_flags["moe_capacity_factor"])
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_batch_shards = mesh.shape.get("pod", 1) * mesh.shape["data"]
    result: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mesh_shape": dict(mesh.shape),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
    }
    saved_rules = dict(shardlib.LOGICAL_RULES)
    if opt_flags.get("moe_token_ep"):
        # tokens-move expert parallelism: keep expert weights resident
        # (shard ff dim over data) instead of FSDP-gathering d_model shards
        shardlib.LOGICAL_RULES["expert_dmodel"] = None
        shardlib.LOGICAL_RULES["expert_ff"] = "data"
    if opt_flags.get("attn_seq_parallel"):
        shardlib.LOGICAL_RULES["seq"] = "model"
    from ..models import attention as _attn
    _attn.SCORES_DTYPE = jnp.bfloat16 if opt_flags.get("scores_bf16") else jnp.float32
    set_sharding_rules(
        {k: shardlib._present(mesh, v) for k, v in shardlib.LOGICAL_RULES.items()},
        dict(mesh.shape),
    )
    result["opt_flags"] = {k: v for k, v in opt_flags.items() if v}
    mkw = dict(
        attn_impl=opt_flags.get("attn_impl", "naive"),
        decode_batch_parallel=bool(opt_flags.get("decode_batch_parallel")),
        attn_seq_parallel=bool(opt_flags.get("attn_seq_parallel")),
    )
    cache_seq_shard = bool(opt_flags.get("cache_seq_shard"))
    try:
        with mesh:
            if phase in ("gate", "all"):
                t0 = time.perf_counter()
                tok_target = 4_096 if cfg.moe else 16_384
                n_micro = (_micro_batches(cfg, shape, n_batch_shards, tok_target)
                           if shape.kind == "train" else 1)
                model = Model(cfg, **mkw)
                model._cache_seq_shard = cache_seq_shard
                fn, sds, shardings, donate, out_sh = _build(model, cfg, shape, mesh, n_micro)
                jfn = jax.jit(fn, in_shardings=shardings, donate_argnums=donate,
                              out_shardings=out_sh)
                lowered = jfn.lower(*sds)
                compiled = lowered.compile()
                mem = compiled.memory_analysis()
                cost = compiled.cost_analysis()
                if isinstance(cost, (list, tuple)):  # newer jax: per-computation list
                    cost = cost[0] if cost else None
                mem_d = {}
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes"):
                    v = getattr(mem, k, None)
                    if v is not None:
                        mem_d[k] = int(v)
                result["gate"] = {
                    "ok": True,
                    "n_microbatches": n_micro,
                    "compile_s": round(time.perf_counter() - t0, 1),
                    "memory_analysis": mem_d,
                    "cost_flops": float(cost.get("flops", -1)) if cost else None,
                    "collectives": parse_collectives(compiled.as_text())["counts"],
                }
                if verbose:
                    print(f"[gate] {arch} {shape_name} mesh={result['mesh']} "
                          f"compile={result['gate']['compile_s']}s mem={mem_d}")
            if phase in ("roofline", "all"):
                costs = []
                for units in (1, 2):
                    rcfg = _reduced_cfg(cfg, units)
                    model = Model(rcfg, unroll=True, **mkw)
                    model._cache_seq_shard = cache_seq_shard
                    fn, sds, shardings, donate, out_sh = _build(model, rcfg, shape, mesh, 1)
                    jfn = jax.jit(fn, in_shardings=shardings, donate_argnums=donate,
                                  out_shardings=out_sh)
                    compiled = jfn.lower(*sds).compile()
                    cost = compiled.cost_analysis() or {}
                    if isinstance(cost, (list, tuple)):  # newer jax: per-computation list
                        cost = cost[0] if cost else {}
                    coll = parse_collectives(compiled.as_text())
                    costs.append({
                        "units": units,
                        "flops": float(cost.get("flops", 0.0)),
                        "bytes": float(cost.get("bytes accessed", 0.0)),
                        "wire_bytes": coll["total_wire_bytes"],
                        "collective_counts": coll["counts"],
                    })
                L = _layer_units(cfg)
                comp: Dict[str, Any] = {"units_total": L, "samples": costs}
                for key in ("flops", "bytes", "wire_bytes"):
                    c1, c2 = costs[0][key], costs[1][key]
                    marginal = max(c2 - c1, 0.0)
                    comp[key] = c1 + (L - 1) * marginal
                    comp[f"{key}_marginal"] = marginal
                result["roofline_raw"] = comp
                if verbose:
                    print(f"[roofline] {arch} {shape_name} mesh={result['mesh']} "
                          f"flops={comp['flops']:.3e} bytes={comp['bytes']:.3e} "
                          f"wire={comp['wire_bytes']:.3e}")
        result["ok"] = True
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug to surface
        result["ok"] = False
        result["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[FAIL] {arch} {shape_name} mesh={result['mesh']}: {result['error']}")
    finally:
        from ..models import attention as _attn2
        _attn2.SCORES_DTYPE = jnp.float32
        set_sharding_rules(None)
        shardlib.LOGICAL_RULES.clear()
        shardlib.LOGICAL_RULES.update(saved_rules)
    return result


def save_result(res: Dict[str, Any]):
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{res['arch']}__{res['shape']}__{res.get('mesh', 'na')}.json"
    (ARTIFACT_DIR / name).write_text(json.dumps(res, indent=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--phase", choices=["gate", "roofline", "all"], default="all")
    ap.add_argument("--all", action="store_true", help="run every supported cell")
    ap.add_argument("--attn-impl", choices=["naive", "chunked"], default="naive")
    ap.add_argument("--decode-bp", action="store_true")
    ap.add_argument("--moe-token-ep", action="store_true")
    ap.add_argument("--cache-seq-shard", action="store_true")
    ap.add_argument("--attn-sp", action="store_true")
    ap.add_argument("--moe-cap", type=float, default=0.0)
    ap.add_argument("--scores-bf16", action="store_true")
    ap.add_argument("--tag", type=str, default="", help="artifact suffix")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                res = run_cell(arch, shape, mp, phase=args.phase, opt_flags={
                    "attn_impl": args.attn_impl,
                    "decode_batch_parallel": args.decode_bp,
                    "moe_token_ep": args.moe_token_ep,
                    "cache_seq_shard": args.cache_seq_shard,
                    "attn_seq_parallel": args.attn_sp,
                    "moe_capacity_factor": args.moe_cap,
                    "scores_bf16": args.scores_bf16,
                })
                if "skipped" in res:
                    print(f"[skip] {arch} {shape}: {res['skipped']}")
                    continue
                if args.tag:
                    res["tag"] = args.tag
                    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
                    name = f"{res['arch']}__{res['shape']}__{res['mesh']}__{args.tag}.json"
                    (ARTIFACT_DIR / name).write_text(json.dumps(res, indent=2))
                else:
                    save_result(res)
                n_fail += 0 if res.get("ok") else 1
    if n_fail:
        raise SystemExit(f"{n_fail} cells FAILED")
    print("dry-run complete: all attempted cells compiled")


if __name__ == "__main__":
    main()
