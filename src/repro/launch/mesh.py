"""Production mesh construction.

The dry-run target is a TPU v5e pod slice: 16x16 = 256 chips single-pod,
(2, 16, 16) = 512 chips multi-pod. Defined as functions so importing the
module never touches jax device state (device count is locked at first
jax init — see dryrun.py's XLA_FLAGS preamble).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (~3 links usable per chip on a 2D torus)
