"""Serving driver: LM decode serving and graph-query serving.

LM path — batched prefill + decode with a KV cache. CPU smoke example:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 16 --gen-len 16

Graph path — a thin client over the serving tier: ``repro.serve()`` stands
up a :class:`~repro.serving.GraphService` (artifact registry + async
scheduler + metrics) and this driver submits parameterized queries to it:
    PYTHONPATH=src python -m repro.launch.serve --graph bfs \
        --queries 32 --pool 4

``--batch N`` turns on dynamic batching: queued queries are collected into
batches of up to N and answered by one vectorized batched execution
(bit-identical results, far fewer launches). Stats are the service's JSON
metrics snapshot (per-tenant counters, latency percentiles, registry
hits, batch occupancy) printed verbatim.

``--updates N`` switches the graph path to streaming serving: N edge-addition
deltas are interleaved through the query stream via a StreamingSession —
in-place updates into the padding slack (no re-lowering), incremental repair
for monotone programs — and per-version query latency plus update-apply
latency are reported.

``--autotune`` runs the :mod:`repro.autotune` search for the served
(program, graph bucket) before the service starts; the winning Target
persists in the TuningCache next to the artifact store, so this process
and every later one resolve it by lookup (``tuned_hits`` in the stats
snapshot) — a second ``--autotune`` start performs zero search trials.

``--artifact-dir DIR`` overrides the service's artifact registry location
(default: ``$REPRO_ARTIFACT_DIR`` / ``~/.cache/repro-artifacts``): the
program is AOT-lowered once per (program, target, shape bucket) into a
saved :class:`~repro.core.accelerator.Accelerator` artifact, and every
later process start loads it instead of recompiling. The stats snapshot
reports resident hits vs artifact hits vs cold lowerings.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, smoke_config
from ..models import Model


def generate(model: Model, params, prompts: jnp.ndarray, gen_len: int,
             greedy: bool = True, seed: int = 0):
    """Prefill via step-wise cache fill, then decode ``gen_len`` tokens."""
    b, plen = prompts.shape
    cache = model.init_cache(b, plen + gen_len)
    dec = jax.jit(model.decode_step)
    logits = None
    for t in range(plen):  # prefill (teacher forcing the prompt)
        logits, cache = dec(params, cache, prompts[:, t : t + 1])
    out = []
    key = jax.random.PRNGKey(seed)
    tok = None
    for _t in range(gen_len):
        if greedy:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1])[:, None].astype(jnp.int32)
        out.append(tok)
        logits, cache = dec(params, cache, tok)
    return jnp.concatenate(out, axis=1)


GRAPH_ALGOS = ("bfs", "pagerank", "sssp")


def _export_trace(trace_dir: str) -> None:
    """Dump the session's telemetry: Chrome trace + per-request spans.

    Writes ``trace.json`` (chrome://tracing / Perfetto ``trace_event``
    format) and ``requests.jsonl`` (one line per request trace: the
    span tree flattened with durations and attributes), then prints the
    queue-wait vs execution latency split from the span histograms.
    """
    import json
    import os

    from .. import telemetry as tel

    tr = tel.get()
    os.makedirs(trace_dir, exist_ok=True)
    chrome = os.path.join(trace_dir, "trace.json")
    n = tr.export_chrome(chrome)
    by_trace: dict = {}
    for s in tr.spans():
        by_trace.setdefault(s.trace_id, []).append(s)
    req_path = os.path.join(trace_dir, "requests.jsonl")
    with open(req_path, "w") as f:
        for trace_id in sorted(by_trace):
            spans = sorted(by_trace[trace_id], key=lambda s: s.t_start)
            f.write(json.dumps({
                "trace_id": trace_id,
                "spans": [
                    {
                        "name": s.name,
                        "span_id": s.span_id,
                        "parent_id": s.parent_id,
                        "duration_ms": round((s.t_end - s.t_start) * 1e3, 3),
                        "attrs": dict(s.attrs),
                    }
                    for s in spans
                ],
            }) + "\n")
    hists = tr.histograms()
    qw, ex = hists.get("queue_wait"), hists.get("execute")
    if qw is not None and ex is not None and qw.total and ex.total:
        print(f"latency split: queue-wait p50={qw.percentile(0.5) * 1e3:.2f}ms "
              f"(total {qw.sum_s * 1e3:.1f}ms) vs execution "
              f"p50={ex.percentile(0.5) * 1e3:.2f}ms "
              f"(total {ex.sum_s * 1e3:.1f}ms) over {ex.total} request(s)")
    print(f"trace: {n} span(s) -> {chrome}; per-request dumps -> {req_path}")


def resolve_accelerator(program, graph, backend: str, artifact_dir: str,
                        verbose: bool = True):
    """Load-or-lower the Accelerator for (program, backend, graph shape).

    Thin reporting wrapper over
    :func:`repro.core.accelerator.load_or_lower`: artifacts are keyed by
    the accelerator fingerprint (program content hash + target + shape),
    so a stale or foreign artifact is never picked up, and an unwritable
    store degrades to cold lowering instead of failing the server.
    """
    from ..core.accelerator import GraphShape, load_or_lower
    from ..core.target import Target

    target = Target.from_options(program.options, kind=backend)
    acc, loaded, dt = load_or_lower(
        program, target, GraphShape.of(graph), artifact_dir
    )
    if verbose:
        how = "warm start: loaded" if loaded else "cold start: lowered"
        print(f"{how} accelerator {acc.fingerprint[:12]} in {dt:.3f}s "
              f"(store: {artifact_dir})")
    return acc


def serve_graph(args) -> int:
    """Serve a batch of graph queries through :func:`repro.serve`.

    Thin client over the serving tier: one ``repro.serve(registry_dir)``
    call stands up the :class:`~repro.serving.GraphService` (artifact
    registry with resident/warm/cold selection, async scheduler with
    dynamic batching, metrics), and this driver only generates queries,
    submits them, and prints ``service.stats()`` — the JSON snapshot is
    the stats output, not hand-rolled counters.
    """
    import json

    from .. import telemetry as tel
    from ..graph import generators
    from ..serving import serve

    if args.trace_dir:
        tel.enable()

    result_prop = {"bfs": "old_level", "pagerank": "rank", "sssp": "SP"}[args.graph]
    weighted = args.graph == "sssp"
    graph = generators.power_law(
        args.vertices, args.edges, seed=args.seed, weighted=weighted
    )
    rng = np.random.default_rng(args.seed)
    if args.graph == "pagerank":
        queries = [{"iters": int(i)} for i in rng.integers(5, 25, args.queries)]
    else:
        roots = rng.integers(0, graph.n_vertices, args.queries)
        queries = [{"root": int(r)} for r in roots]

    max_batch = args.batch if args.batch and args.batch > 1 else 1
    mode = f"dynamic batching x{max_batch}" if max_batch > 1 else "per-query"
    registry_dir = args.artifact_dir if args.artifact_dir else None

    if args.autotune:
        # search BEFORE the service starts, against the same TuningCache
        # the service resolves from — every submission below then picks
        # the tuned Target via pure lookup (tuned_hits in the snapshot)
        from ..autotune import AutoTuner, TuningCache, tuning_dir_for
        from ..core.program import compile_program
        from ..serving.registry import default_artifact_dir
        from ..serving.service import NAMED_ALGORITHMS

        store = registry_dir if registry_dir else default_artifact_dir()
        tuner = AutoTuner(TuningCache(tuning_dir_for(store)), reps=2,
                          max_candidates=8)
        report = tuner.tune(
            compile_program(NAMED_ALGORITHMS[args.graph]), graph,
            params=queries[0],
        )
        how = ("cache hit, zero trials" if report.cache_hit
               else f"{report.trials} trial(s)")
        print(f"autotune: {report.config.target.describe()} "
              f"({how}, {report.config.speedup:.2f}x over baseline)")
    print(f"serving {args.queries} {args.graph} queries on |V|={graph.n_vertices} "
          f"|E|={graph.n_edges} via repro.serve ({args.pool} workers, "
          f"{args.backend} backend, {mode})")
    with serve(registry_dir, backend=args.backend, workers=args.pool,
               max_batch=max_batch) as service:
        t_warm = time.perf_counter()
        # first query resolves resident/warm-artifact/cold-compile
        first = service.run(args.graph, graph, **queries[0])
        warm_s = time.perf_counter() - t_warm
        t0 = time.perf_counter()
        futures = [service.submit(args.graph, graph, **q) for q in queries]
        results = [f.result() for f in futures]
        dt = time.perf_counter() - t0
        stats = service.stats()
    assert len(results) == len(queries)
    sample = np.asarray(first.properties[result_prop])
    lat = stats["queries"]["latency_ms"]
    reg = stats["registry"]
    how = ("resident" if reg["resident_hits"] else
           "warm artifact" if reg["artifact_hits"] else "cold compile")
    print(f"answered {len(results)} queries in {dt:.3f}s "
          f"({len(results) / dt:.1f} qps)")
    print(f"latency per query: p50={lat['p50_ms']:.1f}ms "
          f"p90={lat['p90_ms']:.1f}ms p99={lat['p99_ms']:.1f}ms")
    print(f"first query start: {how} in {warm_s:.3f}s "
          f"(store: {reg['store_dir']})")
    b = stats["batches"]
    if b["batches"]:
        print(f"dynamic batching: {b['batches']} batches for {b['queries']} "
              f"queries, occupancy {b['occupancy']:.0%} of "
              f"max_batch={b['max_batch']}")
    rejected = stats["queries"]["rejections_analysis"]
    if rejected:
        print(f"admission control: {rejected} submission(s) rejected by "
              f"static analysis (see per-tenant rejections_analysis)")
    print(f"first result ({result_prop}): min={sample.min():.4g} "
          f"max={sample.max():.4g}")
    print("service stats snapshot:")
    print(json.dumps(stats, indent=2, sort_keys=True))
    if args.trace_dir:
        _export_trace(args.trace_dir)
    return 0


def serve_streaming(args) -> int:
    """``--updates N``: serve queries over a *mutating* graph.

    N additions-only deltas (each ~1% of |E| random edges) are interleaved
    evenly through the query stream via a
    :class:`~repro.streaming.StreamingSession`. Every update is an in-place
    ``apply_updates`` into the graph's padding slack — a shape-check-only
    rebind, no re-lowering — and repeated queries are answered by
    incremental repair when the program is monotone (bfs/sssp) or a full
    re-run otherwise (pagerank). Reports per-version query latency and
    update-apply latency so the streaming cost model is observable.
    """
    from ..algorithms import sources
    from ..core.accelerator import GraphShape
    from ..core.program import compile_program
    from ..graph import generators
    from ..graph.storage import GraphDelta
    from ..streaming import StreamingSession

    from .. import telemetry as tel

    if args.trace_dir:
        tel.enable()
    src = {
        "bfs": sources.BFS_ECP,
        "pagerank": sources.PAGERANK,
        "sssp": sources.SSSP,
    }[args.graph]
    weighted = args.graph == "sssp"
    base = generators.power_law(
        args.vertices, args.edges, seed=args.seed, weighted=weighted
    )
    shape = GraphShape.bucket_for(
        base.n_vertices, base.n_edges, weighted=weighted
    )
    graph = base.pad_to(shape.n_vertices, shape.n_edges)
    program = compile_program(src)
    rng = np.random.default_rng(args.seed)
    if args.graph == "pagerank":
        queries = [{"iters": int(i)} for i in rng.integers(5, 25, args.queries)]
    else:
        # few distinct roots, repeated: repeats across versions are exactly
        # the queries incremental repair accelerates
        roots = rng.integers(0, base.n_vertices, max(4, args.queries // 4))
        queries = [{"root": int(roots[i % len(roots)])}
                   for i in range(args.queries)]

    accelerator = None
    if args.artifact_dir:
        accelerator = resolve_accelerator(
            program, graph, args.backend, args.artifact_dir
        )
    print(f"streaming-serving {args.queries} {args.graph} queries with "
          f"{args.updates} interleaved updates on |V|={base.n_vertices} "
          f"|E|={base.n_edges} (bucket {shape.n_vertices}x{shape.n_edges}, "
          f"{args.backend} backend)")

    n_add = max(1, base.n_edges // 100)  # ~1% of |E| per delta
    stride = max(1, args.queries // (args.updates + 1))
    lat_by_version: dict = {}
    with StreamingSession(
        program, graph, backend=args.backend, accelerator=accelerator,
        pool_size=args.pool, batch=args.batch,
    ) as ss:
        ss.warmup(**queries[0])
        t0 = time.perf_counter()
        for i, q in enumerate(queries):
            if args.updates and i and i % stride == 0 and ss.updates < args.updates:
                lv = ss.graph.n_vertices_logical
                edges = rng.integers(0, lv, size=(n_add, 2)).astype(np.int32)
                w = (rng.integers(1, 64, size=n_add).astype(np.float32)
                     if weighted else None)
                ss.update(GraphDelta(added_edges=edges, added_weights=w))
            t_q = time.perf_counter()
            result = ss.run(**q)
            lat_by_version.setdefault(result.version, []).append(
                (time.perf_counter() - t_q) * 1e3
            )
        dt = time.perf_counter() - t0
        print(f"answered {args.queries} queries across {ss.version + 1} graph "
              f"versions in {dt:.3f}s ({args.queries / dt:.1f} qps)")
        for version in sorted(lat_by_version):
            lat = np.asarray(lat_by_version[version])
            print(f"  version {version}: {len(lat)} queries, "
                  f"p50={np.percentile(lat, 50):.1f}ms "
                  f"max={lat.max():.1f}ms")
        if ss.update_apply_s:
            apply_ms = np.asarray(ss.update_apply_s) * 1e3
            print(f"updates: {ss.updates} applied ({n_add} edges each), "
                  f"apply p50={np.percentile(apply_ms, 50):.1f}ms "
                  f"max={apply_ms.max():.1f}ms, rebuckets={ss.rebuckets}")
        print(f"answer paths: {ss.cache_hits} cache hits, "
              f"{ss.incremental_runs} incremental repairs, "
              f"{ss.full_runs} full runs "
              f"(monotone={ss.incremental_info.monotone})")
    if args.trace_dir:
        _export_trace(args.trace_dir)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=None,
                    help="LM path: prompt batch size (default 4). Graph "
                         "path: dynamic batching — collect up to N queued "
                         "queries per vectorized execution (default off)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    # graph-query serving (Program/SessionPool path)
    ap.add_argument("--graph", choices=GRAPH_ALGOS, default=None,
                    help="serve graph queries for this algorithm instead of LM decode")
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--updates", type=int, default=0,
                    help="graph path: interleave N streaming edge-addition "
                         "deltas (~1%% of |E| each) through the query stream "
                         "via a StreamingSession; reports per-version query "
                         "latency and update-apply latency")
    ap.add_argument("--pool", type=int, default=2)
    ap.add_argument("--artifact-dir", default=None,
                    help="graph path: warm-start from (or populate) a saved "
                         "Accelerator artifact directory — compile cost is "
                         "paid once per (program, target, shape), offline")
    ap.add_argument("--autotune", action="store_true",
                    help="graph path: run the repro.autotune search for "
                         "(program, graph bucket) before serving; the "
                         "service then resolves every submission through "
                         "the persisted TuningCache (cache hits skip the "
                         "search entirely)")
    ap.add_argument("--trace-dir", default=None,
                    help="graph path: enable repro.telemetry tracing and "
                         "write trace.json (chrome://tracing) plus "
                         "requests.jsonl (per-request span dumps) to DIR "
                         "on exit; prints the queue-wait vs execution "
                         "latency split")
    ap.add_argument("--vertices", type=int, default=2000)
    ap.add_argument("--edges", type=int, default=16000)
    ap.add_argument("--backend", choices=("local", "distributed"), default="local")
    args = ap.parse_args(argv)

    if args.graph is not None:
        if args.batch is None:
            args.batch = 0  # graph path: dynamic batching off by default
        if args.updates:
            return serve_streaming(args)
        return serve_graph(args)
    if args.batch is None:
        args.batch = 4  # LM path: prompt batch size

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decoder:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    model = Model(cfg, dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len),
                                       dtype=np.int32))
    t0 = time.perf_counter()
    toks = generate(model, params, prompts, args.gen_len)
    dt = time.perf_counter() - t0
    n = args.batch * (args.prompt_len + args.gen_len)
    print(f"generated {toks.shape} tokens in {dt:.2f}s ({n / dt:.1f} tok/s inc. compile)")
    print(np.asarray(toks)[:2])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
