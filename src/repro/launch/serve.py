"""Serving driver: batched prefill + decode with a KV cache.

CPU smoke example:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 16 --gen-len 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, smoke_config
from ..models import Model


def generate(model: Model, params, prompts: jnp.ndarray, gen_len: int,
             greedy: bool = True, seed: int = 0):
    """Prefill via step-wise cache fill, then decode ``gen_len`` tokens."""
    b, plen = prompts.shape
    cache = model.init_cache(b, plen + gen_len)
    dec = jax.jit(model.decode_step)
    logits = None
    for t in range(plen):  # prefill (teacher forcing the prompt)
        logits, cache = dec(params, cache, prompts[:, t : t + 1])
    out = []
    key = jax.random.PRNGKey(seed)
    tok = None
    for t in range(gen_len):
        if greedy:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1])[:, None].astype(jnp.int32)
        out.append(tok)
        logits, cache = dec(params, cache, tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decoder:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    model = Model(cfg, dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len),
                                       dtype=np.int32))
    t0 = time.perf_counter()
    toks = generate(model, params, prompts, args.gen_len)
    dt = time.perf_counter() - t0
    n = args.batch * (args.prompt_len + args.gen_len)
    print(f"generated {toks.shape} tokens in {dt:.2f}s ({n / dt:.1f} tok/s inc. compile)")
    print(np.asarray(toks)[:2])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
