"""Dynamic batching: collect queued queries into batches as they arrive.

The serving front door (``repro.launch.serve --graph ... --batch N``)
receives queries one at a time, but the batch engine wants them K at a
time. :class:`DynamicBatcher` bridges the two: ``submit()`` enqueues a
query and returns a Future; a collector thread drains the queue into
batches — waiting up to ``max_wait_s`` after the first query for
stragglers, capping at ``max_batch``, and splitting on parameter-signature
boundaries so every batch it hands downstream is batch-eligible (one
shared key set). Queries keep their submission order within and across
batches, and a query count that is not a multiple of ``max_batch`` simply
yields a final partial batch.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


@dataclass
class BatchServeStats:
    """Occupancy accounting for one batcher.

    ``sizes`` is a bounded window of the most recent batch sizes (long-lived
    serving processes must not accumulate one entry per batch forever);
    ``batches``/``queries`` are exact lifetime counters.
    """

    max_batch: int = 0
    batches: int = 0
    queries: int = 0
    sizes: "deque[int]" = field(default_factory=lambda: deque(maxlen=1024))

    @property
    def occupancy(self) -> float:
        """Mean fill ratio of the batches actually launched (1.0 = every
        batch was full)."""
        if not self.batches or not self.max_batch:
            return 0.0
        return self.queries / (self.batches * self.max_batch)


class DynamicBatcher:
    """Groups submitted queries into batches for a run_many-style callable.

    ``run_many`` receives a list of parameter dicts sharing one key set and
    must return one result per dict, in order. Exceptions from a batch are
    propagated to every Future in that batch.
    """

    def __init__(
        self,
        run_many: Callable[[List[Dict[str, Any]]], Sequence[Any]],
        max_batch: int = 16,
        max_wait_s: float = 0.002,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._run_many = run_many
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.stats = BatchServeStats(max_batch=max_batch)
        self._pending: "deque[Tuple[Dict[str, Any], Future]]" = deque()
        self._cond = threading.Condition()
        self._in_flight = 0  # queries handed to run_many, not yet resolved
        self._closed = False
        self._worker = threading.Thread(
            target=self._loop, name="repro-batch-collector", daemon=True
        )
        self._worker.start()

    def submit(self, params: Dict[str, Any]) -> "Future[Any]":
        from ..core.session import ServiceClosed

        fut: "Future[Any]" = Future()
        with self._cond:
            if self._closed:
                raise ServiceClosed("DynamicBatcher is closed")
            self._pending.append((dict(params), fut))
            self._cond.notify()
        return fut

    @property
    def queue_depth(self) -> int:
        """Queries queued (not yet collected) + handed out but unresolved."""
        with self._cond:
            return len(self._pending) + self._in_flight

    # -- collector ----------------------------------------------------------
    def _take_batch(self) -> Optional[List[Tuple[Dict[str, Any], Future]]]:
        with self._cond:
            while not self._pending:
                if self._closed:
                    return None
                self._cond.wait()
            # wait a short window for the batch to fill up
            deadline = time.monotonic() + self.max_wait_s
            while len(self._pending) < self.max_batch and not self._closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(timeout=remaining)
            # one batch = one parameter signature (batch-eligibility)
            sig = frozenset(self._pending[0][0])
            items = []
            while (
                self._pending
                and len(items) < self.max_batch
                and frozenset(self._pending[0][0]) == sig
            ):
                items.append(self._pending.popleft())
            self._in_flight += len(items)
            return items

    def _loop(self) -> None:
        while True:
            items = self._take_batch()
            if items is None:
                return
            params = [p for p, _ in items]
            try:
                results = self._run_many(params)
            except BaseException as exc:  # surface to every waiter
                for _, fut in items:
                    fut.set_exception(exc)
                self._settle(len(items))
                continue
            self.stats.batches += 1
            self.stats.queries += len(items)
            self.stats.sizes.append(len(items))
            for (_, fut), res in zip(items, results):
                fut.set_result(res)
            self._settle(len(items))

    def _settle(self, n: int) -> None:
        with self._cond:
            self._in_flight -= n
            self._cond.notify_all()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until no query is queued or in flight; True on success.

        The streaming update path calls this (with no new submissions
        racing in — its write gate has already closed the front door) so a
        graph rebind never interleaves with a half-collected batch.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._pending or self._in_flight:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._cond.wait(timeout=remaining)
            return True

    def close(self, wait: bool = True) -> None:
        """Stop accepting queries; drain what is already queued."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if wait:
            self._worker.join(timeout=300)
