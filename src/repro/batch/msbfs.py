"""Bit-packed multi-source BFS: the classic MS-BFS layout for batched roots.

Level-synchronous BFS programs dominate multi-query graph serving (every
query is "the same traversal from a different root"), and their per-query
state is ONE bit: "is v in the frontier". Packing up to ``word_bits``
queries into each lane word turns K frontier expansions into one:

* ``frontier[v]`` / ``seen[v]`` are ``[V, W]`` word arrays (W = ceil(K/32)
  uint32 words — 64 sources ride one int64 lane word on x64-enabled
  builds, 32 per uint32 word otherwise);
* one traversal step ORs every in-neighbor's frontier word into each
  vertex — a segmented bitwise-OR over the CSC edge stream, computed with
  one ``associative_scan`` (the shuffle network reduced to 1-bit lanes);
* newly reached bits record their BFS level, and the loop runs until every
  packed query has an empty frontier — one launch per level serves the
  whole batch, so the launch total is independent of K.

Selection is automatic and conservative: :func:`match_msbfs` re-derives
the BFS template from the MIR — the Property Detector results, the
frontier/direction verdicts assigned by the PR-2 pass pipeline (the edge
kernel must carry a dynamic frontier check on the level property), and the
exact host-loop shape — and anything that doesn't match falls back to the
general vmapped batch path. The reconstruction below is exact: for a
matched program, every output property and host scalar is provably equal
to what the sequential interpreter computes (levels are unique per vertex,
``tuple[v]`` collapses to the vertex's own level for every reached vertex
except the root, which takes the min over its reached in-neighbors), so
the fast path preserves the bit-identical batching contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import fir, mir
from ..core.engine import count_launch


@dataclass(frozen=True)
class MSBFSPlan:
    """The pieces of a matched level-synchronous BFS program."""

    level_prop: str  # e.g. old_level: the frontier/level property
    next_prop: str  # e.g. new_level: double-buffered level copy
    tuple_prop: str  # e.g. tuple: the min-reduce scratch
    counter_prop: str  # e.g. activeVertex: frontier-size accumulator
    level_scalar: str  # e.g. level
    root_scalar: str  # e.g. root
    loop_var: str  # e.g. frontier_size (local declared in main)
    inf: int  # the "unreached" fill of tuple_prop
    init_kernel: str
    loop_launches: Tuple[str, ...]  # launch names per host iteration

    def accepts(self, param_keys, n_vertices: int) -> bool:
        """Fast path applies when queries only vary the root and the
        unreached sentinel cannot be confused with a real level."""
        return set(param_keys) <= {self.root_scalar} and self.inf > n_vertices + 1


# ---------------------------------------------------------------------------
# template matching on the MIR
# ---------------------------------------------------------------------------


def _int_value(e: fir.Expr) -> Optional[int]:
    if isinstance(e, fir.IntLit):
        return e.value
    if isinstance(e, fir.UnaryOp) and e.op == "-" and isinstance(e.operand, fir.IntLit):
        return -e.operand.value
    return None


def _is_prop_at(e: fir.Expr, prop: str, var: str) -> bool:
    return (
        isinstance(e, fir.Index)
        and isinstance(e.base, fir.Ident)
        and e.base.name == prop
        and isinstance(e.index, fir.Ident)
        and e.index.name == var
    )


def _match_eq(e: fir.Expr) -> Optional[Tuple[fir.Expr, fir.Expr]]:
    if isinstance(e, fir.BinOp) and e.op == "==":
        return e.lhs, e.rhs
    return None


def _match_prop_eq(e: fir.Expr, var: str):
    """Match ``P[var] == rhs`` (either operand order) -> (prop, rhs)."""
    sides = _match_eq(e)
    if sides is None:
        return None
    for a, b in (sides, sides[::-1]):
        if (
            isinstance(a, fir.Index)
            and isinstance(a.base, fir.Ident)
            and isinstance(a.index, fir.Ident)
            and a.index.name == var
        ):
            return a.base.name, b
    return None

def _is_scalar_plus_one(e: fir.Expr, scalar: str) -> bool:
    if not (isinstance(e, fir.BinOp) and e.op == "+"):
        return False
    for a, b in ((e.lhs, e.rhs), (e.rhs, e.lhs)):
        if isinstance(a, fir.Ident) and a.name == scalar and _int_value(b) == 1:
            return True
    return False


def _launch_name(st: fir.Stmt) -> Optional[str]:
    if (
        isinstance(st, fir.ExprStmt)
        and isinstance(st.expr, fir.MethodCall)
        and st.expr.method in ("init", "process")
        and len(st.expr.args) == 1
        and isinstance(st.expr.args[0], fir.Ident)
    ):
        return st.expr.args[0].name
    return None


def _expand_launch(module: mir.Module, name: str) -> List[str]:
    """Resolve a fused/pipelined launch back to the original kernel names."""
    parts = module.fusion_groups.get(name)
    if parts:
        return list(parts)
    return [name]


def match_msbfs(module: mir.Module) -> Optional[MSBFSPlan]:
    """Re-derive the BFS template from an analyzed module, or None.

    Matches the paper-Fig.-1 edge-centric BFS shape regardless of which
    passes ran: fused vertex kernels and pipelines are expanded back to
    their original stages via ``module.fusion_groups`` before matching.
    """
    if module.host is None or module.graph.weighted:
        return None
    body = module.host.main.body
    if len(body) != 5:
        return None
    st_init, st_l, st_n, st_var, st_loop = body

    # vertices.init(reset)
    init_names = (
        _expand_launch(module, _launch_name(st_init))
        if _launch_name(st_init)
        else []
    )
    if len(init_names) != 1:
        return None
    init_kernel = init_names[0]

    # L[root] = 1; N[root] = 1
    def _root_assign(st: fir.Stmt) -> Optional[Tuple[str, str]]:
        if (
            isinstance(st, fir.Assign)
            and isinstance(st.target, fir.Index)
            and isinstance(st.target.base, fir.Ident)
            and isinstance(st.target.index, fir.Ident)
            and _int_value(st.value) == 1
        ):
            return st.target.base.name, st.target.index.name
        return None

    la, na = _root_assign(st_l), _root_assign(st_n)
    if la is None or na is None or la[1] != na[1]:
        return None
    level_prop, root_scalar = la
    next_prop = na[0]
    if root_scalar not in module.scalars or level_prop == next_prop:
        return None

    # var fs: int = 1
    if not (isinstance(st_var, fir.VarDecl) and _int_value(st_var.init) == 1):
        return None
    loop_var = st_var.name

    # while (fs) { launches...; fs = C[0]; C[0] = 0; lvl += 1; }
    if not (
        isinstance(st_loop, fir.While)
        and isinstance(st_loop.cond, fir.Ident)
        and st_loop.cond.name == loop_var
    ):
        return None
    loop_body = list(st_loop.body)
    launches: List[str] = []
    while loop_body and _launch_name(loop_body[0]) is not None:
        launches.append(_launch_name(loop_body[0]))
        loop_body.pop(0)
    if len(loop_body) != 3 or not launches:
        return None
    st_fs, st_c0, st_lvl = loop_body
    if not (
        isinstance(st_fs, fir.Assign)
        and isinstance(st_fs.target, fir.Ident)
        and st_fs.target.name == loop_var
        and isinstance(st_fs.value, fir.Index)
        and isinstance(st_fs.value.base, fir.Ident)
        and _int_value(st_fs.value.index) == 0
    ):
        return None
    counter_prop = st_fs.value.base.name
    if not (
        isinstance(st_c0, fir.Assign)
        and isinstance(st_c0.target, fir.Index)
        and isinstance(st_c0.target.base, fir.Ident)
        and st_c0.target.base.name == counter_prop
        and _int_value(st_c0.target.index) == 0
        and _int_value(st_c0.value) == 0
    ):
        return None
    if not (
        isinstance(st_lvl, fir.ReduceAssign)
        and st_lvl.op == "+"
        and isinstance(st_lvl.target, fir.Ident)
        and _int_value(st_lvl.value) == 1
    ):
        return None
    level_scalar = st_lvl.target.name
    if level_scalar not in module.scalars:
        return None
    if _int_value(module.scalars[level_scalar].init or fir.IntLit(value=-1)) != 1:
        return None

    # expand fused launches back to [edge, update, apply] originals
    expanded: List[str] = []
    for nm in launches:
        expanded.extend(_expand_launch(module, nm))
    if len(expanded) != 3:
        return None
    e_name, u_name, a_name = expanded
    ek = module.kernels.get(e_name)
    uk = module.kernels.get(u_name)
    ak = module.kernels.get(a_name)
    ik = module.kernels.get(init_kernel)
    if not all(
        k is not None and isinstance(k, mir.Kernel) for k in (ek, uk, ak, ik)
    ):
        return None
    if ek.kind is not mir.KernelKind.EDGE:
        return None
    if uk.kind is not mir.KernelKind.VERTEX or ak.kind is not mir.KernelKind.VERTEX:
        return None
    if ik.kind is not mir.KernelKind.VERTEX:
        return None

    # the PR-2 verdicts must agree this is a dynamic frontier on L:
    # DENSE would mean the guard is loop-invariant — not a real BFS frontier
    if ek.frontier is None or ek.frontier.props != {level_prop}:
        return None
    if ek.direction is mir.Direction.DENSE:
        return None

    # edge kernel: if (L[src] == lvl) T[dst] min= lvl + 1
    eb = ek.func.body
    if not (
        len(eb) == 1
        and isinstance(eb[0], fir.If)
        and not eb[0].else_body
        and len(eb[0].then_body) == 1
    ):
        return None
    g = _match_prop_eq(eb[0].cond, ek.src_param)
    if g is None or g[0] != level_prop:
        return None
    if not (isinstance(g[1], fir.Ident) and g[1].name == level_scalar):
        return None
    red = eb[0].then_body[0]
    if not (
        isinstance(red, fir.ReduceAssign)
        and red.op == "min"
        and isinstance(red.target, fir.Index)
        and isinstance(red.target.base, fir.Ident)
        and _is_prop_at(red.target, red.target.base.name, ek.dst_param)
        and _is_scalar_plus_one(red.value, level_scalar)
    ):
        return None
    tuple_prop = red.target.base.name
    if tuple_prop in (level_prop, next_prop, counter_prop):
        return None

    # update kernel: if ((T[v] == lvl+1) & (L[v] == -1)) { N[v] = T[v]; C[0] += 1 }
    ub = uk.func.body
    if not (
        len(ub) == 1
        and isinstance(ub[0], fir.If)
        and not ub[0].else_body
        and len(ub[0].then_body) == 2
    ):
        return None
    cond = ub[0].cond
    if not (isinstance(cond, fir.BinOp) and cond.op == "&"):
        return None
    matched_t = matched_l = False
    for side in (cond.lhs, cond.rhs):
        m = _match_prop_eq(side, uk.vertex_param)
        if m is None:
            return None
        prop, rhs = m
        if prop == tuple_prop and _is_scalar_plus_one(rhs, level_scalar):
            matched_t = True
        elif prop == level_prop and _int_value(rhs) == -1:
            matched_l = True
    if not (matched_t and matched_l):
        return None
    set_n, bump_c = ub[0].then_body
    if not (
        isinstance(set_n, fir.Assign)
        and _is_prop_at(set_n.target, next_prop, uk.vertex_param)
        and _is_prop_at(set_n.value, tuple_prop, uk.vertex_param)
    ):
        return None
    if not (
        isinstance(bump_c, fir.ReduceAssign)
        and bump_c.op == "+"
        and isinstance(bump_c.target, fir.Index)
        and isinstance(bump_c.target.base, fir.Ident)
        and bump_c.target.base.name == counter_prop
        and _int_value(bump_c.target.index) == 0
        and _int_value(bump_c.value) == 1
    ):
        return None

    # apply kernel: L[v] = N[v]
    ab = ak.func.body
    if not (
        len(ab) == 1
        and isinstance(ab[0], fir.Assign)
        and _is_prop_at(ab[0].target, level_prop, ak.vertex_param)
        and _is_prop_at(ab[0].value, next_prop, ak.vertex_param)
    ):
        return None

    # init kernel: L[v] = -1; N[v] = -1; T[v] = INF (any order)
    inits: Dict[str, int] = {}
    for st in ik.func.body:
        if not (
            isinstance(st, fir.Assign)
            and isinstance(st.target, fir.Index)
            and isinstance(st.target.base, fir.Ident)
            and isinstance(st.target.index, fir.Ident)
            and st.target.index.name == ik.vertex_param
            and _int_value(st.value) is not None
        ):
            return None
        inits[st.target.base.name] = _int_value(st.value)
    if set(inits) != {level_prop, next_prop, tuple_prop}:
        return None
    if inits[level_prop] != -1 or inits[next_prop] != -1:
        return None
    inf = inits[tuple_prop]
    if inf <= 1:
        return None

    # level / tuple / next must be ints for levels to transfer exactly
    for prop in (level_prop, next_prop, tuple_prop, counter_prop):
        if module.properties[prop].scalar != "int":
            return None
    if module.scalars[root_scalar].scalar != "int":
        return None

    return MSBFSPlan(
        level_prop=level_prop,
        next_prop=next_prop,
        tuple_prop=tuple_prop,
        counter_prop=counter_prop,
        level_scalar=level_scalar,
        root_scalar=root_scalar,
        loop_var=loop_var,
        inf=inf,
        init_kernel=init_kernel,
        loop_launches=tuple(launches),
    )


# ---------------------------------------------------------------------------
# packed traversal
# ---------------------------------------------------------------------------


def _word_dtype():
    """64 sources per lane word when x64 is enabled, else 32 per uint32."""
    if jax.config.jax_enable_x64:
        return jnp.uint64, 64
    return jnp.uint32, 32


def run_msbfs(be, plan: MSBFSPlan) -> None:
    """Execute the packed traversal on a BatchEngine and fill its state.

    Operates entirely in the engine's (possibly hub-relabeled) vertex id
    space; the BatchEngine's shared result-splitting path translates back.
    """
    eng = be.engine
    g = be.graph
    k = be.batch_size
    n_v, n_e = g.n_vertices, g.n_edges
    wdt, word_bits = _word_dtype()
    n_words = (k + word_bits - 1) // word_bits

    roots_orig = np.asarray(be.host_env[plan.root_scalar], np.int64)
    roots_orig = np.broadcast_to(roots_orig, (k,))
    o2n = eng.old2new
    roots = np.asarray(o2n)[roots_orig] if o2n is not None else roots_orig

    lanes = np.arange(k)
    np_wdt = np.dtype(str(jnp.dtype(wdt)))
    frontier0 = np.zeros((n_v, n_words), np_wdt)
    np.bitwise_or.at(
        frontier0,
        (roots, lanes // word_bits),
        (np_wdt.type(1) << (lanes % word_bits).astype(np_wdt)),
    )
    levels0 = np.full((k, n_v), -1, np.int32)
    levels0[lanes, roots] = 1

    indptr, csc_idx, _ = g.csc
    frontier = jnp.asarray(frontier0)
    seen = jnp.asarray(frontier0)
    levels = jnp.asarray(levels0)

    if n_e > 0:
        indeg = np.diff(indptr)
        flags = np.zeros(n_e, bool)
        flags[indptr[:-1][indeg > 0]] = True  # first in-edge of each vertex
        has_in = indeg > 0
        last = np.where(has_in, indptr[1:] - 1, 0)
        csc_dev = jnp.asarray(np.asarray(csc_idx, np.int32))
        flags_dev = jnp.asarray(flags)
        last_dev = jnp.asarray(last.astype(np.int32))
        has_in_dev = jnp.asarray(has_in)
        shifts = jnp.arange(word_bits, dtype=wdt)

        @jax.jit
        def step(frontier, seen, levels, depth):
            gathered = frontier[csc_dev]  # [E, W] packed frontier @ src

            # segmented bitwise OR over the dst-sorted CSC edge stream:
            # the shuffle/reduce network collapsed to 1-bit lanes
            def comb(a, b):
                fa, va = a
                fb, vb = b
                return fa | fb, jnp.where(fb[:, None], vb, va | vb)

            _, ors = jax.lax.associative_scan(comb, (flags_dev, gathered))
            reach = jnp.where(has_in_dev[:, None], ors[last_dev], wdt(0))
            new = reach & ~seen
            seen = seen | new
            # unpack the newly-reached bits to record per-query levels
            bits = ((new[:, :, None] >> shifts[None, None, :]) & wdt(1)) != 0
            newly = bits.reshape(n_v, n_words * word_bits)[:, :k].T  # [K, V]
            levels = jnp.where(
                jnp.logical_and(newly, levels < 0), depth + 1, levels
            )
            return new, seen, levels, jnp.any(new)

    its = 0
    while True:
        its += 1
        be.stats.host_iterations += 1
        count_launch(be.stats, be.module, be.MSBFS_NAME)
        be.stats.full_launches += 1
        be.stats.edges_traversed += n_e
        if n_e == 0:
            break
        frontier, seen, levels, any_new = step(
            frontier, seen, levels, jnp.int32(its)
        )
        if not bool(any_new):
            break

    # ---- exact reconstruction of the sequential interpreter's state ----
    levels_np = np.asarray(levels)  # [K, V], -1 = unreached
    depth = levels_np.max(axis=1)  # >= 1 (the root)
    inf = np.int32(plan.inf)
    tup = np.where(levels_np >= 1, levels_np, inf).astype(np.int32)
    # tuple[v] = min over reached in-neighbors u of (level[u] + 1): for any
    # reached v != root that is exactly level[v]; for the root it needs the
    # explicit in-neighbor minimum (the root's level 1 was host-assigned,
    # never min-reduced); unreached vertices keep INF
    for q in range(k):
        r = int(roots[q])
        nbrs = csc_idx[indptr[r]: indptr[r + 1]]
        lv = levels_np[q, nbrs]
        lv = lv[lv >= 1]
        tup[q, r] = lv.min() + 1 if lv.size else inf

    be.state[plan.level_prop] = jnp.asarray(levels_np)
    be.state[plan.next_prop] = jnp.asarray(levels_np)
    be.state[plan.tuple_prop] = jnp.asarray(tup)
    # counter prop stays all-zero (host clears it after the last iteration),
    # as do any other never-written properties — _reset zeroed them all.
    be.host_env[plan.level_scalar] = (depth + 1).astype(np.int64)
    be.host_env[plan.loop_var] = np.zeros(k, np.int64)
