"""BatchEngine: one set of launches answering K parameterized queries.

The sequential :class:`~repro.core.engine.Engine` interprets the host
program per query and launches each device kernel once per query. On an
immutable graph, the kernels are query-independent executables — only the
*state* they transform differs per query — so K parameter bindings can ride
one launch set:

* every property / scalar gains a **leading batch axis**: state arrays are
  ``[K, n]``, host scalars are ``[K]`` numpy arrays, and device kernels run
  through the backend's batch-axis lowering
  (:func:`repro.core.backend.lower_kernel_batched` — vmap over the shared
  graph bindings, or a vmapped shuffle superstep on the distributed
  engine);
* the host program runs ONCE with **per-query active masks**: an ``if``
  executes both branches under refined masks, a ``while`` iterates until
  every lane's condition is false, and converged queries stop contributing
  state changes (their lanes are masked out of every merge) without
  stopping the batch;
* BFS-like frontier programs additionally get the **bit-packed
  multi-source fast path** (:mod:`repro.batch.msbfs`), selected
  automatically from the MIR frontier/direction verdicts.

Per-lane results are bit-identical to K sequential ``Engine`` runs: vmap
evaluates the same operations per lane, masked merges only suppress writes
a sequential run would not have performed, and the full-stream launches the
batch path always uses agree exactly with the engine's compacted-frontier
launches for every reduction the DSL admits on the frontier path (integer
min/max/add).

The engine is driven through :class:`repro.core.session.BatchSession`; it
wraps (never subclasses) a sequential engine so every registered execution
backend that exposes an ``engine`` attribute serves batches through its own
launch strategy via :meth:`Engine.batched_runner`.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .. import telemetry as tel
from ..core import fir
from ..core.backend import DTYPES, WEIGHT_KEY, combine
from ..core.engine import (
    Engine,
    EngineError,
    EngineResult,
    EngineStats,
    count_launch,
)


class BatchError(Exception):
    pass


# host builtins vectorized over [K] lanes (the numpy analogues of the
# scalar `math`-module table in Engine._host_call)
_VEC_FNS = {
    "exp": np.exp,
    "log": np.log,
    "abs": np.abs,
    "sqrt": np.sqrt,
    "min": np.minimum,
    "max": np.maximum,
    "floor": lambda x: np.floor(x).astype(np.int64),
    "pow": np.power,
    "to_float": lambda x: np.asarray(x, np.float64),
    "to_int": lambda x: np.asarray(x, np.int64),
    "sigmoid": lambda x: 1.0 / (1.0 + np.exp(-np.asarray(x, np.float64))),
    "leakyrelu": lambda x, a: np.where(np.asarray(x) > 0, x, a * np.asarray(x)),
}


def _vec_binop(op: str, a, b):
    if op == "&":
        return np.logical_and(a, b)
    if op == "|":
        return np.logical_or(a, b)
    return {
        "+": lambda: a + b, "-": lambda: a - b, "*": lambda: a * b,
        "/": lambda: a / b, "==": lambda: a == b, "!=": lambda: a != b,
        "<": lambda: a < b, "<=": lambda: a <= b, ">": lambda: a > b,
        ">=": lambda: a >= b,
    }[op]()


class BatchEngine:
    """Executes one compiled module over K parameter bindings at once.

    Wraps any sequential :class:`~repro.core.engine.Engine` (or subclass):
    the inner engine provides the graph, the lowered kernels, and the
    per-launch batching hooks; this class owns the batched state and the
    masked host interpretation.
    """

    MSBFS_NAME = "__msbfs__"  # kernel_launches key of the bit-packed path

    def __init__(self, engine: Engine, enable_msbfs: bool = True):
        self.engine = engine
        self.module = engine.module
        self.options = engine.options
        self.graph = engine.graph  # already hub-relabeled by the engine
        self.argv = engine.argv
        self.enable_msbfs = enable_msbfs
        self.stats = EngineStats()
        self.state: Dict[str, jnp.ndarray] = {}
        self.host_env: Dict[str, Any] = {}
        self.batch_size = 0
        self._msbfs_plan: Any = False  # False = not yet matched

    def refresh_graph(self):
        """Re-point at the inner engine's graph after engine.refresh_graph().

        The inner engine rebuilds its relabeled graph object on refresh;
        this wrapper only snapshots the reference (the msbfs plan is
        module-derived and the batched launch closures live on the inner
        engine, which already dropped them).
        """
        self.graph = self.engine.graph

    # ------------------------------------------------------------------
    # entry point
    # ------------------------------------------------------------------
    def run_batch(self, param_sets: Sequence[Dict[str, Any]]) -> List[EngineResult]:
        """Answer every parameter binding; results in input order.

        All sets must share one key set (the batch-eligibility contract —
        checked again here because this is the last line of defense).
        """
        k = len(param_sets)
        if k == 0:
            return []
        keys = set(param_sets[0])
        for p in param_sets[1:]:
            if set(p) != keys:
                raise BatchError(
                    "batched execution needs one shared parameter key set; got "
                    f"{sorted(keys)} vs {sorted(p)}"
                )
        t0 = time.perf_counter()
        self.batch_size = k
        self.stats = EngineStats(batch_size=k)
        self._reset(param_sets)
        tr = tel.get()
        root_ctx = None
        if tr.enabled:
            with tr.span("run", engine=type(self).__name__,
                         batch_size=k) as sp:
                self._run_host(keys, k)
                sp.set(launches=self.stats.total_launches,
                       msbfs=self.MSBFS_NAME in self.stats.kernel_launches)
            root_ctx = sp.context()
        else:
            self._run_host(keys, k)
        self.stats.wall_time_s = time.perf_counter() - t0
        self.stats.run_time_s = max(
            0.0, self.stats.wall_time_s - self.stats.compile_time_s
        )
        results = self._finalize()
        if root_ctx is not None:
            trace = tr.summarize(root=root_ctx)
            for r in results:
                r.trace = trace  # shared, like stats
        return results

    def _run_host(self, keys, k: int) -> None:
        plan = self._msbfs()
        if plan is not None and plan.accepts(keys, self.graph.n_vertices):
            from .msbfs import run_msbfs

            run_msbfs(self, plan)
        else:
            host = self.module.host
            assert host is not None
            self._exec_block(host.main.body, np.ones(k, dtype=bool))

    def _msbfs(self):
        if not self.enable_msbfs:
            return None
        if self._msbfs_plan is False:
            from .msbfs import match_msbfs

            self._msbfs_plan = match_msbfs(self.module)
        return self._msbfs_plan

    # ------------------------------------------------------------------
    # batched state
    # ------------------------------------------------------------------
    def _reset(self, param_sets: Sequence[Dict[str, Any]]) -> None:
        k = len(param_sets)
        module, graph = self.module, self.graph
        self.state = {}
        for p in module.properties.values():
            n = graph.n_edges if p.is_edge else graph.n_vertices
            self.state[p.name] = jnp.zeros((k, n), DTYPES[p.scalar])
        for name, direction in module.degree_props.items():
            deg = graph.out_degree if direction == "out" else graph.in_degree
            row = jnp.asarray(deg).astype(DTYPES[module.properties[name].scalar])
            self.state[name] = jnp.broadcast_to(row, (k,) + row.shape)
        if module.graph.weighted:
            wdt = DTYPES[module.graph.weight_scalar or "float"]
            row = jnp.asarray(graph.weights).astype(wdt)
            self.state[WEIGHT_KEY] = jnp.broadcast_to(row, (k,) + row.shape)
        # scalar initial values: let the inner engine re-derive them (same
        # _eval_host semantics as a sequential run), then broadcast per lane
        self.engine.reset()
        self.host_env = {
            name: np.full(k, v) if isinstance(v, (int, float, bool, np.number)) else v
            for name, v in self.engine.host_env.items()
        }
        if param_sets:
            for name in param_sets[0]:
                self.host_env[name] = np.asarray([ps[name] for ps in param_sets])

    # ------------------------------------------------------------------
    # kernel launching (batched)
    # ------------------------------------------------------------------
    def _launch(self, name: str, mask: np.ndarray) -> None:
        kern = self.module.kernels.get(name)
        if kern is None:
            raise EngineError(f"{name!r} is not a device kernel")
        count_launch(self.stats, self.module, name)
        tr = tel.get()
        if tr.enabled:
            with tr.span("launch:" + name, kernel=name, mode="batched",
                         batch_size=self.batch_size,
                         active_lanes=int(mask.sum())):
                self._launch_inner(name, kern, mask)
        else:
            self._launch_inner(name, kern, mask)

    def _launch_inner(self, name: str, kern, mask: np.ndarray) -> None:
        bl = self.engine.batched_runner(name)
        scalars = self._kernel_scalars(name, kern)
        # first-touch (cold) timing: every distinct batch size K is its own
        # XLA trace; share the inner engine's warm-key registry so the
        # compile/run split stays consistent across run modes
        warm = self.engine._warm_keys
        key = ("batched", name, self.batch_size)
        if key in warm:
            updates = bl.fn(self.state, scalars)
        else:
            t0 = time.perf_counter()
            try:
                updates = bl.fn(self.state, scalars)
            finally:
                self.stats.compile_time_s += time.perf_counter() - t0
                warm.add(key)
        bl.bump_stats(self.stats)
        self._merge(updates, mask)

    def _kernel_scalars(self, name: str, kern) -> Dict[str, jnp.ndarray]:
        out = {}
        for s in sorted(kern.scalar_reads):
            info = self.module.scalars[s]
            out[s] = jnp.asarray(np.asarray(self.host_env[s]), DTYPES[info.scalar])
        return out

    def _merge(self, updates: Dict[str, jnp.ndarray], mask: np.ndarray) -> None:
        """Commit per-lane updates: inactive (converged) lanes keep state."""
        if mask.all():
            self.state.update(updates)
            return
        m = jnp.asarray(mask)[:, None]
        for prop, arr in updates.items():
            self.state[prop] = jnp.where(m, arr, self.state[prop])

    # ------------------------------------------------------------------
    # vertex id translation (vectorized host/device boundary)
    # ------------------------------------------------------------------
    def _xlate(self, prop: str, idx) -> np.ndarray:
        info = self.module.properties[prop]
        eng = self.engine
        idx = np.broadcast_to(np.asarray(idx, np.int64), (self.batch_size,))
        if (
            eng.old2new is not None
            and not info.is_edge
            and prop not in eng.accumulator_props
            and prop not in self.module.degree_props
        ):
            return np.asarray(eng.old2new)[idx]
        return idx

    # ------------------------------------------------------------------
    # masked host interpretation
    # ------------------------------------------------------------------
    def _truthy(self, v) -> np.ndarray:
        return np.broadcast_to(np.asarray(v) != 0, (self.batch_size,))

    def _exec_block(self, body: List[fir.Stmt], mask: np.ndarray) -> None:
        for st in body:
            self._exec_stmt(st, mask)

    def _exec_stmt(self, st: fir.Stmt, mask: np.ndarray) -> None:
        if isinstance(st, fir.VarDecl):
            val = self._eval(st.init, mask) if st.init is not None else 0
            val = np.broadcast_to(np.asarray(val), (self.batch_size,))
            old = self.host_env.get(st.name)
            # first declaration seeds every lane; re-declarations (loop
            # bodies) only overwrite the active lanes
            self.host_env[st.name] = (
                np.array(val) if old is None else np.where(mask, val, old)
            )
            return
        if isinstance(st, fir.Assign):
            tgt = st.target
            val = self._eval(st.value, mask)
            if isinstance(tgt, fir.Ident):
                old = self.host_env[tgt.name]
                self.host_env[tgt.name] = np.where(mask, val, old)
                return
            if isinstance(tgt, fir.Index) and isinstance(tgt.base, fir.Ident):
                self._write_prop(tgt.base.name, tgt.index, None, val, mask)
                return
            raise EngineError("unsupported host assignment")
        if isinstance(st, fir.ReduceAssign):
            tgt = st.target
            val = self._eval(st.value, mask)
            if isinstance(tgt, fir.Ident):
                cur = self.host_env[tgt.name]
                new = {
                    "+": lambda: cur + val, "-": lambda: cur - val,
                    "*": lambda: cur * val,
                    "min": lambda: np.minimum(cur, val),
                    "max": lambda: np.maximum(cur, val),
                }[st.op]()
                self.host_env[tgt.name] = np.where(mask, new, cur)
                return
            if isinstance(tgt, fir.Index) and isinstance(tgt.base, fir.Ident):
                self._write_prop(tgt.base.name, tgt.index, st.op, val, mask)
                return
            raise EngineError("unsupported host reduce target")
        if isinstance(st, fir.If):
            cond = self._truthy(self._eval(st.cond, mask))
            tmask = np.logical_and(mask, cond)
            if tmask.any():
                self._exec_block(st.then_body, tmask)
            if st.else_body:
                fmask = np.logical_and(mask, np.logical_not(cond))
                if fmask.any():
                    self._exec_block(st.else_body, fmask)
            return
        if isinstance(st, fir.While):
            guard = 0
            m = np.logical_and(mask, self._truthy(self._eval(st.cond, mask)))
            while m.any():
                self.stats.host_iterations += 1
                self._exec_block(st.body, m)
                m = np.logical_and(m, self._truthy(self._eval(st.cond, m)))
                guard += 1
                if guard > 1_000_000:
                    raise EngineError("host while loop exceeded 1e6 iterations")
            return
        if isinstance(st, fir.ExprStmt):
            self._eval(st.expr, mask)
            return
        if isinstance(st, fir.For):
            raise EngineError("host for loops are not part of the grammar")
        raise EngineError(f"unsupported host statement {type(st).__name__}")

    def _write_prop(self, prop: str, idx_expr: fir.Expr, op: Optional[str],
                    val, mask: np.ndarray) -> None:
        if prop not in self.module.properties:
            raise EngineError(f"host write to unknown property {prop!r}")
        cols = self._xlate(prop, self._eval(idx_expr, mask))
        rows = np.arange(self.batch_size)
        arr = self.state[prop]
        cur = arr[rows, cols]
        val = jnp.asarray(np.broadcast_to(np.asarray(val), (self.batch_size,)),
                          arr.dtype)
        if op is None:
            new = val
        elif op in ("+", "*", "min", "max"):
            new = combine(op, cur, val)
        else:
            raise EngineError(f"host reduce {op!r}")
        new = jnp.where(jnp.asarray(mask), new, cur)
        self.state[prop] = arr.at[rows, cols].set(new)

    # ------------------------------------------------------------------
    # vectorized host expression evaluation
    # ------------------------------------------------------------------
    def _eval(self, e: Optional[fir.Expr], mask: np.ndarray):
        if e is None:
            return None
        if isinstance(e, (fir.IntLit, fir.FloatLit, fir.BoolLit, fir.StrLit)):
            return e.value
        if isinstance(e, fir.Ident):
            if e.name in self.host_env:
                return self.host_env[e.name]
            if e.name == "argv":
                return self.argv
            raise EngineError(f"unknown host identifier {e.name!r}")
        if isinstance(e, fir.Index):
            base = e.base
            if isinstance(base, fir.Ident) and base.name in self.module.properties:
                cols = self._xlate(base.name, self._eval(e.index, mask))
                rows = np.arange(self.batch_size)
                return np.asarray(self.state[base.name][rows, cols])
            idx = self._eval(e.index, mask)
            if isinstance(idx, np.ndarray):
                uniq = np.unique(idx)
                if uniq.size != 1:
                    raise EngineError("host sequence index must be lane-uniform")
                idx = uniq[0]
            seq = self._eval(base, mask)
            return seq[int(idx)]
        if isinstance(e, fir.BinOp):
            return _vec_binop(e.op, self._eval(e.lhs, mask), self._eval(e.rhs, mask))
        if isinstance(e, fir.UnaryOp):
            v = self._eval(e.operand, mask)
            return np.logical_not(v) if e.op == "!" else -np.asarray(v)
        if isinstance(e, fir.Call):
            return self._host_call(e, mask)
        if isinstance(e, fir.MethodCall):
            return self._host_method(e, mask)
        raise EngineError(f"cannot evaluate host expression {type(e).__name__}")

    def _host_call(self, e: fir.Call, mask: np.ndarray):
        if e.func == "load":
            return None  # graph loading happened at engine construction
        if e.func == "swap":
            a, b = e.args
            an, bn = a.name, b.name  # type: ignore[attr-defined]
            va, vb = self.state[an], self.state[bn]
            if mask.all():
                self.state[an], self.state[bn] = vb, va
            else:  # per-lane swap: converged lanes keep their buffers
                m = jnp.asarray(mask)[:, None]
                self.state[an] = jnp.where(m, vb, va)
                self.state[bn] = jnp.where(m, va, vb)
            return None
        if e.func == "print":
            print(*[self._eval(a, mask) for a in e.args])
            return None
        host = self.module.host
        if host is not None and e.func in host.host_funcs:
            self._exec_block(host.host_funcs[e.func].body, mask)
            return None
        if e.func in _VEC_FNS:
            args = [self._eval(a, mask) for a in e.args]
            return _VEC_FNS[e.func](*args)
        raise EngineError(f"unknown host function {e.func!r}")

    def _host_method(self, e: fir.MethodCall, mask: np.ndarray):
        obj = e.obj
        name = obj.name if isinstance(obj, fir.Ident) else None
        g = self.module.graph
        if e.method == "size":
            # logical counts, mirroring Engine._host_method: padding is
            # invisible to size()-normalized math
            if name == g.edgeset_name:
                return self.graph.n_edges_logical
            return self.graph.n_vertices_logical
        if e.method in ("init", "process"):
            fn = e.args[0]
            if not isinstance(fn, fir.Ident):
                raise EngineError("init/process expects a function name")
            self._launch(fn.name, mask)
            return None
        if e.method == "getVertices":
            return None
        if e.method in ("getOutDegrees", "getInDegrees"):
            return None
        raise EngineError(f"unknown host method {e.method!r}")

    # ------------------------------------------------------------------
    # result splitting
    # ------------------------------------------------------------------
    def _finalize(self) -> List[EngineResult]:
        eng = self.engine
        props: Dict[str, np.ndarray] = {}
        for p in self.module.properties.values():
            arr = np.asarray(self.state[p.name])
            if (
                eng.old2new is not None
                and not p.is_edge
                and p.name not in eng.accumulator_props
            ):
                arr = arr[:, eng.old2new]
            props[p.name] = arr
        if WEIGHT_KEY in self.state:
            props["weight"] = np.asarray(self.state[WEIGHT_KEY])
        results = []
        for k in range(self.batch_size):
            henv: Dict[str, Any] = {}
            for name, v in self.host_env.items():
                if isinstance(v, np.ndarray):
                    x = v[k] if v.ndim else v
                    henv[name] = x.item() if hasattr(x, "item") else x
                else:
                    henv[name] = v
            results.append(
                EngineResult(
                    properties={n: a[k] for n, a in props.items()},
                    host_env=henv,
                    stats=self.stats,  # shared: batch_size says how many
                )
            )
        return results
