"""Batched multi-query execution: one launch set answering K queries.

Layers:

* :class:`BatchEngine` — batched state ([K, n] properties, [K] host
  scalars), masked host interpretation, vmapped kernel launches through
  each engine's ``batched_runner`` hook;
* :mod:`repro.batch.msbfs` — the bit-packed multi-source BFS fast path,
  selected automatically from the MIR frontier/direction verdicts;
* :class:`DynamicBatcher` — collects a live query stream into batches for
  the serving path.

The user-facing surface is :meth:`repro.core.program.Program.bind_batch`
returning a :class:`repro.core.session.BatchSession`, plus the transparent
rerouting inside ``Session.run_many`` / ``SessionPool.run_batch``.
"""
from .engine import BatchEngine, BatchError
from .dynamic import BatchServeStats, DynamicBatcher
from .msbfs import MSBFSPlan, match_msbfs

__all__ = [
    "BatchEngine",
    "BatchError",
    "BatchServeStats",
    "DynamicBatcher",
    "MSBFSPlan",
    "match_msbfs",
]
