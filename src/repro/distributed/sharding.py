"""Sharding rules: logical axis names -> mesh axes, with divisibility guards.

Parallelism layout (MaxText-style, DESIGN.md §5):
    batch                -> (pod, data)     data parallel across pods
    embed (d_model dim)  -> data            FSDP parameter sharding
    mlp / heads / vocab  -> model           tensor parallel
    experts              -> model           expert parallel
    qlora                -> data            (MLA low-rank dims: FSDP)
    layers / conv / state / head_dim / kvlora -> replicated

Any rule whose mesh axis does not evenly divide the dim is dropped for
that tensor (deterministic fallback to replication) so every config in
the assignment grid lowers without uneven-sharding surprises.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

LOGICAL_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "embed": "data",
    "embed2": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    "experts": "model",
    # expert FFN inner dims: baseline FSDP-shards d_model (weights move via
    # all-gather); the perf loop flips these to shard the ff dim instead
    # (tokens move, weights stay — see EXPERIMENTS.md §Perf)
    "expert_dmodel": "data",
    "expert_ff": None,
    "qlora": "data",
    "kvlora": None,
    "layers": None,
    "layers2": None,
    "conv": None,
    "state": None,
    "head_dim": None,
    "seq": None,
}


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes] if axes in mesh.axis_names else 1
    n = 1
    for a in axes:
        n *= mesh.shape[a] if a in mesh.axis_names else 1
    return n


def _present(mesh: Mesh, axes: MeshAxes) -> MeshAxes:
    """Restrict a rule to axes that exist in this mesh."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names else None
    kept = tuple(a for a in axes if a in mesh.axis_names)
    return kept if kept else None


def spec_for(
    mesh: Mesh,
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    rules: Optional[Dict[str, MeshAxes]] = None,
) -> P:
    """PartitionSpec for one tensor, dropping non-divisible placements."""
    rules = rules or LOGICAL_RULES
    out = []
    used: set = set()
    for dim, name in zip(shape, list(logical) + [None] * (len(shape) - len(logical))):
        axes = _present(mesh, rules.get(name)) if name else None
        if axes is not None:
            flat = (axes,) if isinstance(axes, str) else tuple(axes)
            if any(a in used for a in flat):
                axes = None  # a mesh axis may appear once per spec
        if axes is None or dim % _axis_size(mesh, axes) != 0:
            out.append(None)
        else:
            out.append(axes)
            for a in (axes,) if isinstance(axes, str) else axes:
                used.add(a)
    return P(*out)


def param_pspecs(mesh: Mesh, abstract_params: Any, logical_specs: Any,
                 rules: Optional[Dict[str, MeshAxes]] = None) -> Any:
    """PartitionSpec tree matching the params tree."""
    flat_p, tdef = jax.tree.flatten(abstract_params)
    flat_s = tdef.flatten_up_to(logical_specs)
    out = [
        spec_for(mesh, p.shape, s if isinstance(s, tuple) else (s,), rules)
        for p, s in zip(flat_p, flat_s)
    ]
    return tdef.unflatten(out)


def shardings_of(mesh: Mesh, pspecs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def opt_state_pspecs(mesh: Mesh, abstract_opt: Any, param_pspec_tree: Any) -> Any:
    """Optimizer-state specs: fp32 moments mirror the param specs; int8
    moments are flattened (replicate — they are 1/4 the size and the
    quantized path is used precisely when memory is tightest, so we shard
    them over 'data' on the flat axis when divisible)."""

    all_axes = tuple(mesh.axis_names)

    def for_moment(ps, leaf):
        if isinstance(leaf, dict):  # quantized {q, scale}: flat tensors —
            # shard over EVERY mesh axis (they are the biggest state for
            # the models that use quantization)
            out = {}
            for k, v in leaf.items():
                n = v.shape[0]
                axes = all_axes
                while axes and n % _axis_size(mesh, axes) != 0:
                    axes = axes[:-1]
                out[k] = P(axes) if axes else P(None)
            return out
        return ps

    is_q = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}
    m = jax.tree.map(for_moment, param_pspec_tree, abstract_opt["m"],
                     is_leaf=lambda x: isinstance(x, P))
    v = jax.tree.map(for_moment, param_pspec_tree, abstract_opt["v"],
                     is_leaf=lambda x: isinstance(x, P))
    return {"m": m, "v": v, "step": P()}


def batch_pspecs(mesh: Mesh, batch_abstract: Any) -> Any:
    def f(leaf):
        axes = _present(mesh, LOGICAL_RULES["batch"])
        b = leaf.shape[0]
        if axes is not None and b % _axis_size(mesh, axes) == 0:
            return P(axes, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(f, batch_abstract)


def cache_pspecs(mesh: Mesh, cfg, cache_abstract: Any, batch_size: int,
                 seq_shard: bool = False) -> Any:
    """Decode-cache specs: shard the batch dim over (pod, data) and the
    kv-head dim over model where divisible.

    seq_shard=True (perf-loop toggle): shard the cache SEQUENCE dim over
    the model axis instead — sequence-parallel decode attention. GSPMD
    turns the softmax/contraction reductions into small all-reduces while
    each chip only ever touches its 1/|model| cache slice."""
    d_axes = _present(mesh, ("pod", "data"))
    m_axis = _present(mesh, "model")

    def f(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
        if "pos" in names:
            return P()
        shape = leaf.shape
        spec: list = [None] * len(shape)
        for i, d in enumerate(shape):
            if d == batch_size and d % _axis_size(mesh, d_axes) == 0:
                spec[i] = d_axes
                break
        leafname = names[-1] if names else ""
        if leafname in ("k", "v", "ckv", "krope") and len(shape) >= 3:
            if seq_shard:
                sdim = len(shape) - (3 if leafname in ("k", "v") else 2)
                if m_axis is not None and shape[sdim] % _axis_size(mesh, m_axis) == 0 \
                        and spec[sdim] is None:
                    spec[sdim] = m_axis
            elif leafname in ("k", "v"):
                hk = shape[-2]
                if m_axis is not None and hk % _axis_size(mesh, m_axis) == 0:
                    spec[-2] = m_axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(f, cache_abstract)
