"""Compressed data-parallel gradient reduction (int8 all-reduce) and the
straggler monitor.

``compressed_psum_transform(mesh, axis)`` returns a grad_transform for
``make_train_step``: inside a ``shard_map`` over the data axis it
quantizes each gradient shard to int8 (block-wise absmax), all-reduces the
int8 payload + per-block scales, and dequantizes — 4x less DP wire traffic
than an f32 all-reduce, with error feedback left to the optimizer's moment
accumulation. Use with pure data-parallel replicas (each replica computes
grads on its microbatch); the GSPMD/FSDP path keeps XLA's native
all-reduces instead.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

QBLOCK = 256


def _quant_block(x: jnp.ndarray):
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = (n + QBLOCK - 1) // QBLOCK
    fb = jnp.pad(flat, (0, nb * QBLOCK - n)).reshape(nb, QBLOCK)
    scale = jnp.max(jnp.abs(fb), axis=1) / 127.0
    q = jnp.clip(jnp.round(fb / jnp.maximum(scale, 1e-12)[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_block(q: jnp.ndarray, scale: jnp.ndarray, shape):
    vals = q.astype(jnp.float32) * scale[:, None]
    n = int(np.prod(shape))
    return vals.reshape(-1)[:n].reshape(shape)


def compressed_allreduce(grads: Any, axis: str) -> Any:
    """int8-compressed mean over ``axis`` (call inside shard_map).

    Shared-scale scheme: one cheap pmax agrees on a per-block scale, every
    shard quantizes against it, and the summed int8 payload dequantizes
    EXACTLY (error = one rounding step per shard, bounded by n/254 of the
    block max). Wire accounting: the payload is 1 byte/element (+ nb f32
    scales) vs 4 — the 4x compression claim; XLA emulates the int8 ring
    with a widened psum, a custom collective on real fleets.
    """
    n = jax.lax.psum(1, axis)

    def one(g):
        flat = g.astype(jnp.float32).reshape(-1)
        shape, size = g.shape, flat.shape[0]
        fb = _blocks(flat)
        gmax = jax.lax.pmax(jnp.max(jnp.abs(fb), axis=1), axis)
        scale = jnp.maximum(gmax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(fb / scale[:, None]), -127, 127).astype(jnp.int8)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        mean = (qsum.astype(jnp.float32) * scale[:, None]) / n
        return mean.reshape(-1)[:size].reshape(shape).astype(g.dtype)

    return jax.tree.map(one, grads)


def _blocks(flat: jnp.ndarray) -> jnp.ndarray:
    n = flat.shape[0]
    nb = (n + QBLOCK - 1) // QBLOCK
    return jnp.pad(flat, (0, nb * QBLOCK - n)).reshape(nb, QBLOCK)


def compressed_psum_transform(mesh: Mesh, axis: str = "data") -> Callable:
    """grad_transform for make_train_step under shard_map data parallelism."""

    def transform(grads):
        return compressed_allreduce(grads, axis)

    return transform


# --------------------------------------------------------------------------
# straggler mitigation
# --------------------------------------------------------------------------


@dataclass
class StragglerMonitor:
    """Step-time EWMA monitor (DESIGN.md §5).

    In a real deployment each host reports step durations; a step slower
    than ``threshold`` x the EWMA flags its host as a straggler, which the
    orchestrator answers by (1) shrinking that host's data shard
    (rebalance), or (2) promoting a hot spare and re-sharding via the
    elastic checkpoint path. This class implements the detection half and
    records the decisions it would take (unit-tested; the cluster side
    needs real hardware).
    """

    alpha: float = 0.2
    threshold: float = 2.0
    warmup: int = 5
    ewma: Optional[float] = None
    steps: int = 0
    flags: List[Dict[str, Any]] = field(default_factory=list)

    def record(self, step_time_s: float, host: int = 0) -> bool:
        self.steps += 1
        if self.ewma is None:
            self.ewma = step_time_s
            return False
        is_straggler = (
            self.steps > self.warmup and step_time_s > self.threshold * self.ewma
        )
        if is_straggler:
            self.flags.append(
                {
                    "host": host,
                    "step_time_s": step_time_s,
                    "ewma_s": self.ewma,
                    "action": "rebalance-or-replace",
                    "at_step": self.steps,
                }
            )
        # stragglers do not poison the EWMA
        if not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time_s
        return is_straggler
