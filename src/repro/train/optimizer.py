"""Optimizers built from scratch (no optax): AdamW and 8-bit AdamW.

``adamw``      — fp32 moments (standard production configuration).
``adamw8bit``  — block-wise absmax-quantized int8 moments (1+1 bytes/param
                 instead of 4+4): the distributed-optimization trick that
                 lets the 1T-param kimi-k2 fit 512 chips (see DESIGN.md §5).

Both support global-norm clipping and decoupled weight decay; state is a
plain pytree so it shards with the same PartitionSpecs as the params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any

QBLOCK = 256  # quantization block (elements)


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    quantized: bool = False  # int8 moments
    acc_dtype: str = "float32"  # microbatch grad accumulator dtype


def lr_schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


# --------------------------------------------------------------------------
# int8 block quantization
# --------------------------------------------------------------------------


def _blocks(flat: jnp.ndarray) -> jnp.ndarray:
    n = flat.shape[0]
    nb = (n + QBLOCK - 1) // QBLOCK
    return jnp.pad(flat, (0, nb * QBLOCK - n)).reshape(nb, QBLOCK)


def _quant(x: jnp.ndarray, power: int = 2) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-wise *nonlinear* int8 quantization (bitsandbytes-style).

    code value = sign(q) * (|q|/127)**power * blockmax — the power-law code
    concentrates resolution near zero, which linear absmax lacks; power=2
    suits first moments, power=4 the (non-negative, huge-dynamic-range)
    second moments."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    fb = _blocks(flat)
    scale = jnp.max(jnp.abs(fb), axis=1)
    safe = jnp.maximum(scale, 1e-20)
    frac = jnp.clip(jnp.abs(fb) / safe[:, None], 0.0, 1.0)
    q = jnp.round(127.0 * frac ** (1.0 / power)) * jnp.sign(fb)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:n], scale.astype(jnp.float32)


def _dequant(q: jnp.ndarray, scale: jnp.ndarray, shape, power: int = 2) -> jnp.ndarray:
    fb = _blocks(q.astype(jnp.float32))
    frac = jnp.abs(fb) / 127.0
    vals = jnp.sign(fb) * frac**power * scale[:, None]
    n = q.shape[0]
    return vals.reshape(-1)[:n].reshape(shape)


# --------------------------------------------------------------------------
# state construction
# --------------------------------------------------------------------------


def init_state(params: Params, cfg: OptConfig, abstract: bool = False) -> Dict[str, Any]:
    def zeros_like_f32(p):
        if abstract:
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def qzeros(p):
        n = 1
        for d in p.shape:
            n *= d
        nb = (n + QBLOCK - 1) // QBLOCK
        if abstract:
            return {
                "q": jax.ShapeDtypeStruct((n,), jnp.int8),
                "scale": jax.ShapeDtypeStruct((nb,), jnp.float32),
            }
        return {"q": jnp.zeros((n,), jnp.int8), "scale": jnp.zeros((nb,), jnp.float32)}

    mk = qzeros if cfg.quantized else zeros_like_f32
    is_leaf = lambda x: isinstance(x, jax.ShapeDtypeStruct) or hasattr(x, "shape")
    return {
        "m": jax.tree.map(mk, params, is_leaf=is_leaf),
        "v": jax.tree.map(mk, params, is_leaf=is_leaf),
        "step": jax.ShapeDtypeStruct((), jnp.int32) if abstract else jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_updates(
    params: Params,
    grads: Params,
    state: Dict[str, Any],
    cfg: OptConfig,
) -> Tuple[Params, Dict[str, Any]]:
    """One AdamW step (fp32 or int8 moments)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    if cfg.quantized:
        def upd(p, g, mq, vq):
            g = g.astype(jnp.float32) * clip
            m = _dequant(mq["q"], mq["scale"], g.shape, power=2)
            v = _dequant(vq["q"], vq["scale"], g.shape, power=4)
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            nmq, nms = _quant(m, power=2)
            nvq, nvs = _quant(v, power=4)
            return newp, {"q": nmq, "scale": nms}, {"q": nvq, "scale": nvs}

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return newp, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
