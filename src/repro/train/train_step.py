"""Train step builder: remat + microbatch accumulation + optional
int8-compressed data-parallel gradient reduction.

``make_train_step(model, opt_cfg, n_microbatches)`` returns a pure
function (params, opt_state, batch) -> (params, opt_state, metrics) that
jits/pjits cleanly; the global batch's leading dim is split into
microbatches accumulated by a ``lax.scan`` (activation memory /
n_microbatches, the standard large-model configuration).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..models.model import Model
from . import optimizer as opt_mod
from .optimizer import OptConfig


def make_train_step(
    model: Model,
    opt_cfg: OptConfig,
    n_microbatches: int = 1,
    remat: bool = True,  # layer-level remat: construct the Model with remat=True
    grad_transform: Optional[Callable] = None,  # e.g. compressed psum
):
    model.remat = model.remat or remat
    loss_fn = lambda p, mb: model.loss(p, mb)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if n_microbatches > 1:
            def micro(acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
                return acc, metrics

            def split(x):
                b = x.shape[0]
                return x.reshape((n_microbatches, b // n_microbatches) + x.shape[1:])

            mbs = jax.tree.map(split, batch)
            acc_dt = jnp.dtype(opt_cfg.acc_dtype)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            grads, metricses = jax.lax.scan(micro, zeros, mbs)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            metrics = jax.tree.map(jnp.mean, metricses)
            loss = metrics["loss"]
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        metrics = dict(metrics)
        metrics["grad_norm"] = opt_mod.global_norm(grads)
        new_params, new_state = opt_mod.apply_updates(params, grads, opt_state, opt_cfg)
        metrics["lr"] = opt_mod.lr_schedule(opt_cfg, new_state["step"])
        return new_params, new_state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return metrics

    return eval_step
