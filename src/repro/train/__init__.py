from .optimizer import OptConfig, init_state, apply_updates, lr_schedule, global_norm
from .train_step import make_train_step, make_eval_step

__all__ = ["OptConfig", "init_state", "apply_updates", "lr_schedule", "global_norm", "make_train_step", "make_eval_step"]
