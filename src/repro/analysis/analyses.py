"""Dataflow analyses over analyzed MIR modules.

Runs *after* semantic analysis (and, for a compiled ``Program``, after the
optimization pass pipeline — so fusion-merged kernels are analyzed in their
final, concatenated form and cross-kernel conflicts introduced by ``fuse``
surface here). Nothing in this module mutates the module or contributes to
its canonical serialization: like ``passes.analyze_incremental`` (the
precedent this framework promotes), verdicts live entirely outside
``Module.describe()`` / ``fir.dump``, so program fingerprints, cache
identities and saved artifacts are untouched by analysis.

The concrete analyses (diagnostic codes in :mod:`.diagnostics`):

* **Scatter-write race** (GT101/GT102) — the paper's §III memory-conflict
  hazard. A per-edge write (DST/NEIGHBOR/OTHER pattern anywhere, or SRC in
  an edge kernel) that is a plain ``=`` store races unless its value is
  *uniform per target slot* (e.g. ``active[src] = 0``: every edge of one
  src writes the same value). ``min=``/``max=``/``+=``/``-=``/``*=``
  reductions are commutative-associative and conflict-free. Two different
  reduce ops on one property inside one kernel (possible after ``fuse``
  body-merges adjacent vertex kernels) are order-dependent: GT102.
* **Determinism certificate** (GT201) — ``deterministic`` (no scatters, or
  only min/max/integer reductions), ``reduction-deterministic`` (float
  ``+=``/``*=`` scatters: value-correct under any reduction order, but
  bitwise output depends on it; the shuffle path's sorted segment reduce
  pins a canonical order), or ``racy`` (a GT101/GT102 finding exists).
* **Uninitialized-read / dead-write** (GT301/GT302) along host control
  flow in launch order.
* **Non-termination heuristics** (GT401/GT402).
* **Shape-dependent dtype/overflow** (GT501/GT502) given a ``GraphShape``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from ..core import fir, mir
from ..core.passes import (
    _host_blocks,
    _host_written_names,
    _iter_all_stmts,
    _launch_target,
    _visit_expr,
    analyze_incremental,
)
from ..core.semantic import _index_pattern
from .diagnostics import Diagnostic, make

_SCATTERED = (mir.IndexPattern.DST, mir.IndexPattern.NEIGHBOR,
              mir.IndexPattern.OTHER)
_INT32_MAX = 2**31 - 1

# certificate tiers, weakest guarantee last
DETERMINISTIC = "deterministic"
REDUCTION_DETERMINISTIC = "reduction-deterministic"
RACY = "racy"


def _device_kernels(module: mir.Module) -> List[mir.Kernel]:
    """Plain kernels to analyze — includes fusion-merged bodies (they are
    reanalyzed ``Kernel`` entries) and PipelineKernel stages (stages keep
    their own ``module.kernels`` entries, and stage boundaries commit, so
    a pipeline introduces no cross-stage write hazard of its own)."""
    return [k for k in module.kernels.values()
            if isinstance(k, mir.Kernel) and k.kind is not mir.KernelKind.HOST]


def _iter_prop_writes(module: mir.Module, k: mir.Kernel):
    """Yield ``(stmt, prop, pattern, op)`` for every property write in
    ``k``'s body, tracking neighbor-loop variables for NEIGHBOR patterns.
    ``op`` is the reduce op or None for a plain assignment."""
    loop_vars: Set[str] = set()

    def walk(body):
        for st in body:
            if isinstance(st, (fir.Assign, fir.ReduceAssign)):
                tgt = st.target
                if (isinstance(tgt, fir.Index) and isinstance(tgt.base, fir.Ident)
                        and tgt.base.name in module.properties):
                    pat = _index_pattern(tgt.index, k, loop_vars)
                    op = st.op if isinstance(st, fir.ReduceAssign) else None
                    yield st, tgt.base.name, pat, op
            elif isinstance(st, fir.If):
                yield from walk(st.then_body)
                yield from walk(st.else_body)
            elif isinstance(st, fir.For):
                loop_vars.add(st.var)
                yield from walk(st.body)
                loop_vars.discard(st.var)
            elif isinstance(st, fir.While):
                yield from walk(st.body)

    yield from walk(k.func.body)


def _per_edge(k: mir.Kernel, pattern: mir.IndexPattern) -> bool:
    """True when multiple lanes/edges may target the same slot: scattered
    patterns anywhere, SRC writes in edge kernels (one src, many edges),
    and CONST accumulator cells written from edge kernels."""
    if pattern in _SCATTERED:
        return True
    if k.kind is mir.KernelKind.EDGE and pattern in (
            mir.IndexPattern.SRC, mir.IndexPattern.CONST):
        return True
    return False


def _write_anchor(k: mir.Kernel, tgt_index: fir.Expr) -> Optional[str]:
    """The index identifier a write is keyed on, when it is a plain ident."""
    if isinstance(tgt_index, fir.Ident):
        return tgt_index.name
    return None


def _value_uniform(module: mir.Module, k: mir.Kernel, value: fir.Expr,
                   anchor: Optional[str]) -> bool:
    """True when ``value`` is provably the same for every edge/lane writing
    a given target slot — literals, host scalars, and reads keyed on the
    write's own index. Anything else (other kernel params, the edge
    weight, locals, differently-indexed property reads) is conservatively
    per-edge-varying."""
    uniform = True
    params = {p for p in (k.vertex_param, k.src_param, k.dst_param,
                          k.weight_param) if p}

    def visit(e):
        nonlocal uniform
        if not uniform or e is None:
            return
        if isinstance(e, (fir.IntLit, fir.FloatLit, fir.BoolLit, fir.StrLit)):
            return
        if (isinstance(e, fir.Index) and isinstance(e.base, fir.Ident)
                and e.base.name in module.properties):
            idx = e.index
            if not (anchor and isinstance(idx, fir.Ident) and idx.name == anchor):
                uniform = False
            return
        if isinstance(e, fir.Ident):
            if e.name in module.scalars or e.name == anchor:
                return
            if e.name in params:
                uniform = False  # varies per edge relative to the target slot
            else:
                uniform = False  # locals/loop vars: conservatively varying
            return
        if isinstance(e, fir.BinOp):
            visit(e.lhs)
            visit(e.rhs)
        elif isinstance(e, fir.UnaryOp):
            visit(e.operand)
        elif isinstance(e, fir.Index):
            visit(e.base)
            visit(e.index)
        elif isinstance(e, (fir.Call, fir.MethodCall)):
            for a in e.args:
                visit(a)
            if isinstance(e, fir.MethodCall):
                visit(e.obj)

    visit(value)
    return uniform


def race_analysis(module: mir.Module) -> Tuple[List[Diagnostic], Set[str]]:
    """GT101/GT102 plus the float-reduction property set (certificate).

    Returns ``(diagnostics, float_reduce_props)`` where the latter names
    float properties receiving per-edge ``+``/``-``/``*`` reductions —
    value-correct but reassociation-sensitive.
    """
    diags: List[Diagnostic] = []
    float_props: Set[str] = set()
    seen: Set[Tuple[str, str, int, int]] = set()  # dedup fusion body copies

    for k in _device_kernels(module):
        ops_by_prop: Dict[str, Set[str]] = {}
        first_site: Dict[str, Tuple[int, int]] = {}
        for st, prop, pat, op in _iter_prop_writes(module, k):
            if not _per_edge(k, pat):
                continue
            anchor = None
            if pat in (mir.IndexPattern.SRC, mir.IndexPattern.DST,
                       mir.IndexPattern.NEIGHBOR):
                anchor = _write_anchor(k, st.target.index)
            if op is None:
                if _value_uniform(module, k, st.value, anchor):
                    continue  # every conflicting writer stores the same value
                key = ("GT101", prop, st.line, st.col)
                if key not in seen:
                    seen.add(key)
                    diags.append(make(
                        "GT101",
                        f"non-reduction scatter write: {prop}[{pat.value}] = ... "
                        f"is stored per edge with an edge-varying value; "
                        f"concurrent edges targeting one {pat.value} slot race. "
                        f"Use a min=/max=/+= reduction (or make the stored "
                        f"value depend only on the written index).",
                        kernel=k.name, prop=prop, line=st.line, col=st.col,
                    ))
                effective = "="
            else:
                effective = op
                if (op in ("+", "-", "*")
                        and module.properties[prop].scalar == "float"):
                    float_props.add(prop)
            ops_by_prop.setdefault(prop, set()).add(effective)
            first_site.setdefault(prop, (st.line, st.col))

        for prop, ops in sorted(ops_by_prop.items()):
            if len(ops) > 1:
                line, col = first_site[prop]
                key = ("GT102", prop, line, col)
                if key in seen:
                    continue
                seen.add(key)
                diags.append(make(
                    "GT102",
                    f"conflicting reduction operators {sorted(ops)} on "
                    f"scattered property {prop} within kernel {k.name}; "
                    f"the combined result depends on commit order.",
                    kernel=k.name, prop=prop, line=line, col=col,
                ))
    return diags, float_props


def certificate_info(module: mir.Module) -> Tuple[str, str]:
    """(tier, explanation) of the determinism certificate."""
    race_diags, float_props = race_analysis(module)
    if race_diags:
        codes = sorted({d.code for d in race_diags})
        return RACY, (
            f"racy: unresolved scatter-write hazards ({', '.join(codes)}); "
            f"results depend on commit order"
        )
    if float_props:
        return REDUCTION_DETERMINISTIC, (
            f"reduction-deterministic: float reductions into "
            f"{sorted(float_props)} are value-correct under any reduction "
            f"order but bitwise-sensitive to reassociation; the shuffle "
            f"path's sorted segment reduce pins a canonical edge order"
        )
    return DETERMINISTIC, (
        "deterministic: all scattered writes are order-insensitive "
        "reductions (min/max or integer arithmetic)"
    )


def determinism_certificate(module: mir.Module) -> str:
    """The certificate tier alone (what reports and manifests carry)."""
    return certificate_info(module)[0]


def needs_shuffle(module: mir.Module) -> bool:
    """True when the program relies on the shuffle stage for *correctness*,
    not just throughput: it contains a racy plain-``=`` scatter, and only
    the shuffle path's deterministic last-write-wins commit gives it a
    defined result. Engines consult this to force ``shuffle`` on
    (``Target.shuffle=False`` is a throughput ablation, not a license to
    produce undefined results)."""
    diags, _ = race_analysis(module)
    return any(d.code == "GT101" for d in diags)


# ---------------------------------------------------------------------------
# host-control-flow analyses
# ---------------------------------------------------------------------------


def _prop_mentions(module: mir.Module, e: fir.Expr) -> Set[str]:
    """Property names read anywhere inside one expression tree."""
    out: Set[str] = set()

    def note(x):
        if isinstance(x, fir.Index) and isinstance(x.base, fir.Ident) \
                and x.base.name in module.properties:
            out.add(x.base.name)
        if isinstance(x, fir.Ident) and x.name in module.properties:
            out.add(x.name)

    _visit_expr(e, note)
    return out


def _launch_stages(module: mir.Module, st: fir.Stmt) -> List[mir.Kernel]:
    """The plain kernels a host statement launches (pipeline stages in
    commit order), or [] when it is not a launch."""
    tgt = _launch_target(module, st)
    if tgt is None:
        return []
    kern = module.kernels[tgt[0]]
    if isinstance(kern, mir.PipelineKernel):
        return list(kern.stages)
    return [kern]


def uninit_and_dead_analysis(module: mir.Module) -> List[Diagnostic]:
    """GT301 (read-before-init) + GT302 (write-only property).

    Walks the host program in launch order, tracking which properties have
    been written (by host index-stores or by launched kernels — reduce
    writes count: they *define* through accumulation over the zero-filled
    buffer). A kernel/host read of a never-written property relies on the
    backend's implicit zero fill: GT301. Properties written somewhere but
    never read by any kernel or host expression are flagged GT302 (they
    remain observable in results, hence a warning, not an error).
    """
    diags: List[Diagnostic] = []
    props = module.properties
    defined: Set[str] = set(module.degree_props)
    reported: Set[str] = set()

    def read(prop: str, line: int, col: int, where: str):
        if prop in props and prop not in defined and prop not in reported:
            reported.add(prop)
            diags.append(make(
                "GT301",
                f"property {prop} is read ({where}) before any kernel or "
                f"host statement initializes it; the read observes the "
                f"implicit zero-filled buffer.",
                prop=prop, line=line, col=col,
            ))

    def expr_reads(e: Optional[fir.Expr], st: fir.Stmt, where: str):
        if e is None:
            return
        for p in sorted(_prop_mentions(module, e)):
            read(p, st.line, getattr(st, "col", 0), where)

    def scan(body: List[fir.Stmt], depth: int = 0):
        if depth > 8:  # host-func recursion guard
            return
        for st in body:
            stages = _launch_stages(module, st)
            if stages:
                for s in stages:
                    for r in s.reads:
                        read(r.prop, st.line, getattr(st, "col", 0),
                             f"by kernel {s.name}")
                    defined.update(w.prop for w in s.writes)
                continue
            if isinstance(st, fir.Assign):
                if isinstance(st.target, fir.Index):
                    expr_reads(st.target.index, st, "as a host index")
                expr_reads(st.value, st, "by a host statement")
                tgt = st.target
                if (isinstance(tgt, fir.Index) and isinstance(tgt.base, fir.Ident)
                        and tgt.base.name in props):
                    defined.add(tgt.base.name)
            elif isinstance(st, fir.ReduceAssign):
                expr_reads(st.target, st, "by a host reduce")
                expr_reads(st.value, st, "by a host statement")
                tgt = st.target
                if (isinstance(tgt, fir.Index) and isinstance(tgt.base, fir.Ident)
                        and tgt.base.name in props):
                    defined.add(tgt.base.name)
            elif isinstance(st, fir.VarDecl):
                expr_reads(st.init, st, "by a host statement")
            elif isinstance(st, fir.If):
                expr_reads(st.cond, st, "by a host condition")
                scan(st.then_body, depth)
                scan(st.else_body, depth)
            elif isinstance(st, fir.While):
                expr_reads(st.cond, st, "by a host condition")
                scan(st.body, depth)
            elif isinstance(st, fir.For):
                expr_reads(st.iter, st, "by a host statement")
                scan(st.body, depth)
            elif isinstance(st, fir.ExprStmt):
                e = st.expr
                if isinstance(e, fir.Call) and e.func == "swap":
                    for a in e.args:
                        if isinstance(a, fir.Ident) and a.name in props:
                            read(a.name, st.line, getattr(st, "col", 0),
                                 "by swap()")
                            defined.add(a.name)
                    continue
                if (isinstance(e, fir.Call)
                        and e.func in module.host.host_funcs):
                    scan(module.host.host_funcs[e.func].body, depth + 1)
                    continue
                expr_reads(e, st, "by a host statement")

    scan(module.host.main.body)

    # -- dead writes: written somewhere, read nowhere ----------------------
    read_props: Set[str] = set()
    written_props: Dict[str, Tuple[Optional[str], int, int]] = {}
    for k in _device_kernels(module):
        read_props.update(r.prop for r in k.reads)
        for st, prop, _pat, _op in _iter_prop_writes(module, k):
            written_props.setdefault(prop, (k.name, st.line, st.col))
    for block in _host_blocks(module):
        for st in _iter_all_stmts(block):
            for e in _stmt_read_exprs(st):
                read_props |= _prop_mentions(module, e)
            if isinstance(st, (fir.Assign, fir.ReduceAssign)):
                tgt = st.target
                if (isinstance(tgt, fir.Index) and isinstance(tgt.base, fir.Ident)
                        and tgt.base.name in props):
                    written_props.setdefault(
                        tgt.base.name, (None, st.line, getattr(st, "col", 0)))
    for prop in sorted(set(written_props) - read_props):
        kname, line, col = written_props[prop]
        diags.append(make(
            "GT302",
            f"property {prop} is written but never read by any kernel or "
            f"host statement; its writes are observable only as a result "
            f"output.",
            kernel=kname, prop=prop, line=line, col=col,
        ))
    return diags


def _stmt_read_exprs(st: fir.Stmt) -> List[fir.Expr]:
    """The value-side expressions of one host statement (read positions)."""
    if isinstance(st, fir.Assign):
        out = [st.value]
        if isinstance(st.target, fir.Index):
            out.append(st.target.index)
        return out
    if isinstance(st, fir.ReduceAssign):
        return [st.target, st.value]
    if isinstance(st, fir.VarDecl):
        return [st.init] if st.init is not None else []
    if isinstance(st, fir.If):
        return [st.cond]
    if isinstance(st, fir.While):
        return [st.cond]
    if isinstance(st, fir.For):
        return [st.iter]
    if isinstance(st, fir.ExprStmt):
        return [st.expr]
    return []


def _names_read(module: mir.Module, e: fir.Expr) -> Tuple[Set[str], bool]:
    """(scalar/local/property names read in ``e``, analyzable) — not
    analyzable when the condition involves calls whose effects we cannot
    model (e.g. ``argv()``)."""
    names: Set[str] = set()
    analyzable = True

    def note(x):
        nonlocal analyzable
        if isinstance(x, fir.Index) and isinstance(x.base, fir.Ident) \
                and x.base.name in module.properties:
            names.add(x.base.name)
        elif isinstance(x, fir.Ident):
            names.add(x.name)
        elif isinstance(x, (fir.Call, fir.MethodCall)):
            analyzable = False

    _visit_expr(e, note)
    return names, analyzable


def _body_writes(module: mir.Module, body: List[fir.Stmt],
                 depth: int = 0) -> Set[str]:
    """Every name (host var, scalar, property) written inside a loop body,
    including properties written by launched kernels and writes inside
    called host functions."""
    written: Set[str] = set()
    if depth > 8:
        return written
    for st in _iter_all_stmts(body):
        stages = _launch_stages(module, st)
        if stages:
            for s in stages:
                written.update(w.prop for w in s.writes)
            continue
        if isinstance(st, (fir.Assign, fir.ReduceAssign)):
            tgt = st.target
            if isinstance(tgt, fir.Ident):
                written.add(tgt.name)
            elif isinstance(tgt, fir.Index) and isinstance(tgt.base, fir.Ident):
                written.add(tgt.base.name)
        elif isinstance(st, fir.For):
            written.add(st.var)
        elif isinstance(st, fir.ExprStmt):
            e = st.expr
            if isinstance(e, fir.Call) and e.func == "swap":
                written.update(a.name for a in e.args
                               if isinstance(a, fir.Ident))
            elif isinstance(e, fir.Call) and e.func in module.host.host_funcs:
                written |= _body_writes(
                    module, module.host.host_funcs[e.func].body, depth + 1)
    return written


def termination_analysis(module: mir.Module) -> List[Diagnostic]:
    """GT401 (condition never updated) + GT402 (stale frontier loop)."""
    diags: List[Diagnostic] = []
    # globally-mutated names: distinguishes a dynamic frontier from a
    # loop-invariant guard (mirrors the `direction` pass's DENSE verdict)
    mutated: Set[str] = set(_host_written_names(module))
    for k in _device_kernels(module):
        mutated |= {w.prop for w in k.writes}

    for block in _host_blocks(module):
        for st in _iter_all_stmts(block):
            if not isinstance(st, fir.While):
                continue
            cond_names, analyzable = _names_read(module, st.cond)
            writes = _body_writes(module, st.body)
            if analyzable and not (cond_names & writes):
                what = (f"variables {sorted(cond_names)} are"
                        if cond_names else "the condition reads no variable and is")
                diags.append(make(
                    "GT401",
                    f"while condition never updated: {what} never written "
                    f"inside the loop body, so the loop cannot make "
                    f"progress toward termination.",
                    line=st.line, col=getattr(st, "col", 0),
                ))
            # frontier staleness: a dynamically-guarded edge kernel is
            # launched here, but nothing in this loop updates its frontier
            for lst in _iter_all_stmts(st.body):
                for s in _launch_stages(module, lst):
                    fr = s.frontier
                    if fr is None or s.kind is not mir.KernelKind.EDGE:
                        continue
                    if not (fr.props & mutated):
                        continue  # loop-invariant guard (direction: DENSE)
                    if not (fr.props & writes):
                        diags.append(make(
                            "GT402",
                            f"frontier loop never updates the frontier: "
                            f"kernel {s.name} is guarded on "
                            f"{sorted(fr.props)} but no statement in this "
                            f"loop writes those properties — the frontier "
                            f"can never drain.",
                            kernel=s.name, line=st.line,
                            col=getattr(st, "col", 0),
                        ))
    return diags


# ---------------------------------------------------------------------------
# shape-dependent dtype / overflow analysis
# ---------------------------------------------------------------------------


def shape_analysis(module: mir.Module, shape) -> List[Diagnostic]:
    """GT501/GT502 given a GraphShape-like object with ``n_edges``.

    Edge indices and CSR offsets are int32 in every backend buffer layout:
    |E| past 2**31-1 is unrepresentable (GT502). Int properties receiving
    per-edge ``+`` reductions accumulate up to |E| contributions per sweep;
    with host loops repeating sweeps, int32 wraps once |E| nears the int32
    range — flagged with a 2x safety margin (GT501).
    """
    diags: List[Diagnostic] = []
    n_edges = int(getattr(shape, "n_edges", 0) or 0)
    if n_edges > _INT32_MAX:
        diags.append(make(
            "GT502",
            f"graph shape declares n_edges={n_edges}, which exceeds the "
            f"int32 edge-index space ({_INT32_MAX}) of the CSR "
            f"indptr/indices layout.",
        ))
    if n_edges > _INT32_MAX // 2:
        for k in _device_kernels(module):
            for st, prop, pat, op in _iter_prop_writes(module, k):
                if op not in ("+", "-"):
                    continue
                if not _per_edge(k, pat):
                    continue
                if module.properties[prop].scalar != "int":
                    continue
                diags.append(make(
                    "GT501",
                    f"int32 accumulator {prop} receives a per-edge "
                    f"'{op}=' reduction; at n_edges={n_edges} a single "
                    f"sweep can contribute up to |E| increments and "
                    f"overflow int32. Use a float property or reduce "
                    f"the shape bucket.",
                    kernel=k.name, prop=prop, line=st.line, col=st.col,
                ))
    # dedup repeated sites per (kernel, prop)
    seen: Set[Tuple[str, Optional[str], Optional[str]]] = set()
    out: List[Diagnostic] = []
    for d in diags:
        key = (d.code, d.kernel, d.prop)
        if key not in seen:
            seen.add(key)
            out.append(d)
    return out


# ---------------------------------------------------------------------------
# framework entry
# ---------------------------------------------------------------------------


def incremental_diagnostic(module: mir.Module) -> Diagnostic:
    """``passes.analyze_incremental`` promoted into the framework: the
    streaming-eligibility boolean with its explanation attached."""
    info = analyze_incremental(module)
    if info.incremental_ok:
        msg = (f"streaming-incremental eligible: monotone "
               f"{'/'.join(info.reduce_ops)} reductions match the "
               f"{info.template.kind!r} repair template on property "
               f"{info.template.dist_prop!r}.")
    elif info.monotone:
        msg = ("monotone but no recognized repair template; streaming "
               "updates fall back to full recompute.")
    else:
        msg = ("not streaming-incremental: "
               + "; ".join(info.reasons)
               + ". Streaming updates fall back to full recompute.")
    return make("GT202", msg)


def analyze_module(module: mir.Module, shape=None) -> List[Diagnostic]:
    """Run every analysis over one analyzed (and possibly optimized) MIR
    module; returns diagnostics sorted most-severe-first."""
    diags: List[Diagnostic] = []
    race_diags, _ = race_analysis(module)
    diags += race_diags
    tier, explanation = certificate_info(module)
    diags.append(make("GT201", f"determinism certificate: {explanation}"))
    diags.append(incremental_diagnostic(module))
    diags += uninit_and_dead_analysis(module)
    diags += termination_analysis(module)
    if shape is not None:
        diags += shape_analysis(module, shape)
    return sorted(diags, key=lambda d: d.sort_key)
