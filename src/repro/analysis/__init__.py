"""`repro.analyze`: static analysis + lint over Graphitron programs.

    result = repro.analyze(src_or_program)      # AnalysisResult
    for d in result.diagnostics:
        print(d.format())

``analyze`` accepts ``.gt`` source text, an embedded
:class:`~repro.frontend.GraphProgram`, or a compiled
:class:`~repro.core.program.Program`, runs the front-end + pass pipeline
(for text/embedded inputs it re-runs them *fresh*, never trusting the
shared module cache, so line/column provenance is always faithful to the
input you passed), and runs every dataflow analysis in
:mod:`repro.analysis.analyses`. Front-end failures do not raise — they
surface as ``GT001``–``GT004`` error diagnostics, which is what a lint
driver wants.

Provenance is rendered per front-end: caret excerpts into the ``.gt``
text, ``file.py:lineno`` for embedded programs. The diagnostic *codes*
are front-end independent — a text program and its embedded twin produce
the same codes (tested as the parity matrix in tests/test_analysis.py).

The ``python -m repro.lint`` CLI (:mod:`repro.lint`) and the ``strict=``
knob of :func:`repro.compile` are thin wrappers over this entry point;
:meth:`GraphService.submit` consults :meth:`Program.diagnostics` to
reject error-level programs before registry admission.
"""
from __future__ import annotations

from typing import Dict, List

from .analyses import (  # noqa: F401 - re-exported analysis API
    DETERMINISTIC,
    RACY,
    REDUCTION_DETERMINISTIC,
    analyze_module,
    certificate_info,
    determinism_certificate,
    incremental_diagnostic,
    needs_shuffle,
    race_analysis,
)
from .diagnostics import CODES, SEVERITIES, AnalysisResult, Diagnostic, make  # noqa: F401

__all__ = [
    "AnalysisResult",
    "Diagnostic",
    "CODES",
    "SEVERITIES",
    "analyze",
    "analyze_module",
    "determinism_certificate",
    "certificate_info",
    "needs_shuffle",
    "DETERMINISTIC",
    "REDUCTION_DETERMINISTIC",
    "RACY",
]


# ---------------------------------------------------------------------------
# provenance rendering
# ---------------------------------------------------------------------------


def attach_text_provenance(diags, src: str) -> List[Diagnostic]:
    """Render caret excerpts into ``.gt`` source text."""
    from ..core.program import _excerpt

    out = []
    for d in diags:
        loc = _excerpt(src, d.line, d.col) if d.line else ""
        out.append(d.with_location(loc) if loc else d)
    return out


def embedded_files(gp) -> Dict[str, str]:
    """kernel/func name -> defining Python file, from the builder's
    symbol table (every decorated function keeps its original ``fn``)."""
    files: Dict[str, str] = {}
    for name, handle in getattr(gp, "_symbols", {}).items():
        code = getattr(getattr(handle, "fn", None), "__code__", None)
        if code is not None:
            files[name] = code.co_filename
    return files


def attach_embedded_provenance(diags, gp) -> List[Diagnostic]:
    """Render ``file.py:lineno`` locations (FIR lines of embedded programs
    are absolute Python line numbers)."""
    files = embedded_files(gp)
    default = files.get("main") or next(iter(sorted(files.values())), "")
    out = []
    for d in diags:
        f = files.get(d.kernel or "", default)
        if d.line and f:
            out.append(d.with_location(f"{f}:{d.line}"))
        else:
            out.append(d.with_location(f) if f else d)
    return out


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------


def _front_end_diag(code: str, exc: Exception) -> Diagnostic:
    line = getattr(exc, "line", 0) or getattr(exc, "lineno", 0) or 0
    col = getattr(exc, "col", 0) or 0
    return make(code, str(exc), line=int(line), col=int(col))


def analyze(src_or_program, options=None, *, shape=None) -> AnalysisResult:
    """Statically analyze a program; never raises on a bad program.

    ``shape`` (a :class:`~repro.core.accelerator.GraphShape` or any object
    with ``n_edges``) additionally enables the dtype/overflow analyses
    (GT5xx). ``options`` selects the pass pipeline the analysis observes
    (fusion-merged kernels are analyzed in final form); ignored when a
    compiled ``Program`` is passed, which carries its own.
    """
    from ..core import mir, passes, semantic
    from ..core.lexer import LexError
    from ..core.options import CompileOptions
    from ..core.parser import ParseError, parse
    from ..core.program import Program

    if isinstance(src_or_program, Program):
        prog = src_or_program
        diags = analyze_module(prog.module, shape)
        diags = attach_text_provenance(diags, prog.source)
        return AnalysisResult(tuple(diags), determinism_certificate(prog.module),
                              prog.fingerprint)

    opts = options if options is not None else CompileOptions()
    embedded = not isinstance(src_or_program, str)
    if embedded and not hasattr(src_or_program, "to_fir"):
        raise TypeError(
            f"analyze() expects DSL source text, a GraphProgram, or a "
            f"compiled Program; got {type(src_or_program).__name__}"
        )

    def done(diags, module=None) -> AnalysisResult:
        cert = determinism_certificate(module) if module is not None else "unknown"
        if embedded:
            diags = attach_embedded_provenance(diags, src_or_program)
        else:
            diags = attach_text_provenance(diags, src_or_program)
        fp = mir.fingerprint(module) if module is not None else ""
        return AnalysisResult(tuple(diags), cert, fp)

    # front end (always fresh — provenance must match THIS input, not
    # whichever twin populated the shared module cache first)
    if embedded:
        from ..frontend.lowering import FrontendError

        try:
            fir_prog = src_or_program.to_fir()
        except FrontendError as e:
            return done([_front_end_diag("GT002", e)])
    else:
        try:
            fir_prog = parse(src_or_program)
        except LexError as e:
            return done([_front_end_diag("GT001", e)])
        except ParseError as e:
            return done([_front_end_diag("GT002", e)])
    try:
        module = semantic.analyze(fir_prog)
    except semantic.SemanticError as e:
        return done([_front_end_diag("GT003", e)])
    try:
        module = passes.run_pipeline(module, opts)
    except passes.PassError as e:
        return done([_front_end_diag("GT004", e)])
    return done(analyze_module(module, shape), module)
