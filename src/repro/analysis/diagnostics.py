"""Typed diagnostics for the MIR static-analysis framework.

Every finding :mod:`repro.analysis.analyses` emits is a :class:`Diagnostic`
with a **stable code** (the table below; golden-tested and documented in
ROADMAP.md), a severity, and provenance fields. Codes never change meaning
across releases — tooling may match on them.

==========  ========  ==============================================================
code        severity  meaning
==========  ========  ==============================================================
``GT001``   error     source does not lex
``GT002``   error     source does not parse
``GT003``   error     semantic analysis rejected the program
``GT004``   error     pass pipeline rejected the program/options
``GT101``   error     scatter-write race: per-edge plain ``=`` write whose value
                      varies per edge (not a commutative-associative reduction)
``GT102``   error     conflicting reduction operators on one scattered property
                      within a single (possibly fusion-merged) kernel
``GT201``   info      determinism certificate (deterministic /
                      reduction-deterministic / racy)
``GT202``   info      streaming-incremental eligibility verdict
``GT301``   warning   property read before any initialization (relies on
                      implicit zero-filled buffers)
``GT302``   warning   write-only property: written but never read by any kernel
                      or host statement
``GT401``   warning   ``while`` condition never updated inside the loop body
``GT402``   warning   frontier loop never updates the frontier properties
``GT501``   warning   int32 accumulator over an |E|-scaled sum may overflow at
                      the given :class:`~repro.core.accelerator.GraphShape`
``GT502``   error     |E| exceeds the int32 edge-index space of the CSR layout
==========  ========  ==============================================================

Suppression: analyses are advisory by default — ``repro.compile`` only
raises under ``strict=True`` and :meth:`GraphService.submit` only rejects
error-level findings. There is no per-line pragma; restructure the program
(use a ``min=``/``max=``/``+=`` reduction for scattered writes) or compile
non-strict to proceed past warnings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

#: severity levels, most severe first (sort key: index in this tuple)
SEVERITIES: Tuple[str, ...] = ("error", "warning", "info")

#: code -> (severity, one-line summary); the public registry of stable codes
CODES: Dict[str, Tuple[str, str]] = {
    "GT001": ("error", "source does not lex"),
    "GT002": ("error", "source does not parse"),
    "GT003": ("error", "semantic analysis rejected the program"),
    "GT004": ("error", "pass pipeline rejected the program/options"),
    "GT101": ("error", "scatter-write race (non-reduction per-edge write)"),
    "GT102": ("error", "conflicting reduce ops on one scattered property"),
    "GT201": ("info", "determinism certificate"),
    "GT202": ("info", "streaming-incremental eligibility"),
    "GT301": ("warning", "property read before initialization"),
    "GT302": ("warning", "write-only property (dead writes)"),
    "GT401": ("warning", "while condition never updated in loop body"),
    "GT402": ("warning", "frontier loop never updates the frontier"),
    "GT501": ("warning", "int32 accumulator may overflow at |E| scale"),
    "GT502": ("error", "|E| exceeds int32 edge-index space"),
}


@dataclass(frozen=True)
class Diagnostic:
    """One static-analysis finding, front-end independent.

    ``line``/``col`` are 1-based positions into whatever source the FIR
    was built from: ``.gt`` text for the text front-end, the decorated
    function's Python file for the embedded front-end (``col`` is then 0).
    ``location`` is the rendered provenance — a caret excerpt for text
    sources, ``file.py:lineno`` for embedded programs — attached by
    :func:`repro.analyze` / :meth:`Program.diagnostics`, which know which
    front-end authored the program.
    """

    code: str
    severity: str  # 'error' | 'warning' | 'info'
    message: str
    kernel: Optional[str] = None
    prop: Optional[str] = None
    line: int = 0
    col: int = 0
    location: str = field(default="", compare=False)

    def with_location(self, location: str) -> "Diagnostic":
        return dataclasses.replace(self, location=location)

    @property
    def sort_key(self):
        sev = SEVERITIES.index(self.severity) if self.severity in SEVERITIES else 99
        return (sev, self.code, self.line, self.col, self.message)

    def format(self) -> str:
        """One human-readable block: ``CODE severity: message`` + context."""
        ctx = []
        if self.kernel:
            ctx.append(f"kernel {self.kernel}")
        if self.prop:
            ctx.append(f"property {self.prop}")
        head = f"{self.code} {self.severity}: {self.message}"
        if ctx:
            head += f" [{', '.join(ctx)}]"
        if self.location:
            head += self.location if self.location.startswith("\n") \
                else f" ({self.location})"
        return head

    def to_dict(self) -> dict:
        """JSON-ready form (the ``repro.lint --json`` record shape)."""
        return {
            "code": self.code,
            "severity": self.severity,
            "message": self.message,
            "kernel": self.kernel,
            "prop": self.prop,
            "line": self.line,
            "col": self.col,
            "location": self.location,
        }


def make(code: str, message: str, *, kernel: Optional[str] = None,
         prop: Optional[str] = None, line: int = 0, col: int = 0) -> Diagnostic:
    """Build a Diagnostic with the severity registered for its code."""
    if code not in CODES:
        raise ValueError(f"unknown diagnostic code {code!r}")
    return Diagnostic(code=code, severity=CODES[code][0], message=message,
                      kernel=kernel, prop=prop, line=line, col=col)


@dataclass(frozen=True)
class AnalysisResult:
    """Everything :func:`repro.analyze` derives from one program.

    ``certificate`` is the determinism tier (``deterministic`` /
    ``reduction-deterministic`` / ``racy``) — the same string
    ``accelerator.report()`` and saved artifact manifests carry.
    """

    diagnostics: Tuple[Diagnostic, ...]
    certificate: str
    fingerprint: str = ""

    @property
    def errors(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == "error")

    @property
    def warnings(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == "warning")

    @property
    def infos(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == "info")

    @property
    def ok(self) -> bool:
        """No error-level findings (warnings and infos may remain)."""
        return not self.errors

    def codes(self) -> Tuple[str, ...]:
        """Sorted unique diagnostic codes (the front-end parity invariant)."""
        return tuple(sorted({d.code for d in self.diagnostics}))

    def render(self) -> str:
        lines = [d.format() for d in self.diagnostics]
        lines.append(
            f"{len(self.errors)} error(s), {len(self.warnings)} warning(s), "
            f"{len(self.infos)} info(s); determinism: {self.certificate}"
        )
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "fingerprint": self.fingerprint,
            "certificate": self.certificate,
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }
