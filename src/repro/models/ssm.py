"""Mamba2 (SSD) block — chunked matrix formulation (TPU-native).

The selective-state-space recurrence
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t ⊗ x_t ;  y_t = C_t · h_t + D x_t
is evaluated in the **chunked SSD form**: the sequence is split into chunks
of length ``Lc``; intra-chunk contributions become attention-like matmuls
(MXU work), inter-chunk state is carried by a short ``lax.scan`` over
chunks. This replaces the GPU kernel's warp-level scan with block matmuls —
the TPU adaptation of the recurrence (see DESIGN.md §2).

Decode keeps O(1) state: (conv ring buffer, SSM state [B, H, P, N]).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import _init_normal, rmsnorm

Params = Dict[str, Any]


def _dims(cfg: ArchConfig):
    d_in = cfg.d_model * cfg.ssm_expand
    n_heads = cfg.ssm_heads or max(1, d_in // 128)
    headdim = d_in // n_heads
    return d_in, n_heads, headdim, cfg.ssm_state


def mamba2_init(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    d_in, nh, hp, ns = _dims(cfg)
    conv_dim = d_in + 2 * ns
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    params = {
        # split projections keep every sharded dim cleanly divisible
        "w_z": _init_normal(ks[0], (d, d_in), s, dtype),
        "w_xbc": _init_normal(ks[3], (d, conv_dim), s, dtype),
        "w_dt": _init_normal(ks[1], (d, nh), s, jnp.float32),
        "conv_w": _init_normal(ks[1], (cfg.ssm_conv, conv_dim), 0.5, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "w_out": _init_normal(ks[2], (d_in, d), 1.0 / math.sqrt(d_in), dtype),
        "out_norm": jnp.ones((d_in,), dtype),
    }
    specs = {
        "w_z": ("embed", "mlp"),
        "w_xbc": ("embed", "mlp"),
        "w_dt": ("embed", None),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "a_log": (None,),
        "d_skip": (None,),
        "dt_bias": (None,),
        "w_out": ("mlp", "embed"),
        "out_norm": ("mlp",),
    }
    return params, specs


def _split_proj(cfg, p, x):
    return x @ p["w_z"], x @ p["w_xbc"], x @ p["w_dt"]


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along seq: xbc [B,S,C], w [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def mamba2_forward(
    p: Params, cfg: ArchConfig, x: jnp.ndarray, chunk: int = 256, unroll: bool = False
) -> jnp.ndarray:
    b, s, d = x.shape
    d_in, nh, hp, ns = _dims(cfg)
    z, xbc, dt = _split_proj(cfg, p, x)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_in].reshape(b, s, nh, hp)
    bmat = xbc[..., d_in : d_in + ns]  # [B,S,N]
    cmat = xbc[..., d_in + ns :]  # [B,S,N]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["a_log"])  # [H] negative decay rates

    lc = min(chunk, s)
    while s % lc:
        lc //= 2
    nc = s // lc
    # reshape into chunks
    xs_c = xs.reshape(b, nc, lc, nh, hp)
    b_c = bmat.reshape(b, nc, lc, ns)
    c_c = cmat.reshape(b, nc, lc, ns)
    dt_c = dt.reshape(b, nc, lc, nh)

    da = dt_c * a  # [B,nc,lc,H] log-decay per step
    cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative
    total = cum[:, :, -1, :]  # [B,nc,H]

    # intra-chunk (attention-like): L[i,j] = exp(cum_i - cum_j) for j<=i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,lc,lc,H]
    causal = jnp.tril(jnp.ones((lc, lc), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", c_c.astype(jnp.float32), b_c.astype(jnp.float32))
    w_ij = scores[..., None] * decay * dt_c[:, :, None, :, :]  # [B,nc,i,j,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w_ij, xs_c.astype(jnp.float32))

    # inter-chunk state carry (scan over chunks)
    # state contribution of chunk: sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)  # [B,nc,lc,H]
    bx = jnp.einsum(
        "bcjn,bcjhp->bcnhp",
        b_c.astype(jnp.float32),
        xs_c.astype(jnp.float32) * (dt_c * decay_to_end)[..., None],
    )  # [B,nc,N,H,P]

    def step(state, inputs):
        bx_c, tot_c = inputs  # [B,N,H,P], [B,H]
        new = state * jnp.exp(tot_c)[:, None, :, None] + bx_c
        return new, state  # emit the INCOMING state for this chunk

    init = jnp.zeros((b, ns, nh, hp), jnp.float32)
    scan_in = (jnp.moveaxis(bx, 1, 0), jnp.moveaxis(total, 1, 0))
    if unroll:
        st, outs = init, []
        for c in range(nc):
            st, emitted = step(st, jax.tree.map(lambda l: l[c], scan_in))
            outs.append(emitted)
        states_in = jnp.stack(outs)
    else:
        _, states_in = jax.lax.scan(step, init, scan_in)  # [nc,B,N,H,P]
    states_in = jnp.moveaxis(states_in, 0, 1)  # [B,nc,N,H,P]
    y_inter = jnp.einsum(
        "bcin,bcnhp->bcihp", c_c.astype(jnp.float32), states_in
    ) * jnp.exp(cum)[..., None]  # decay from chunk start to i

    y = (y_intra + y_inter).reshape(b, s, nh, hp)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["w_out"]


# -- O(1) decode -------------------------------------------------------------


def mamba2_init_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    d_in, nh, hp, ns = _dims(cfg)
    conv_dim = d_in + 2 * ns
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, ns, nh, hp), jnp.float32),
    }


def mamba2_decode(
    p: Params, cfg: ArchConfig, cache: Params, x: jnp.ndarray
) -> Tuple[jnp.ndarray, Params]:
    b, s, d = x.shape  # s == 1
    d_in, nh, hp, ns = _dims(cfg)
    z, xbc, dt = _split_proj(cfg, p, x)
    # conv ring: shift in the new frame
    frames = jnp.concatenate([cache["conv"], xbc], axis=1)  # [B,K,C]
    conv_out = jnp.einsum("bkc,kc->bc", frames, p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    xs = xbc1[..., :d_in].reshape(b, nh, hp)
    bvec = xbc1[:, 0, d_in : d_in + ns]
    cvec = xbc1[:, 0, d_in + ns :]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt1 * a)  # [B,H]
    upd = jnp.einsum(
        "bn,bhp->bnhp", bvec.astype(jnp.float32), xs.astype(jnp.float32) * dt1[..., None]
    )
    state = cache["state"] * decay[:, None, :, None] + upd
    y = jnp.einsum("bn,bnhp->bhp", cvec.astype(jnp.float32), state)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["w_out"], {"conv": frames[:, 1:], "state": state}
