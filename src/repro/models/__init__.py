from .model import Model
from . import attention, layers, moe, ssm, xlstm

__all__ = ["Model", "attention", "layers", "moe", "ssm", "xlstm"]
