"""Attention variants: GQA/MQA (+qk-norm, sliding window, M-RoPE) and MLA.

All variants support three execution modes:
* ``forward``  — full-sequence training/prefill (causal or bidirectional);
* ``decode``   — single-token step against a KV cache;
* sliding-window decode uses a **ring-buffer cache** of size ``window`` so
  long_500k decode holds O(window) state, not O(L).

MLA (deepseek-v2) caches the *compressed* latent (kv_lora + rope head) and
supports the **absorbed decode** optimization (projection absorption into
the query) as a toggle — the paper-faithful baseline decompresses per step.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import (
    _init_normal,
    apply_mrope,
    apply_rope,
    head_rmsnorm,
    mrope_sections,
    rmsnorm,
    shd,
)

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------


def gqa_init(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    params = {
        "wq": _init_normal(ks[0], (d, h * hd), s, dtype),
        "wk": _init_normal(ks[1], (d, hkv * hd), s, dtype),
        "wv": _init_normal(ks[2], (d, hkv * hd), s, dtype),
        "wo": _init_normal(ks[3], (h * hd, d), 1.0 / math.sqrt(h * hd), dtype),
    }
    specs = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "heads"),
        "wv": ("embed", "heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((hd,), dtype)
        params["k_norm"] = jnp.ones((hd,), dtype)
        specs["q_norm"] = ("head_dim",)
        specs["k_norm"] = ("head_dim",)
    return params, specs


def _mask_bias(
    q_pos: jnp.ndarray,  # [Lq]
    k_pos: jnp.ndarray,  # [Lk]
    causal: bool,
    window: int,
    valid_k: Optional[jnp.ndarray] = None,  # [B, Lk] cache validity
) -> jnp.ndarray:
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > q_pos[:, None] - window
    bias = jnp.where(m, 0.0, -jnp.inf)[None, None]  # [1,1,Lq,Lk]
    if valid_k is not None:
        bias = bias + jnp.where(valid_k, 0.0, -jnp.inf)[:, None, None, :]
    return bias


SCORES_DTYPE = jnp.float32  # perf-loop toggle: bf16 halves score traffic


def _sdpa(q, k, v, bias):
    """q:[B,Lq,H,Dh] k/v:[B,Lk,Hkv,Dh] -> [B,Lq,H,Dh].

    Scores are stored in SCORES_DTYPE (f32 default; the perf loop flips to
    bf16 — the MXU accumulates in f32 either way, and the softmax
    normalization below always reduces in f32)."""
    b, lq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    sd = SCORES_DTYPE
    qf = q.reshape(b, lq, hkv, g, dh).astype(sd)
    kf = k.astype(sd)
    logits = jnp.einsum("bqkgd,btkd->bkgqt", qf, kf,
                        preferred_element_type=sd) / jnp.asarray(math.sqrt(dh), sd)
    logits = logits + bias.reshape(
        b if bias.shape[0] > 1 else 1, 1, 1, *bias.shape[-2:]
    ).astype(sd)
    m = jnp.max(logits.astype(jnp.float32), axis=-1, keepdims=True)
    e = jnp.exp(logits.astype(jnp.float32) - m).astype(sd)
    p = e / jnp.maximum(jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True), 1e-30).astype(sd)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(sd),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, lq, h, dh).astype(q.dtype)


def _sdpa_chunked(q, k, v, pos1d, causal, window, chunk: int, unroll: bool):
    """Query-chunked attention: scores are materialized per q-chunk
    ([B, H, c, Lk] instead of [B, H, Lq, Lk]) — an Lq/c reduction in the
    attention working set. The Pallas kernel (kernels/flash_attention.py)
    is the fully-blocked TPU-native version; this path is the
    GSPMD-compatible lowering the perf loop toggles on."""
    b, lq, h, dh = q.shape
    nc = max(1, lq // chunk)
    while lq % nc:
        nc -= 1
    c = lq // nc
    qc = q.reshape(b, nc, c, h, dh)
    kpos = pos1d[0]

    def one(qi, i):
        qpos = jax.lax.dynamic_slice_in_dim(kpos, i * c, c)
        bias = _mask_bias(qpos, kpos, causal, window)
        return _sdpa(qi, k, v, bias)

    if unroll:
        outs = [one(qc[:, i], i) for i in range(nc)]
        out = jnp.stack(outs, axis=1)
    else:
        out = jax.lax.map(lambda iq: one(iq[1], iq[0]),
                          (jnp.arange(nc), jnp.moveaxis(qc, 1, 0)))
        out = jnp.moveaxis(out, 0, 1)
    return out.reshape(b, lq, h, dh)


def gqa_forward(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # [B, S, D]
    positions: jnp.ndarray,  # [B, S] or [3, B, S] for mrope
    layer_window: int = -1,  # -1: use cfg.sliding_window
    attn_impl: str = "naive",  # 'naive' | 'chunked'
    chunk: int = 2048,
    unroll: bool = False,
    seq_parallel: bool = False,
) -> jnp.ndarray:
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if seq_parallel:
        # sequence-parallel attention (perf loop): shard q along SEQ over
        # the model axis; k/v are gathered once per layer. Avoids the
        # resharding ping-pong when n_heads doesn't divide the model axis.
        q = shd(q, "batch", "seq", None, None)
        k = shd(k, "batch", None, None, None)
        v = shd(v, "batch", None, None, None)
    else:
        q = shd(q, "batch", None, "heads", None)
        k = shd(k, "batch", None, "kv_heads", None)
        v = shd(v, "batch", None, "kv_heads", None)
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = head_rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, mrope_sections(hd))
        k = apply_mrope(k, positions, cfg.rope_theta, mrope_sections(hd))
        pos1d = positions[0]
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        pos1d = positions
    window = cfg.sliding_window if layer_window < 0 else layer_window
    if attn_impl == "chunked" and s > chunk:
        out = _sdpa_chunked(q, k, v, pos1d, cfg.causal, window, chunk, unroll)
    else:
        bias = _mask_bias(pos1d[0], pos1d[0], cfg.causal, window)
        out = _sdpa(q, k, v, bias)
    if seq_parallel:
        out = shd(out, "batch", "seq", None, None)
    else:
        out = shd(out, "batch", None, "heads", None)
    return out.reshape(b, s, h * hd) @ p["wo"]


# -- decode with (ring-)buffered KV cache ----------------------------------


def gqa_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    buf = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, buf, hkv, hd), dtype),
        "v": jnp.zeros((batch, buf, hkv, hd), dtype),
    }


def gqa_decode(
    p: Params,
    cfg: ArchConfig,
    cache: Params,
    x: jnp.ndarray,  # [B, 1, D]
    pos: jnp.ndarray,  # scalar int32: index of this token
    layer_window: int = -1,
    batch_parallel: bool = False,
) -> Tuple[jnp.ndarray, Params]:
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    k = (x @ p["wk"]).reshape(b, 1, hkv, hd)
    v = (x @ p["wv"]).reshape(b, 1, hkv, hd)
    if batch_parallel:
        # decode-sharding optimization: attention runs entirely within the
        # batch shard — gather the (tiny) q/k/v activations over the model
        # axis instead of gathering the (huge) KV cache per step
        q = shd(q, "batch", None, None, None)
        k = shd(k, "batch", None, None, None)
        v = shd(v, "batch", None, None, None)
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = head_rmsnorm(k, p["k_norm"], cfg.norm_eps)
    posb = jnp.full((b, 1), pos, jnp.int32)
    if cfg.mrope:
        pos3 = jnp.broadcast_to(posb[None], (3, b, 1))
        q = apply_mrope(q, pos3, cfg.rope_theta, mrope_sections(hd))
        k = apply_mrope(k, pos3, cfg.rope_theta, mrope_sections(hd))
    else:
        q = apply_rope(q, posb, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_theta)
    buf = cache["k"].shape[1]
    slot = pos % buf if cfg.sliding_window > 0 else jnp.minimum(pos, buf - 1)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    # absolute position of each cache slot
    if cfg.sliding_window > 0:
        # ring buffer: slot i holds the latest position congruent to i
        slots = jnp.arange(buf)
        abs_pos = pos - ((pos - slots) % buf)
        valid = (abs_pos >= 0) & (abs_pos <= pos)
    else:
        abs_pos = jnp.arange(buf)
        valid = abs_pos <= pos
    window = cfg.sliding_window if layer_window < 0 else layer_window
    bias = _mask_bias(posb[0], abs_pos, cfg.causal, window, valid[None].repeat(b, 0))
    out = _sdpa(q, ck, cv, bias)
    if batch_parallel:
        out = shd(out, "batch", None, None, None)
    out = out.reshape(b, 1, h * hd) @ p["wo"]
    return out, {"k": ck, "v": cv}


# --------------------------------------------------------------------------
# MLA (deepseek-v2)
# --------------------------------------------------------------------------


def mla_init(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    d, h = cfg.d_model, cfg.n_heads
    hd, rhd, vhd = cfg.resolved_head_dim, cfg.rope_head_dim, cfg.resolved_v_head_dim
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    params = {
        "wq_a": _init_normal(ks[0], (d, qlr), s, dtype),
        "q_a_norm": jnp.ones((qlr,), dtype),
        "wq_b": _init_normal(ks[1], (qlr, h * (hd + rhd)), 1.0 / math.sqrt(qlr), dtype),
        "wkv_a": _init_normal(ks[2], (d, kvlr + rhd), s, dtype),
        "kv_a_norm": jnp.ones((kvlr,), dtype),
        "wkv_b": _init_normal(ks[3], (kvlr, h * (hd + vhd)), 1.0 / math.sqrt(kvlr), dtype),
        "wo": _init_normal(ks[4], (h * vhd, d), 1.0 / math.sqrt(h * vhd), dtype),
    }
    specs = {
        "wq_a": ("embed", "qlora"),
        "q_a_norm": ("qlora",),
        "wq_b": ("qlora", "heads"),
        "wkv_a": ("embed", "kvlora"),
        "kv_a_norm": ("kvlora",),
        "wkv_b": ("kvlora", "heads"),
        "wo": ("heads", "embed"),
    }
    return params, specs


def _mla_qkv(p, cfg: ArchConfig, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    hd, rhd, vhd = cfg.resolved_head_dim, cfg.rope_head_dim, cfg.resolved_v_head_dim
    q = rmsnorm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(b, s, h, hd + rhd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"]  # [B,S,kvlr+rhd]
    c_kv = rmsnorm(kv[..., : cfg.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, cfg.kv_lora_rank :], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope  # k_rope: [B,S,1,rhd]


def mla_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    b, s, _ = x.shape
    h = cfg.n_heads
    hd, rhd, vhd = cfg.resolved_head_dim, cfg.rope_head_dim, cfg.resolved_v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    kvb = p["wkv_b"].reshape(cfg.kv_lora_rank, h, hd + vhd)
    k_nope = jnp.einsum("bsc,chd->bshd", c_kv, kvb[..., :hd])
    v = jnp.einsum("bsc,chd->bshd", c_kv, kvb[..., hd:])
    scale = 1.0 / math.sqrt(hd + rhd)
    logits = (
        jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
        + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                     k_rope[:, :, 0].astype(jnp.float32))
    ) * scale
    qp = positions[0]
    bias = _mask_bias(qp, qp, cfg.causal, 0)
    logits = logits + bias[0]
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, v.astype(jnp.float32)).astype(x.dtype)
    return out.reshape(b, s, h * vhd) @ p["wo"]


def mla_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
    }


def mla_decode(
    p: Params,
    cfg: ArchConfig,
    cache: Params,
    x: jnp.ndarray,  # [B, 1, D]
    pos: jnp.ndarray,
    absorb: bool = True,
    batch_parallel: bool = False,
) -> Tuple[jnp.ndarray, Params]:
    """MLA decode against the compressed cache.

    absorb=False (paper-faithful baseline): decompress the whole cache to
    per-head K/V each step — O(S·h·(hd+vhd)) bytes materialized.
    absorb=True (optimized): fold wkv_b into the query / output so scores
    are taken directly against the latent — O(S·kvlr) bytes touched.
    """
    b = x.shape[0]
    h = cfg.n_heads
    hd, rhd, vhd = cfg.resolved_head_dim, cfg.rope_head_dim, cfg.resolved_v_head_dim
    posb = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, posb)
    if batch_parallel:
        # same decode-sharding optimization as gqa_decode: keep the latent
        # cache batch-local; gather only the per-step activations
        q_nope = shd(q_nope, "batch", None, None, None)
        q_rope = shd(q_rope, "batch", None, None, None)
        c_kv = shd(c_kv, "batch", None, None)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_kv, (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], k_rope[:, :, 0], (0, pos, 0))
    buf = ckv.shape[1]
    valid = jnp.arange(buf) <= pos
    scale = 1.0 / math.sqrt(hd + rhd)
    kvb = p["wkv_b"].reshape(cfg.kv_lora_rank, h, hd + vhd)
    if absorb:
        # score side: q_eff = q_nope @ Wk  -> against latent directly
        q_eff = jnp.einsum("bqhd,chd->bqhc", q_nope.astype(jnp.float32),
                           kvb[..., :hd].astype(jnp.float32))
        logits = jnp.einsum("bqhc,bkc->bhqk", q_eff, ckv.astype(jnp.float32))
    else:
        k_nope = jnp.einsum("bkc,chd->bkhd", ckv.astype(jnp.float32),
                            kvb[..., :hd].astype(jnp.float32))
        logits = jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32), k_nope)
    logits = logits + jnp.einsum(
        "bqhd,bkd->bhqk", q_rope.astype(jnp.float32), krope.astype(jnp.float32)
    )
    logits = logits * scale
    logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
    att = jax.nn.softmax(logits, axis=-1)  # [B,h,1,S]
    if absorb:
        ctx = jnp.einsum("bhqk,bkc->bqhc", att, ckv.astype(jnp.float32))  # latent ctx
        out = jnp.einsum("bqhc,chd->bqhd", ctx, kvb[..., hd:].astype(jnp.float32))
    else:
        v = jnp.einsum("bkc,chd->bkhd", ckv.astype(jnp.float32),
                       kvb[..., hd:].astype(jnp.float32))
        out = jnp.einsum("bhqk,bkhd->bqhd", att, v)
    out = out.astype(x.dtype).reshape(b, 1, h * vhd) @ p["wo"]
    return out, {"ckv": ckv, "krope": krope}
