"""Shared layer primitives: norms, MLPs, embeddings, RoPE/M-RoPE.

Pure-JAX (no flax): params are plain pytrees; every init function returns
(params, logical_axes) mirrored trees so the distributed layer can derive
PartitionSpecs without name-matching heuristics.

Logical axis names (resolved to mesh axes by distributed.sharding):
    batch, seq, embed, mlp, heads, kv_heads, head_dim, vocab, experts,
    layers, conv, state, qlora, kvlora
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]

_SHARDING_RULES: Optional[Dict[str, Any]] = None
_MESH_SIZES: Dict[str, int] = {}


def set_sharding_rules(rules: Optional[Dict[str, Any]], mesh_sizes: Optional[Dict[str, int]] = None):
    """Install logical->mesh axis rules (None disables constraints)."""
    global _SHARDING_RULES, _MESH_SIZES
    _SHARDING_RULES = rules
    _MESH_SIZES = mesh_sizes or {}


def _axes_size(axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return _MESH_SIZES.get(axes, 1)
    n = 1
    for a in axes:
        n *= _MESH_SIZES.get(a, 1)
    return n


def shd(x: jnp.ndarray, *axes: Optional[str]) -> jnp.ndarray:
    """Constrain activation sharding by logical axis names. Axes that do
    not evenly divide the dim are dropped (no uneven-sharding remat); no-op
    outside a mesh context."""
    if _SHARDING_RULES is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = []
    used: set = set()
    for dim, a in zip(x.shape, axes):
        ma = _SHARDING_RULES.get(a) if a else None
        if ma is not None:
            flat = (ma,) if isinstance(ma, str) else tuple(ma)
            if any(m in used for m in flat) or dim % _axes_size(ma) != 0:
                ma = None
            else:
                used.update(flat)
        spec.append(ma)
    return jax.lax.with_sharding_constraint(x, P(*spec))


_ABSTRACT_INIT = False


class abstract_init:
    """Context manager: param initializers return ShapeDtypeStructs.

    Used by the dry-run — trillion-parameter models are never materialized
    on the host; ``jax.jit(...).lower()`` only needs shapes."""

    def __enter__(self):
        global _ABSTRACT_INIT
        self._prev = _ABSTRACT_INIT
        _ABSTRACT_INIT = True

    def __exit__(self, *exc):
        global _ABSTRACT_INIT
        _ABSTRACT_INIT = self._prev


def _init_normal(key, shape, scale: float, dtype) -> jnp.ndarray:
    if _ABSTRACT_INIT:
        return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.bfloat16):
    return jnp.ones((d,), dtype), ("embed",)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def head_rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """qk-norm: rmsnorm over the trailing head_dim."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


# --------------------------------------------------------------------------
# MLP (gated SwiGLU or plain GELU)
# --------------------------------------------------------------------------


def mlp_init(key, d: int, ff: int, gated: bool, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(ff)
    if gated:
        params = {
            "wi": _init_normal(ks[0], (d, ff), scale_in, dtype),
            "wg": _init_normal(ks[1], (d, ff), scale_in, dtype),
            "wo": _init_normal(ks[2], (ff, d), scale_out, dtype),
        }
        specs = {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"), "wo": ("mlp", "embed")}
    else:
        params = {
            "wi": _init_normal(ks[0], (d, ff), scale_in, dtype),
            "wo": _init_normal(ks[2], (ff, d), scale_out, dtype),
        }
        specs = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    return params, specs


def mlp_apply(p: Params, x: jnp.ndarray, gated: bool) -> jnp.ndarray:
    h = x @ p["wi"]
    if gated:
        h = jax.nn.silu(x @ p["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    h = shd(h, "batch", None, "mlp")
    return h @ p["wo"]


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return _init_normal(key, (vocab, d), 1.0 / math.sqrt(d), dtype), ("vocab", "embed")


def embed_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Embedding lookup.

    Under SPMD (sharding rules installed) the lookup is a one-hot matmul:
    with a (vocab x embed)-sharded table, gather/scatter-add would
    materialize a replicated f32 gradient of the full table; the one-hot
    contraction keeps both the forward and the backward as fully-sharded
    matmuls (standard TPU practice)."""
    if _SHARDING_RULES is not None:
        onehot = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
        onehot = shd(onehot, "batch", None, "vocab")
        return onehot @ table
    return table[ids]


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray,  # [B, S, H, Dh]
    positions: jnp.ndarray,  # [B, S]
    theta: float,
) -> jnp.ndarray:
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, Dh/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,  # [B, S, H, Dh]
    positions: jnp.ndarray,  # [3, B, S] (t, h, w) position ids
    theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: rotary halves split into (t, h, w) sections."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, "mrope sections must cover head_dim/2"
    freqs = rope_freqs(dh, theta)  # [half]
    # per-frequency position source: section 0 -> t, 1 -> h, 2 -> w
    sec_id = np.concatenate(
        [np.full(s, i, np.int32) for i, s in enumerate(sections)]
    )  # [half]
    pos = positions.astype(jnp.float32)  # [3, B, S]
    pos_per_freq = pos[sec_id]  # [half, B, S]
    angles = jnp.moveaxis(pos_per_freq, 0, -1) * freqs  # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """Default Qwen2-VL sections scaled to head_dim (16/24/24 at Dh=128)."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return (t, h, w)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy in f32; labels < 0 are masked out."""
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
