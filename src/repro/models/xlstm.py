"""xLSTM blocks: mLSTM (matrix memory, parallel train form) + sLSTM.

mLSTM trains in its parallel (attention-like) form with stabilized
exponential gating; decode is the O(1) recurrent form with per-head matrix
memory C [Dh, Dh] and normalizer n [Dh]. sLSTM is a true scalar recurrence
(lax.scan over time) placed every ``slstm_every``-th layer.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import _init_normal, rmsnorm

Params = Dict[str, Any]


def _dims(cfg: ArchConfig):
    d_in = cfg.d_model * cfg.ssm_expand
    nh = cfg.n_heads
    hd = d_in // nh
    return d_in, nh, hd


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def mlstm_init(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    d_in, nh, hd = _dims(cfg)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    params = {
        "wq": _init_normal(ks[0], (d, d_in), s, dtype),
        "wk": _init_normal(ks[1], (d, d_in), s, dtype),
        "wv": _init_normal(ks[2], (d, d_in), s, dtype),
        "wif": _init_normal(ks[3], (d, 2 * nh), s, jnp.float32),  # i,f gate logits
        "wo_gate": _init_normal(ks[4], (d, d_in), s, dtype),
        "w_out": _init_normal(ks[5], (d_in, d), 1.0 / math.sqrt(d_in), dtype),
        "out_norm": jnp.ones((d_in,), dtype),
    }
    specs = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "heads"),
        "wv": ("embed", "heads"),
        "wif": ("embed", None),
        "wo_gate": ("embed", "heads"),
        "w_out": ("heads", "embed"),
        "out_norm": ("heads",),
    }
    return params, specs


def mlstm_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Parallel (training) form with log-space stabilization."""
    b, s, d = x.shape
    d_in, nh, hd = _dims(cfg)
    q = (x @ p["wq"]).reshape(b, s, nh, hd)
    k = (x @ p["wk"]).reshape(b, s, nh, hd)
    v = (x @ p["wv"]).reshape(b, s, nh, hd)
    gates = (x.astype(jnp.float32) @ p["wif"]).reshape(b, s, nh, 2)
    log_i = -jax.nn.softplus(-gates[..., 0])  # log sigmoid-ish input gate
    log_f = -jax.nn.softplus(-gates[..., 1])  # log forget gate in (-inf, 0)
    logcum_f = jnp.cumsum(log_f, axis=1)  # [B,S,H]
    # D_ij = exp(logcum_f_i - logcum_f_j + log_i_j) for j <= i
    dmat = logcum_f[:, :, None, :] - logcum_f[:, None, :, :] + log_i[:, None, :, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)  # [B,S,1,H] row stabilizer
    dstab = jnp.exp(dmat - m)
    scores = jnp.einsum(
        "bihd,bjhd->bijh", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(hd)
    w = scores * dstab  # [B,S,S,H]
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2, keepdims=True)), jnp.exp(-m))
    w = w / norm
    h = jnp.einsum("bijh,bjhd->bihd", w, v.astype(jnp.float32)).astype(x.dtype)
    h = h.reshape(b, s, d_in)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    h = h * jax.nn.silu(x @ p["wo_gate"])
    return h @ p["w_out"]


def mlstm_init_cache(cfg: ArchConfig, batch: int):
    d_in, nh, hd = _dims(cfg)
    return {
        "c": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def mlstm_decode(p: Params, cfg: ArchConfig, cache: Params, x: jnp.ndarray):
    b, s, d = x.shape  # s == 1
    d_in, nh, hd = _dims(cfg)
    q = (x @ p["wq"]).reshape(b, nh, hd).astype(jnp.float32)
    k = (x @ p["wk"]).reshape(b, nh, hd).astype(jnp.float32)
    v = (x @ p["wv"]).reshape(b, nh, hd).astype(jnp.float32)
    gates = (x.astype(jnp.float32) @ p["wif"]).reshape(b, nh, 2)
    log_i = -jax.nn.softplus(-gates[..., 0])
    log_f = -jax.nn.softplus(-gates[..., 1])
    m_new = jnp.maximum(log_f + cache["m"], log_i)  # [B,H]
    f_sc = jnp.exp(log_f + cache["m"] - m_new)[..., None]
    i_sc = jnp.exp(log_i - m_new)[..., None]
    c = cache["c"] * f_sc[..., None] + i_sc[..., None] * (k[..., :, None] * v[..., None, :])
    n = cache["n"] * f_sc + i_sc * k
    qs = q / math.sqrt(hd)
    num = jnp.einsum("bhd,bhde->bhe", qs, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, 1, d_in).astype(x.dtype)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    h = h * jax.nn.silu(x @ p["wo_gate"])
    return h @ p["w_out"], {"c": c, "n": n, "m": m_new}


# --------------------------------------------------------------------------
# sLSTM (scalar memory, true recurrence)
# --------------------------------------------------------------------------


def slstm_init(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    d_in, nh, hd = _dims(cfg)
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    params = {
        # input projections for (z, i, f, o) gates
        "w_x": _init_normal(ks[0], (d, 4 * d_in), s, dtype),
        # block-diagonal recurrent weights per head: [H, hd, 4*hd]
        "w_h": _init_normal(ks[1], (nh, hd, 4 * hd), 1.0 / math.sqrt(hd), jnp.float32),
        "w_out": _init_normal(ks[2], (d_in, d), 1.0 / math.sqrt(d_in), dtype),
        "out_norm": jnp.ones((d_in,), dtype),
    }
    specs = {
        "w_x": ("embed", "heads"),
        "w_h": (None, "head_dim", "heads"),
        "w_out": ("heads", "embed"),
        "out_norm": ("heads",),
    }
    return params, specs


def slstm_init_cache(cfg: ArchConfig, batch: int):
    d_in, nh, hd = _dims(cfg)
    z = lambda: jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}


def _slstm_cell(p, cfg, carry, xt):
    """One sLSTM step. xt: [B, 4*d_in] pre-projected input contributions."""
    d_in, nh, hd = _dims(cfg)
    c, n, h, m = carry["c"], carry["n"], carry["h"], carry["m"]
    rec = jnp.einsum("bhd,hde->bhe", h, p["w_h"]).reshape(-1, nh, 4, hd)
    pre = xt.astype(jnp.float32).reshape(-1, nh, 4, hd) + rec.reshape(-1, nh, 4, hd)
    z_t = jnp.tanh(pre[:, :, 0])
    i_log = pre[:, :, 1]
    f_log = -jax.nn.softplus(-pre[:, :, 2])  # log sigmoid
    o_t = jax.nn.sigmoid(pre[:, :, 3])
    m_new = jnp.maximum(f_log + m, i_log)
    i_sc = jnp.exp(i_log - m_new)
    f_sc = jnp.exp(f_log + m - m_new)
    c_new = f_sc * c + i_sc * z_t
    n_new = jnp.maximum(f_sc * n + i_sc, jnp.exp(-m_new))
    h_new = o_t * c_new / n_new
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    b, s, d = x.shape
    d_in, nh, hd = _dims(cfg)
    xin = x @ p["w_x"]  # [B,S,4*d_in]

    def step(carry, xt):
        new = _slstm_cell(p, cfg, carry, xt)
        return new, new["h"]

    init = slstm_init_cache(cfg, b)
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(xin, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d_in).astype(x.dtype)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    return h @ p["w_out"]


def slstm_decode(p: Params, cfg: ArchConfig, cache: Params, x: jnp.ndarray):
    b = x.shape[0]
    d_in, nh, hd = _dims(cfg)
    xin = (x @ p["w_x"])[:, 0]
    new = _slstm_cell(p, cfg, cache, xin)
    h = new["h"].reshape(b, 1, d_in).astype(x.dtype)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    return h @ p["w_out"], new
