"""Model assembly: all ten assigned architectures behind one interface.

Structure per family (scan-over-stacked-layers keeps HLO compact and the
layer collectives pipelined):

* dense / vlm / audio: ``scan`` over N identical (attn + mlp) blocks;
* moe (deepseek-v2 / kimi-k2): first ``first_dense_layers`` unstacked dense
  blocks, then ``scan`` over MoE blocks (shuffle-dispatch experts);
* hybrid (zamba2): ``scan`` over groups of ``attn_every`` Mamba2 blocks,
  each group followed by the ONE weight-shared attention block (Zamba's
  signature trick) — per-group KV caches, shared weights;
* ssm (xlstm): ``scan`` over groups of (slstm_every-1) mLSTM + 1 sLSTM.

The public surface:
    init(key) / abstract_params()         params (real / ShapeDtypeStruct)
    param_specs()                         logical-axis tree for sharding
    loss(params, batch)                   training loss + metrics
    forward(params, batch)                logits (prefill/encoder path)
    init_cache(batch, max_len)            decode cache pytree
    decode_step(params, cache, token,pos) one-token serve step
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import (
    _init_normal,
    abstract_init,
    embed_init,
    embed_lookup,
    mlp_apply,
    mlp_init,
    rmsnorm,
    shd,
    softmax_xent,
)

Params = Dict[str, Any]


def _sds(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def _stack_template(template, n: int, abstract: bool, key=None, rebuild=None):
    """Stack single-layer params along a new leading 'layers' axis.

    abstract: template leaves -> ShapeDtypeStruct with (n, ...) shape.
    real: re-run the per-layer initializer ``rebuild(key_i)`` n times and
    jnp.stack (smoke-test sizes only)."""
    if abstract:
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((n,) + tuple(l.shape), l.dtype), template
        )
    keys = jax.random.split(key, n)
    per_layer = [rebuild(keys[i]) for i in range(n)]
    return jax.tree.map(lambda *ls: jnp.stack(ls), *per_layer)


class Model:
    def __init__(self, cfg: ArchConfig, dtype=jnp.bfloat16, unroll: bool = False,
                 remat: bool = False, attn_impl: str = "naive",
                 decode_batch_parallel: bool = False, attn_seq_parallel: bool = False):
        self.cfg = cfg
        self.dtype = dtype
        # perf-loop toggles (EXPERIMENTS.md §Perf):
        #   attn_impl='chunked'      — query-chunked attention (HBM term)
        #   decode_batch_parallel    — batch-local decode attention (ICI term)
        self.attn_impl = attn_impl
        self.decode_batch_parallel = decode_batch_parallel
        self.attn_seq_parallel = attn_seq_parallel
        # 2D activation sharding: residual stream carries (batch, seq)
        self._seq_ax = "seq" if attn_seq_parallel else None
        # unroll=True replaces scan-over-layers with a python loop so the
        # compiled HLO exposes every layer to cost_analysis (used by the
        # roofline lowering; production/training uses scan for compact HLO)
        self.unroll = unroll
        # remat=True checkpoints each layer-unit: backward recomputes the
        # layer instead of saving its intermediates (activation memory is
        # O(layers * d_model) carries instead of O(layers * everything))
        self.remat = remat

    def _maybe_scan(self, body, x, xs):
        if self.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        if not self.unroll:
            return jax.lax.scan(body, x, xs)
        length = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(length):
            x, y = body(x, jax.tree.map(lambda l: l[i], xs))
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        else:
            ys = None
        return x, ys

    # ------------------------------------------------------------------
    # parameter construction
    # ------------------------------------------------------------------
    def _dense_block_init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        a, a_specs = (
            attn.mla_init(ks[0], cfg, self.dtype)
            if cfg.mla
            else attn.gqa_init(ks[0], cfg, self.dtype)
        )
        m, m_specs = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, self.dtype)
        p = {"ln1": jnp.ones((cfg.d_model,), self.dtype), "attn": a,
             "ln2": jnp.ones((cfg.d_model,), self.dtype), "mlp": m}
        s = {"ln1": ("embed",), "attn": a_specs, "ln2": ("embed",), "mlp": m_specs}
        return p, s

    def _moe_block_init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        a, a_specs = (
            attn.mla_init(ks[0], cfg, self.dtype)
            if cfg.mla
            else attn.gqa_init(ks[0], cfg, self.dtype)
        )
        m, m_specs = moe_mod.moe_init(ks[1], cfg, self.dtype)
        p = {"ln1": jnp.ones((cfg.d_model,), self.dtype), "attn": a,
             "ln2": jnp.ones((cfg.d_model,), self.dtype), "moe": m}
        s = {"ln1": ("embed",), "attn": a_specs, "ln2": ("embed",), "moe": m_specs}
        return p, s

    def _mamba_block_init(self, key):
        cfg = self.cfg
        m, m_specs = ssm_mod.mamba2_init(key, cfg, self.dtype)
        p = {"ln1": jnp.ones((cfg.d_model,), self.dtype), "mamba": m}
        s = {"ln1": ("embed",), "mamba": m_specs}
        return p, s

    def _mlstm_block_init(self, key):
        cfg = self.cfg
        m, m_specs = xlstm_mod.mlstm_init(key, cfg, self.dtype)
        return ({"ln1": jnp.ones((cfg.d_model,), self.dtype), "mlstm": m},
                {"ln1": ("embed",), "mlstm": m_specs})

    def _slstm_block_init(self, key):
        cfg = self.cfg
        m, m_specs = xlstm_mod.slstm_init(key, cfg, self.dtype)
        return ({"ln1": jnp.ones((cfg.d_model,), self.dtype), "slstm": m},
                {"ln1": ("embed",), "slstm": m_specs})

    def init_with_specs(self, key, abstract: bool = False):
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        ctx = abstract_init() if abstract else _nullctx()
        with ctx:
            emb, emb_spec = embed_init(ks[0], cfg.vocab_size, cfg.d_model, self.dtype)
            params: Params = {"embed": emb, "final_norm": jnp.ones((cfg.d_model,), self.dtype)}
            specs: Params = {"embed": emb_spec, "final_norm": ("embed",)}
            if not cfg.tie_embeddings:
                params["lm_head"] = _init_normal(
                    ks[1], (cfg.d_model, cfg.vocab_size), 1.0 / math.sqrt(cfg.d_model),
                    self.dtype,
                )
                specs["lm_head"] = ("embed", "vocab")
            if cfg.frontend != "none":
                params["frontend_proj"] = _init_normal(
                    ks[2], (cfg.d_model, cfg.d_model), 1.0 / math.sqrt(cfg.d_model),
                    self.dtype,
                )
                specs["frontend_proj"] = ("embed", "embed2")

            if cfg.xlstm:
                g, rem = self._xlstm_groups()
                t_m, s_m = self._mlstm_block_init(ks[3])
                tmpl_m = jax.tree.map(_sds, t_m)
                # group stacks: [G, rem, ...]
                if abstract:
                    params["mlstm_groups"] = jax.tree.map(
                        lambda l: jax.ShapeDtypeStruct((g, rem) + tuple(l.shape), l.dtype),
                        tmpl_m,
                    )
                else:
                    def rebuild_group(k):
                        return _stack_template(tmpl_m, rem, False, k,
                                               lambda kk: self._mlstm_block_init(kk)[0])
                    params["mlstm_groups"] = _stack_group(ks[3], g, rebuild_group)
                specs["mlstm_groups"] = jax.tree.map(
                    lambda ax: ("layers", "layers2") + ax, s_m,
                    is_leaf=lambda x: isinstance(x, tuple),
                )
                t_s, s_s = self._slstm_block_init(ks[4])
                tmpl_s = jax.tree.map(_sds, t_s)
                if abstract:
                    params["slstm_blocks"] = _stack_template(tmpl_s, g, True)
                else:
                    params["slstm_blocks"] = _stack_template(
                        tmpl_s, g, False, ks[4], lambda kk: self._slstm_block_init(kk)[0]
                    )
                specs["slstm_blocks"] = jax.tree.map(
                    lambda ax: ("layers",) + ax, s_s,
                    is_leaf=lambda x: isinstance(x, tuple),
                )
                return params, specs

            if cfg.ssm:  # zamba2 hybrid
                g, per = self._hybrid_groups()
                t_m, s_m = self._mamba_block_init(ks[3])
                tmpl_m = jax.tree.map(_sds, t_m)
                grouped = jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct((g, per) + tuple(l.shape), l.dtype), tmpl_m
                )
                if abstract:
                    params["mamba_groups"] = grouped
                else:
                    def rebuild_group(k):
                        return _stack_template(tmpl_m, per, False, k,
                                               lambda kk: self._mamba_block_init(kk)[0])
                    params["mamba_groups"] = _stack_group(ks[3], g, rebuild_group)
                specs["mamba_groups"] = jax.tree.map(
                    lambda ax: ("layers", "layers2") + ax, s_m,
                    is_leaf=lambda x: isinstance(x, tuple),
                )
                shared, shared_specs = self._dense_block_init(ks[4])
                if abstract:
                    shared = jax.tree.map(_sds, shared)
                params["shared_attn"] = shared  # ONE weight set, reused per group
                specs["shared_attn"] = shared_specs
                return params, specs

            if cfg.moe:
                nd = cfg.first_dense_layers
                dense_blocks = []
                dense_specs = None
                for i in range(nd):
                    dp, dsp = self._dense_block_init(jax.random.fold_in(ks[3], i))
                    if abstract:
                        dp = jax.tree.map(_sds, dp)
                    dense_blocks.append(dp)
                    dense_specs = dsp
                params["dense_blocks"] = dense_blocks
                specs["dense_blocks"] = [dense_specs] * nd
                n_moe = cfg.n_layers - nd
                t, s = self._moe_block_init(ks[4])
                tmpl = jax.tree.map(_sds, t)
                if abstract:
                    params["blocks"] = _stack_template(tmpl, n_moe, True)
                else:
                    params["blocks"] = _stack_template(
                        tmpl, n_moe, False, ks[4], lambda kk: self._moe_block_init(kk)[0]
                    )
                specs["blocks"] = jax.tree.map(
                    lambda ax: ("layers",) + ax, s, is_leaf=lambda x: isinstance(x, tuple)
                )
                return params, specs

            # dense / vlm / audio
            t, s = self._dense_block_init(ks[3])
            tmpl = jax.tree.map(_sds, t)
            if abstract:
                params["blocks"] = _stack_template(tmpl, cfg.n_layers, True)
            else:
                params["blocks"] = _stack_template(
                    tmpl, cfg.n_layers, False, ks[3], lambda kk: self._dense_block_init(kk)[0]
                )
            specs["blocks"] = jax.tree.map(
                lambda ax: ("layers",) + ax, s, is_leaf=lambda x: isinstance(x, tuple)
            )
            return params, specs

    def _xlstm_groups(self):
        cfg = self.cfg
        if not cfg.slstm_every:
            return 1, cfg.n_layers  # one group, all mLSTM, no sLSTM
        assert cfg.n_layers % cfg.slstm_every == 0
        g = cfg.n_layers // cfg.slstm_every
        return g, cfg.slstm_every - 1

    def _hybrid_groups(self):
        cfg = self.cfg
        assert cfg.n_layers % cfg.attn_every == 0
        return cfg.n_layers // cfg.attn_every, cfg.attn_every

    def init(self, key) -> Params:
        return self.init_with_specs(key, abstract=False)[0]

    def abstract_params(self) -> Params:
        p, _ = self.init_with_specs(jax.random.PRNGKey(0), abstract=True)
        return jax.tree.map(
            lambda l: l if isinstance(l, jax.ShapeDtypeStruct) else _sds(l), p
        )

    def param_specs(self) -> Params:
        _, s = self.init_with_specs(jax.random.PRNGKey(0), abstract=True)
        return s

    # ------------------------------------------------------------------
    # forward (training / prefill / encoder)
    # ------------------------------------------------------------------
    def _inputs(self, params, batch):
        cfg = self.cfg
        if cfg.frontend != "none":
            x = batch["embeds"].astype(self.dtype) @ params["frontend_proj"]
        else:
            x = embed_lookup(params["embed"], batch["tokens"])
        b, s = x.shape[0], x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if cfg.mrope:
            pos = jnp.broadcast_to(pos[None], (3, b, s))
        return shd(x, "batch", self._seq_ax, None), pos

    def _dense_block_apply(self, p, x, pos):
        cfg = self.cfg
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        h = attn.mla_forward(p["attn"], cfg, h, pos) if cfg.mla else attn.gqa_forward(
            p["attn"], cfg, h, pos, attn_impl=self.attn_impl, unroll=self.unroll,
            seq_parallel=self.attn_seq_parallel,
        )
        x = x + h
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg.gated_mlp)
        return shd(x, "batch", self._seq_ax, None)

    def forward(self, params: Params, batch: Dict[str, jnp.ndarray]):
        cfg = self.cfg
        x, pos = self._inputs(params, batch)
        aux: Dict[str, jnp.ndarray] = {}

        if cfg.xlstm:
            g, rem = self._xlstm_groups()

            def group(x, gp):
                mg, sp = gp
                for i in range(rem):
                    blk = jax.tree.map(lambda l: l[i], mg)
                    h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
                    x = x + xlstm_mod.mlstm_forward(blk["mlstm"], cfg, h)
                if cfg.slstm_every:
                    h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
                    x = x + xlstm_mod.slstm_forward(sp["slstm"], cfg, h)
                return x, None

            x, _ = self._maybe_scan(
                lambda c, gp: group(c, gp), x,
                (params["mlstm_groups"], params["slstm_blocks"]),
            )
        elif cfg.ssm:
            g, per = self._hybrid_groups()
            shared = params["shared_attn"]

            def group(x, mg):
                for i in range(per):
                    blk = jax.tree.map(lambda l: l[i], mg)
                    h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
                    x = x + ssm_mod.mamba2_forward(blk["mamba"], cfg, h, unroll=self.unroll)
                x = self._dense_block_apply(shared, x, pos)
                return x, None

            x, _ = self._maybe_scan(group, x, params["mamba_groups"])
        elif cfg.moe:
            for dp in params["dense_blocks"]:
                x = self._dense_block_apply(dp, x, pos)

            def block(x, p):
                h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                h = attn.mla_forward(p["attn"], cfg, h, pos) if cfg.mla \
                    else attn.gqa_forward(p["attn"], cfg, h, pos,
                                          attn_impl=self.attn_impl, unroll=self.unroll,
                                          seq_parallel=self.attn_seq_parallel)
                x = x + h
                h = rmsnorm(x, p["ln2"], cfg.norm_eps)
                mo, a = moe_mod.moe_apply(p["moe"], cfg, h)
                x = x + mo
                return shd(x, "batch", self._seq_ax, None), a["load_balance_loss"]

            x, lbl = self._maybe_scan(block, x, params["blocks"])
            aux["load_balance_loss"] = jnp.mean(lbl)
        else:
            def block(x, p):
                return self._dense_block_apply(p, x, pos), None

            x, _ = self._maybe_scan(block, x, params["blocks"])

        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = x @ head
        return shd(logits, "batch", self._seq_ax, "vocab"), aux

    # ------------------------------------------------------------------
    # training loss
    # ------------------------------------------------------------------
    def loss(self, params: Params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = self.forward(params, batch)
        loss = softmax_xent(logits, batch["labels"])
        metrics = {"xent": loss}
        if "load_balance_loss" in aux:
            loss = loss + 0.01 * aux["load_balance_loss"]
            metrics["load_balance"] = aux["load_balance_loss"]
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------------
    # serving: cache init + single-token decode
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, abstract: bool = False) -> Params:
        cfg = self.cfg

        def build():
            if cfg.xlstm:
                g, rem = self._xlstm_groups()
                mc = xlstm_mod.mlstm_init_cache(cfg, batch)
                mg = jax.tree.map(
                    lambda l: jnp.zeros((g, rem) + l.shape, l.dtype), mc
                )
                sc = xlstm_mod.slstm_init_cache(cfg, batch)
                sg = jax.tree.map(lambda l: jnp.zeros((g,) + l.shape, l.dtype), sc)
                return {"mlstm": mg, "slstm": sg, "pos": jnp.zeros((), jnp.int32)}
            if cfg.ssm:
                g, per = self._hybrid_groups()
                mc = ssm_mod.mamba2_init_cache(cfg, batch, self.dtype)
                mg = jax.tree.map(lambda l: jnp.zeros((g, per) + l.shape, l.dtype), mc)
                ac = attn.gqa_init_cache(cfg, batch, max_len, self.dtype)
                ag = jax.tree.map(lambda l: jnp.zeros((g,) + l.shape, l.dtype), ac)
                return {"mamba": mg, "attn": ag, "pos": jnp.zeros((), jnp.int32)}
            if cfg.mla:
                lc = attn.mla_init_cache(cfg, batch, max_len, self.dtype)
            else:
                lc = attn.gqa_init_cache(cfg, batch, max_len, self.dtype)
            n_stack = cfg.n_layers - (cfg.first_dense_layers if cfg.moe else 0)
            stacked = jax.tree.map(lambda l: jnp.zeros((n_stack,) + l.shape, l.dtype), lc)
            out = {"kv": stacked, "pos": jnp.zeros((), jnp.int32)}
            if cfg.moe and cfg.first_dense_layers:
                out["kv_dense"] = [
                    jax.tree.map(lambda l: l.copy(), lc)
                    for _ in range(cfg.first_dense_layers)
                ]
            return out

        if abstract:
            return jax.eval_shape(build)
        return build()

    def decode_step(self, params: Params, cache: Params, tokens: jnp.ndarray):
        """One serve step: tokens [B, 1] (or embeds [B, 1, D]) -> logits."""
        cfg = self.cfg
        pos = cache["pos"]
        if cfg.frontend != "none":
            x = tokens.astype(self.dtype) @ params["frontend_proj"]
        else:
            x = embed_lookup(params["embed"], tokens)
        new_cache = dict(cache)

        if cfg.xlstm:
            g, rem = self._xlstm_groups()

            def group(x, gp):
                mg, sp, mcache, scache = gp
                new_mc = []
                for i in range(rem):
                    blk = jax.tree.map(lambda l: l[i], mg)
                    cc = jax.tree.map(lambda l: l[i], mcache)
                    h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
                    dh, cc2 = xlstm_mod.mlstm_decode(blk["mlstm"], cfg, cc, h)
                    x = x + dh
                    new_mc.append(cc2)
                new_mc = jax.tree.map(lambda *ls: jnp.stack(ls), *new_mc)
                if cfg.slstm_every:
                    h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
                    dh, sc2 = xlstm_mod.slstm_decode(sp["slstm"], cfg, scache, h)
                    x = x + dh
                else:
                    sc2 = scache
                return x, (new_mc, sc2)

            x, (mg2, sg2) = self._maybe_scan(
                group, x,
                (params["mlstm_groups"], params["slstm_blocks"],
                 cache["mlstm"], cache["slstm"]),
            )
            new_cache["mlstm"], new_cache["slstm"] = mg2, sg2
        elif cfg.ssm:
            g, per = self._hybrid_groups()
            shared = params["shared_attn"]

            def group(x, gp):
                mg, mcache, acache = gp
                new_mc = []
                for i in range(per):
                    blk = jax.tree.map(lambda l: l[i], mg)
                    cc = jax.tree.map(lambda l: l[i], mcache)
                    h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
                    dh, cc2 = ssm_mod.mamba2_decode(blk["mamba"], cfg, cc, h)
                    x = x + dh
                    new_mc.append(cc2)
                new_mc = jax.tree.map(lambda *ls: jnp.stack(ls), *new_mc)
                h = rmsnorm(x, shared["ln1"], cfg.norm_eps)
                dh, ac2 = attn.gqa_decode(shared["attn"], cfg, acache, h, pos,
                                          batch_parallel=self.decode_batch_parallel)
                x = x + dh
                h = rmsnorm(x, shared["ln2"], cfg.norm_eps)
                x = x + mlp_apply(shared["mlp"], h, cfg.gated_mlp)
                return x, (new_mc, ac2)

            x, (mg2, ag2) = self._maybe_scan(
                group, x, (params["mamba_groups"], cache["mamba"], cache["attn"])
            )
            new_cache["mamba"], new_cache["attn"] = mg2, ag2
        else:
            if cfg.moe and cfg.first_dense_layers:
                kvd = []
                for dp, dc in zip(params["dense_blocks"], cache["kv_dense"]):
                    h = rmsnorm(x, dp["ln1"], cfg.norm_eps)
                    dh, dc2 = (
                        attn.mla_decode(dp["attn"], cfg, dc, h, pos,
                                        batch_parallel=self.decode_batch_parallel)
                        if cfg.mla
                        else attn.gqa_decode(dp["attn"], cfg, dc, h, pos,
                                             batch_parallel=self.decode_batch_parallel)
                    )
                    x = x + dh
                    h = rmsnorm(x, dp["ln2"], cfg.norm_eps)
                    x = x + mlp_apply(dp["mlp"], h, cfg.gated_mlp)
                    kvd.append(dc2)
                new_cache["kv_dense"] = kvd

            def block(x, bp):
                p, c = bp
                h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                dh, c2 = (
                    attn.mla_decode(p["attn"], cfg, c, h, pos,
                                    batch_parallel=self.decode_batch_parallel)
                    if cfg.mla
                    else attn.gqa_decode(p["attn"], cfg, c, h, pos,
                                         batch_parallel=self.decode_batch_parallel)
                )
                x = x + dh
                h = rmsnorm(x, p["ln2"], cfg.norm_eps)
                if cfg.moe:
                    mo, _ = moe_mod.moe_apply(p["moe"], cfg, h)
                    x = x + mo
                else:
                    x = x + mlp_apply(p["mlp"], h, cfg.gated_mlp)
                return x, c2

            x, kv2 = self._maybe_scan(block, x, (params["blocks"], cache["kv"]))
            new_cache["kv"] = kv2

        new_cache["pos"] = pos + 1
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return x @ head, new_cache


def _stack_group(key, g: int, rebuild_group):
    keys = jax.random.split(key, g)
    groups = [rebuild_group(keys[i]) for i in range(g)]
    return jax.tree.map(lambda *ls: jnp.stack(ls), *groups)


class _nullctx:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False
