"""Mixture-of-Experts layer routed through the paper's shuffle engine.

Token dispatch is the graph-shuffle problem of paper §III-C3: tokens are
update tuples keyed by expert id. The layer:

1. routes (softmax top-k),
2. **sorts token-assignments by expert** (the static shuffle routing),
3. bins them into block-aligned capacity groups (the dst-partition step —
   `kernels/moe_dispatch` is the Pallas realization; the jnp path below is
   its exact oracle and is used under jit/SPMD),
4. runs the per-expert FFN as dense [E, C, D] batched matmuls (MXU),
5. combines with the inverse shuffle weighted by router probabilities.

Capacity overflow drops tokens (standard Switch-style), counted in aux.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import _init_normal, shd

Params = Dict[str, Any]


def moe_init(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    params = {
        "router": _init_normal(ks[0], (d, e), s_in, jnp.float32),
        "wi": _init_normal(ks[1], (e, d, f), s_in, dtype),
        "wg": _init_normal(ks[2], (e, d, f), s_in, dtype),
        "wo": _init_normal(ks[3], (e, f, d), s_out, dtype),
    }
    specs = {
        "router": ("embed", None),
        "wi": ("experts", "expert_dmodel", "expert_ff"),
        "wg": ("experts", "expert_dmodel", "expert_ff"),
        "wo": ("experts", "expert_ff", "expert_dmodel"),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        params["shared_wi"] = _init_normal(ks[4], (d, fs), s_in, dtype)
        params["shared_wg"] = _init_normal(ks[4], (d, fs), s_in, dtype)
        params["shared_wo"] = _init_normal(ks[4], (fs, d), s_out, dtype)
        specs["shared_wi"] = ("embed", "mlp")
        specs["shared_wg"] = ("embed", "mlp")
        specs["shared_wo"] = ("mlp", "embed")
    return params, specs


def _dispatch_groups(t: int, max_groups: int = 32) -> int:
    """Largest power-of-two group count <= max_groups dividing t.

    Groups correspond to data-parallel shards: each group sorts/bins its
    own tokens (per-shard capacity), which keeps every dispatch tensor
    batched on a sharded leading axis under GSPMD — the SPMD analogue of
    per-device shuffle routing."""
    g = 1
    while g * 2 <= max_groups and t % (g * 2) == 0 and t // (g * 2) >= 1:
        g *= 2
    return g


def moe_apply(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # [B, S, D]
    capacity_factor: float = 0.0,  # 0 -> cfg.moe_capacity_factor
    n_groups: int = 0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = n_groups or _dispatch_groups(t)
    tg = t // g
    xt = shd(x.reshape(g, tg, d), "batch", None, None)

    # 1. route
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [G,Tg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # [G,Tg,k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # 2. shuffle routing per group: sort the Tg*k assignments by expert id
    flat_e = top_e.reshape(g, tg * k)
    flat_w = top_p.reshape(g, tg * k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None], (g, tg * k)
    )
    order = jnp.argsort(flat_e, axis=-1)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    sw = jnp.take_along_axis(flat_w, order, axis=-1)
    stok = jnp.take_along_axis(flat_tok, order, axis=-1)

    # 3. per-group capacity binning (the dst-partition step)
    cap = int(max(1, math.ceil(capacity_factor * tg * k / e)))
    first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(se)
    pos_in_e = jnp.arange(tg * k)[None] - first
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)  # drop -> overflow bin
    # inverse shuffle map (token id filling each bin slot), then ONE gather
    # into [G, E*C, D] bins — the wide (D-dim) token tensor is never
    # materialized in assignment order (oracle of kernels/moe_dispatch)
    tok_for_slot = jax.vmap(
        lambda sl, tk: jnp.full((e * cap + 1,), tg, jnp.int32).at[sl].set(tk)
    )(slot, stok)[:, :-1]
    xt_pad = jnp.concatenate([xt, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    binned = jnp.take_along_axis(xt_pad, tok_for_slot[..., None], axis=1)
    binned = binned.reshape(g, e, cap, d)
    from .layers import _SHARDING_RULES

    token_ep = bool(_SHARDING_RULES and _SHARDING_RULES.get("expert_ff"))
    if token_ep:
        # tokens-move expert parallelism (perf loop): gather the (small)
        # token bins across the data axis; expert weights stay resident
        # with their ff dim sharded — the FFN computes on weight shards
        # and the combine reduce-scatters back to token owners.
        binned = shd(binned, None, "experts", None, None)
    else:
        binned = shd(binned, "batch", "experts", None, None)

    # 4. per-expert FFN (dense batched matmul on the MXU)
    h = jnp.einsum("gecd,edf->gecf", binned, p["wi"])
    gg = jnp.einsum("gecd,edf->gecf", binned, p["wg"])
    h = jax.nn.silu(gg) * h
    if token_ep:
        h = shd(h, None, "experts", None, "expert_ff")
    else:
        h = shd(h, "batch", "experts", None, None)
    y = jnp.einsum("gecf,efd->gecd", h, p["wo"])  # [G,E,C,D]

    # 5. inverse shuffle + weighted combine (in x.dtype: at most top_k
    # accumulands per token, so low-precision accumulation is benign and
    # halves the combine traffic vs f32)
    flat_y = y.reshape(g, e * cap, d)
    safe_slot = jnp.minimum(slot, e * cap - 1)
    gathered = jnp.take_along_axis(flat_y, safe_slot[..., None], axis=1)
    gathered = gathered * sw[..., None].astype(x.dtype)
    gathered = jnp.where(keep[..., None], gathered, 0)
    contrib = jax.vmap(
        lambda tok, v: jnp.zeros((tg, d), x.dtype).at[tok].add(v)
    )(stok, gathered)
    out = shd(contrib, "batch", None, None)

    # shared experts (always-on)
    if cfg.n_shared_experts:
        hs = jax.nn.silu(xt @ p["shared_wg"]) * (xt @ p["shared_wi"])
        out = out + (hs @ p["shared_wo"]).astype(out.dtype)

    # aux metrics: load balance + drop fraction
    me = jnp.mean(probs, axis=(0, 1))  # [E] router prob mass
    ce = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )  # top-1 assignment fraction
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce),
        "drop_fraction": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.reshape(b, s, d), aux
