"""repro: Graphitron-on-TPU — DSL-driven graph processing + LM framework in JAX.

Graph-program quickstart (compile once, bind many, run parameterized):

    import repro

    program = repro.compile(src)            # Program (content-hash cached)
    session = program.bind(graph)           # Session on the local backend
    result  = session.run(root=3)           # explicit run-time parameters

``src`` is either ``.gt`` text or an embedded :class:`GraphProgram`
(:mod:`repro.frontend`) — two front-ends, one compiler: both produce the
same MIR and share one content-hash cache entry.

Deployment path (compile -> lower -> bind): AOT-lower once per shape
bucket and substrate, then bind any number of same-shape graphs — and
warm-start new processes from a saved artifact:

    acc = program.lower(repro.Target(), shape=repro.GraphShape(
        n_vertices=2000, n_edges=16000))
    acc.save("artifacts/bfs")               # canonical MIR + executables
    ...
    acc = repro.load_accelerator("artifacts/bfs")
    result = acc.bind(graph).run(root=3)    # shape check only, no compile

Serving path (one call, resident/warm/cold picked automatically):

    service = repro.serve()                 # GraphService over the
    fut = service.submit("bfs", g, root=3)  #   artifact registry; async,
    res = repro.run("pagerank", g, iters=20)  # batched, multi-tenant

Static analysis (lint + determinism certificates, both front-ends):

    result = repro.analyze(src)             # AnalysisResult, never raises
    result.errors                           # GT1xx scatter races, ...
    result.certificate                      # deterministic / reduction-
                                            #   deterministic / racy
    repro.compile(src, strict=True)         # errors -> ProgramError

``python -m repro.lint [--json] file.gt|module:program`` is the CLI twin;
:meth:`GraphService.submit` rejects error-level programs with
:class:`ProgramRejected` before they reach the registry.

Observability (off by default, near-zero cost when on):

    repro.telemetry.enable()                # process-wide tracer
    result = session.run(root=3)            # spans: compile/lower/bind/
    result.trace                            #   launch:<kernel>/...
    repro.telemetry.get().export_chrome("trace.json")  # chrome://tracing

Autotuning (profile-guided Target search, persisted and reused):

    report = repro.autotune.autotune(program, graph, params={"root": 3})
    acc = program.lower(graph=graph, tuned=True)  # lookup, zero trials
    repro.serve() resolves tuned Targets automatically (``tuned_hits``
    in ``service.stats()``); ``python -m repro.autotune`` is the CLI.
"""

from .core import (  # noqa: F401 - re-exported public API
    Accelerator,
    AcceleratorError,
    BatchSession,
    CompileOptions,
    GraphShape,
    Program,
    ProgramError,
    ServiceClosed,
    Session,
    SessionPool,
    Target,
    compile,
    compile_program,
    load_accelerator,
    program_cache_info,
    set_program_cache_limit,
)
from .analysis import AnalysisResult, Diagnostic, analyze  # noqa: F401
from .frontend import FrontendError, GraphProgram  # noqa: F401
from .graph.storage import GraphDelta, GraphUpdateError  # noqa: F401
from .streaming import StreamingSession  # noqa: F401
from . import telemetry  # noqa: F401
from . import autotune  # noqa: F401
from .autotune import AutoTuner, TunedConfig, TuningCache  # noqa: F401
from .serving import (  # noqa: F401
    ArtifactRegistry,
    DeadlineExceeded,
    GraphService,
    Overloaded,
    ProgramRejected,
    ServingError,
    run,
    serve,
)

__version__ = "0.6.0"

__all__ = [
    "CompileOptions",
    "Target",
    "Accelerator",
    "AcceleratorError",
    "GraphShape",
    "load_accelerator",
    "Program",
    "ProgramError",
    "GraphProgram",
    "FrontendError",
    "BatchSession",
    "Session",
    "SessionPool",
    "StreamingSession",
    "GraphDelta",
    "GraphUpdateError",
    "ArtifactRegistry",
    "GraphService",
    "ServingError",
    "ServiceClosed",
    "Overloaded",
    "DeadlineExceeded",
    "ProgramRejected",
    "serve",
    "run",
    "analyze",
    "AnalysisResult",
    "Diagnostic",
    "compile",
    "compile_program",
    "program_cache_info",
    "set_program_cache_limit",
    "telemetry",
    "autotune",
    "AutoTuner",
    "TunedConfig",
    "TuningCache",
    "__version__",
]
