"""repro: Graphitron-on-TPU — DSL-driven graph processing + LM framework in JAX."""

__version__ = "0.1.0"
