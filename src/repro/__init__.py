"""repro: Graphitron-on-TPU — DSL-driven graph processing + LM framework in JAX.

Graph-program quickstart (compile once, bind many, run parameterized):

    import repro

    program = repro.compile(src)            # Program (content-hash cached)
    session = program.bind(graph)           # Session on the local backend
    result  = session.run(root=3)           # explicit run-time parameters
"""

from .core import (  # noqa: F401 - re-exported public API
    CompileOptions,
    Program,
    ProgramError,
    Session,
    SessionPool,
    compile,
    compile_program,
)

__version__ = "0.2.0"

__all__ = [
    "CompileOptions",
    "Program",
    "ProgramError",
    "Session",
    "SessionPool",
    "compile",
    "compile_program",
    "__version__",
]
