"""repro: Graphitron-on-TPU — DSL-driven graph processing + LM framework in JAX.

Graph-program quickstart (compile once, bind many, run parameterized):

    import repro

    program = repro.compile(src)            # Program (content-hash cached)
    session = program.bind(graph)           # Session on the local backend
    result  = session.run(root=3)           # explicit run-time parameters

``src`` is either ``.gt`` text or an embedded :class:`GraphProgram`
(:mod:`repro.frontend`) — two front-ends, one compiler: both produce the
same MIR and share one content-hash cache entry.
"""

from .core import (  # noqa: F401 - re-exported public API
    BatchSession,
    CompileOptions,
    Program,
    ProgramError,
    Session,
    SessionPool,
    compile,
    compile_program,
)
from .frontend import FrontendError, GraphProgram  # noqa: F401

__version__ = "0.3.0"

__all__ = [
    "CompileOptions",
    "Program",
    "ProgramError",
    "GraphProgram",
    "FrontendError",
    "BatchSession",
    "Session",
    "SessionPool",
    "compile",
    "compile_program",
    "__version__",
]
