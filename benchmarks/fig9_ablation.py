"""Paper Fig. 9: BFS speedups with individual memory-access optimizations
(burst-only / cache-only / shuffle-only) vs the full composition.
Warm-engine timing (see fig8).

Ablation axes live on :class:`repro.Target` (the substrate description);
``CompileOptions`` carries only the MIR pass pipeline — each variant is
``compile_program(src, CompileOptions(passes=...)).bind(g, target=...)``.

Beyond-paper axis: ``fullNoPasses`` runs the full memory-optimization
composition with the MIR optimization pass pipeline disabled
(``passes="none"``), isolating the contribution of kernel fusion /
direction selection from the memory-access optimizations."""
from __future__ import annotations

import numpy as np

from repro.core import CompileOptions, Target
from repro.core.program import compile_program
from repro.graph.datasets import make_dataset
from repro.algorithms import sources

from .common import DATASETS, DEFAULT_SCALE, csv_line, timed

# name -> (target, MIR passes); the paper's single-axis points keep the
# pass pipeline off so only the memory optimization under test moves
VARIANTS = {
    "baseline": (Target.baseline(), "none"),
    "withBurst": (Target.with_only("burst"), "none"),
    "withCache": (Target.with_only("cache"), "none"),
    "withShuffle": (Target.with_only("shuffle"), "none"),
    "fullNoPasses": (Target(), "none"),
    "full": (Target(), "default"),
}


def _warm_runner(src, graph, target, passes, params):
    session = compile_program(src, CompileOptions(passes=passes)).bind(
        graph, target=target
    )

    def run():
        return session.run(**params)

    run()  # warm: compile every kernel launch path before timing
    return run


def main(scale: float = DEFAULT_SCALE, datasets=None) -> list:
    lines = []
    for short in datasets or DATASETS:
        g = make_dataset(short, scale=scale, seed=0)
        root = int(np.argmax(g.out_degree))
        t_base = None
        for name, (target, passes) in VARIANTS.items():
            run = _warm_runner(sources.BFS_ECP, g, target, passes,
                               {"root": root})
            t, res = timed(run)
            if name == "baseline":
                t_base = t
                e_base = res.stats.edges_traversed
            lines.append(
                csv_line(
                    f"fig9.BFS.{short}.{name}",
                    t * 1e6,
                    f"cpu_speedup={t_base / t:.2f}x;"
                    f"work_reduction={e_base / max(res.stats.edges_traversed, 1):.2f}x;"
                    f"edges={res.stats.edges_traversed};"
                    f"launches={res.stats.total_launches};"
                    f"fused={res.stats.fused_launches}",
                )
            )
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
