"""Paper Fig. 9: BFS speedups with individual memory-access optimizations
(burst-only / cache-only / shuffle-only) vs the full composition.
Warm-engine timing (see fig8).

Beyond-paper axis: ``fullNoPasses`` runs the full memory-optimization
composition with the MIR optimization pass pipeline disabled
(``CompileOptions.passes="none"``), isolating the contribution of kernel
fusion / direction selection from the memory-access optimizations."""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from repro.core import CompileOptions
from repro.graph.datasets import make_dataset
from repro.algorithms import sources
from repro.algorithms.runners import make_warm_runner

from .common import DATASETS, DEFAULT_SCALE, csv_line, timed

VARIANTS = {
    "baseline": CompileOptions.baseline(),
    "withBurst": CompileOptions.with_only("burst"),
    "withCache": CompileOptions.with_only("cache"),
    "withShuffle": CompileOptions.with_only("shuffle"),
    "fullNoPasses": replace(CompileOptions.full(), passes="none"),
    "full": CompileOptions.full(),
}


def main(scale: float = DEFAULT_SCALE, datasets=None) -> list:
    lines = []
    for short in datasets or DATASETS:
        g = make_dataset(short, scale=scale, seed=0)
        root = int(np.argmax(g.out_degree))
        t_base = None
        for name, opts in VARIANTS.items():
            run = make_warm_runner(sources.BFS_ECP, g, opts, {"root": root})
            t, res = timed(run)
            if name == "baseline":
                t_base = t
                e_base = res.stats.edges_traversed
            lines.append(
                csv_line(
                    f"fig9.BFS.{short}.{name}",
                    t * 1e6,
                    f"cpu_speedup={t_base / t:.2f}x;"
                    f"work_reduction={e_base / max(res.stats.edges_traversed, 1):.2f}x;"
                    f"edges={res.stats.edges_traversed};"
                    f"launches={res.stats.total_launches};"
                    f"fused={res.stats.fused_launches}",
                )
            )
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
