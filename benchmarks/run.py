"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--fast`` shrinks datasets.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig8,fig9,...]
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller datasets")
    ap.add_argument("--scale", type=float, default=0.0, help="Table II dataset scale")
    ap.add_argument("--only", type=str, default="")
    args = ap.parse_args(argv)

    from . import fig8_speedups, fig9_ablation, fig10_productivity
    from . import table3_flexibility, roofline_report
    from .common import DEFAULT_SCALE

    scale = args.scale or (0.001 if args.fast else DEFAULT_SCALE)
    sections = {
        "fig8": lambda: fig8_speedups.main(scale=scale),
        "fig9": lambda: fig9_ablation.main(scale=scale),
        "fig10": fig10_productivity.main,
        "table3": table3_flexibility.main,
        "roofline": roofline_report.main,
    }
    chosen = [s.strip() for s in args.only.split(",") if s.strip()] or list(sections)
    print("name,us_per_call,derived")
    for name in chosen:
        for line in sections[name]():
            print(line)
            sys.stdout.flush()


if __name__ == "__main__":
    main()
