"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Tuple

DEFAULT_SCALE = 0.01  # Table II datasets scaled for CPU wall-clock runs
DATASETS = ["R19", "HT", "TC", "AM", "PK"]


def timed(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    """Best-of-N wall time in seconds (first call may include compile)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = min(best, dt)
    return best, out


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
