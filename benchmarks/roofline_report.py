"""Roofline analysis (EXPERIMENTS.md §Roofline): derives the three terms
from the dry-run artifacts for every (arch x shape) cell.

    compute_s    = HLO_FLOPs_per_chip / peak_FLOP/s      (197 TFLOP/s bf16)
    memory_s     = HLO_bytes_per_chip / HBM_bw           (819 GB/s)
    collective_s = wire_bytes_per_chip / link_bw         (50 GB/s/link)

cost_analysis of the GSPMD-partitioned module is per-chip, so no extra
division by chip count is needed. MODEL_FLOPS uses 6*N*D (dense train),
6*N_active*D (MoE train), 2*N*D (prefill), 2*N_active*D (decode, D=batch
tokens per step). The reported `roofline_frac` is the roofline-model MFU
bound: useful model compute time / dominant term.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK = 197e12
HBM = 819e9
LINK = 50e9

ART = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    n_full = cfg.param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch


def load_cells(mesh: str = "single") -> List[Dict]:
    out = []
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        d = json.loads(f.read_text())
        if d.get("ok") and "roofline_raw" in d:
            out.append(d)
    return out


def analyze_cell(d: Dict) -> Optional[Dict]:
    from repro.configs import SHAPES, get_config

    cfg = get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    rr = d["roofline_raw"]
    n_chips = d["n_devices"]
    compute_s = rr["flops"] / PEAK
    memory_s = rr["bytes"] / HBM
    collective_s = rr["wire_bytes"] / LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_per_chip = mf / n_chips
    useful_ratio = mf_per_chip / max(rr["flops"], 1e-30)
    roofline_frac = (mf_per_chip / PEAK) / max(terms[dominant], 1e-30)
    hints = {
        "compute": "compute-bound: reduce redundant FLOPs (remat policy, "
        "fuse attention) or accept — near the right wall",
        "memory": "HBM-bound: raise arithmetic intensity (flash/blocked "
        "attention, fuse elementwise chains, wider tiles)",
        "collective": "ICI-bound: reshard to cut collective volume "
        "(2D sharding, overlap collectives with compute, compress)",
    }
    return {
        "arch": d["arch"],
        "shape": d["shape"],
        "mesh": d["mesh"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_per_chip": rr["flops"],
        "useful_ratio": useful_ratio,
        "roofline_frac": roofline_frac,
        "hint": hints[dominant],
    }


def table(mesh: str = "single") -> List[Dict]:
    return [analyze_cell(d) for d in load_cells(mesh)]


def markdown(rows: List[Dict]) -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO | roofline frac | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.2%} | {r['hint']} |"
        )
    return "\n".join(out)


def main() -> list:
    from .common import csv_line

    lines = []
    rows = table("single")
    for r in rows:
        lines.append(
            csv_line(
                f"roofline.{r['arch']}.{r['shape']}",
                max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
                f"dominant={r['dominant']};frac={r['roofline_frac']:.3f};"
                f"useful={r['useful_ratio']:.2f}",
            )
        )
    if not lines:
        lines.append(csv_line("roofline.no_artifacts", 0.0, "run launch.dryrun first"))
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
