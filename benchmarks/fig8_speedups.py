"""Paper Fig. 8: speedups of ThunderGP and Graphitron over the
unoptimized baseline, per algorithm x dataset.

Baseline       = Graphitron engine with every back-end optimization off
                 (the paper's "handcrafted HLS without optimizations").
ThunderGP      = the GAS/ECP template engine (PPR/CGAW: unsupported,
                 reported as 'n/a' — paper Table III).
Graphitron     = full back-end (burst + cache + shuffle + compaction).

All engines are timed warm (kernels pre-compiled), matching the paper's
accelerator-execution-time measurements (synthesis excluded).
"""
from __future__ import annotations

import numpy as np

from repro.core import CompileOptions, Target
from repro.core.program import compile_program
from repro.graph.datasets import make_dataset
from repro.algorithms import sources
from repro.baselines import thundergp as tg
from repro.baselines.thundergp import TemplateLimitation

from .common import DATASETS, DEFAULT_SCALE, csv_line, timed

# substrate ablation on Target, pass pipeline on CompileOptions (the
# baseline disables both — the paper's unoptimized handcrafted HLS)
BASE = (Target.baseline(), CompileOptions(passes="none"))
FULL = (Target(), CompileOptions())


def _warm_runner(src, graph, variant, params):
    target, opts = variant
    session = compile_program(src, opts).bind(graph, target=target)

    def run():
        return session.run(**params)

    run()  # warm: compile every kernel launch path before timing
    return run

ALGOS = {
    "PageRank": (sources.PAGERANK, {"iters": 20}, False),
    "BFS": (sources.BFS_ECP, {}, False),
    "SSSP": (sources.SSSP, {}, True),
    "PPR": (sources.PPR, {"max_iters": 30}, False),
    "CGAW": (sources.CGAW, {}, True),
}


def _tgp_time(algo, g, gw, root):
    try:
        if algo == "PageRank":
            run = tg.make_warm_pagerank(g, 20)
        elif algo == "BFS":
            run = tg.make_warm_bfs(g, root)
        elif algo == "SSSP":
            run = tg.make_warm_sssp(gw, root)
        elif algo == "PPR":
            tg.ppr_run(g)
            return None
        else:
            tg.cgaw_run(g)
            return None
        t, _ = timed(run)
        return t
    except TemplateLimitation:
        return None


def main(scale: float = DEFAULT_SCALE, datasets=None) -> list:
    lines = []
    for short in datasets or DATASETS:
        g = make_dataset(short, scale=scale, seed=0)
        gw = make_dataset(short, scale=scale, seed=0, weighted=True)
        root = int(np.argmax(g.out_degree))
        for algo, (src, ov, weighted) in ALGOS.items():
            graph = gw if weighted else g
            ov = dict(ov)
            if algo in ("BFS", "SSSP"):
                ov["root"] = root
            run_b = _warm_runner(src, graph, BASE, ov)
            run_f = _warm_runner(src, graph, FULL, ov)
            t_b, res_b = timed(run_b)
            t_f, res_f = timed(run_f)
            t_t = _tgp_time(algo, g, gw, root)
            sp_t = f"{t_b / t_t:.2f}x" if t_t else "n/a(template)"
            wr = res_b.stats.edges_traversed / max(res_f.stats.edges_traversed, 1)
            lines.append(
                csv_line(
                    f"fig8.{algo}.{short}",
                    t_f * 1e6,
                    f"graphitron_cpu_speedup={t_b / t_f:.2f}x;"
                    f"work_reduction={wr:.2f}x;thundergp_speedup={sp_t};"
                    f"baseline_us={t_b * 1e6:.1f}",
                )
            )
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
