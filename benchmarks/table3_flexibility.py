"""Paper Table III: algorithm-design flexibility matrix, verified by
actually exercising each capability (not just claiming it)."""
from __future__ import annotations

import numpy as np

from repro.core import CompileOptions, compile_program
from repro.graph import generators
from repro.algorithms import sources, run_bfs_hybrid, run_cgaw, run_ppr
from repro.baselines import thundergp as tg
from repro.baselines.thundergp import TemplateLimitation

from .common import csv_line


def main() -> list:
    g = generators.power_law(200, 1200, seed=0)
    gw = generators.power_law(200, 1200, seed=0, weighted=True)
    rows = []

    def check(fn):
        try:
            fn()
            return True
        except (TemplateLimitation, Exception) as e:
            return False if isinstance(e, TemplateLimitation) else (_ for _ in ()).throw(e)

    # Graphitron capabilities (executed)
    compile_program(sources.BFS_HYBRID, CompileOptions.full()).bind(g).run()  # vcp+ecp+hybrid
    run_cgaw(gw)  # weight writes
    run_ppr(g)  # many properties
    graphitron = {"vcp": True, "ecp": True, "hybrid": True, "weight": True,
                  "kernels": "flexible", "properties": "flexible"}

    # ThunderGP capabilities (template raises on the unsupported ones)
    tgp = {
        "vcp": False,
        "ecp": True,
        "hybrid": False,
        "weight": check(lambda: tg.cgaw_run(g)),
        "kernels": "fixed",
        "properties": "fixed",
    }
    for sysname, caps in (("ThunderGP", tgp), ("Graphitron", graphitron)):
        rows.append(
            csv_line(
                f"table3.{sysname}", 0.0,
                ";".join(f"{k}={v}" for k, v in caps.items()),
            )
        )
    return rows


if __name__ == "__main__":
    for ln in main():
        print(ln)
