"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.experiments_doc
"""
from __future__ import annotations

import json
from pathlib import Path

from .roofline_report import ART, PEAK, HBM, LINK, analyze_cell, load_cells

REPO = Path(__file__).resolve().parents[1]


def dryrun_table() -> str:
    rows = [
        "| arch | shape | mesh | compile | microbatches | args/dev | temp/dev | collectives (counts) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for f in sorted(ART.glob("*.json")):
        if f.stem.count("__") != 2:
            continue  # skip tagged perf artifacts
        d = json.loads(f.read_text())
        if not d.get("ok") or "gate" not in d:
            continue
        g = d["gate"]
        mem = g.get("memory_analysis", {})
        args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
        temp_gb = mem.get("temp_size_in_bytes", 0) / 1e9
        coll = ",".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in g["collectives"].items() if v)
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {g['compile_s']}s | "
            f"{g['n_microbatches']} | {args_gb:.2f} GB | {temp_gb:.2f} GB | {coll or '-'} |"
        )
    return "\n".join(rows)


def roofline_table() -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | roofline frac | compute-bound MFU cap |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for d in load_cells("single"):
        r = analyze_cell(d)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | {r['memory_s']:.3g} | "
            f"{r['collective_s']:.3g} | **{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2%} | {min(r['useful_ratio'], 1.0):.0%} |"
        )
    return "\n".join(rows)


def multi_pod_table() -> str:
    rows = [
        "| arch | shape | single-pod wire B/chip | multi-pod wire B/chip | pod-axis collectives present |",
        "|---|---|---|---|---|",
    ]
    singles = {(d["arch"], d["shape"]): d for d in load_cells("single")}
    for d in load_cells("multi"):
        key = (d["arch"], d["shape"])
        s = singles.get(key)
        if not s:
            continue
        rows.append(
            f"| {d['arch']} | {d['shape']} | {s['roofline_raw']['wire_bytes']:.2e} | "
            f"{d['roofline_raw']['wire_bytes']:.2e} | yes |"
        )
    return "\n".join(rows)


def main():
    print("## Generated tables\n")
    print("### Dry-run gate results\n")
    print(dryrun_table())
    print("\n### Roofline (single-pod)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
