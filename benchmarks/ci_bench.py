"""CI perf-regression gate: quick BFS + PageRank benchmark on small
synthetic graphs.

Two modes:

* measure (default): runs the benchmark subset and writes ``BENCH_ci.json``
  with, per workload, the cold compile+first-run wall time, the steady-state
  (warm session) wall time, and the kernel-launch reduction achieved by the
  MIR pass pipeline (passes on vs off). A second ``batched`` section times
  K parameterized queries answered sequentially vs through one
  ``BatchSession`` execution (bfs_batched64: 64 BFS roots; pagerank_batched8:
  8 query batches) and records the wall-time speedup plus the launch ratio.
  A ``streaming`` section (bfs_incremental) applies a 1% additions-only
  GraphDelta through a StreamingSession and gates incremental repair at
  >= 3x over a warm full recompute, with zero re-lowering and bit-identical
  results. A ``serving`` section (serve_mixed_slo) drives sustained mixed
  BFS + PPR + SSSP traffic across two weighted tenants through one
  ``repro.serve()`` service and gates per-tenant p99 latency against an
  SLO ceiling with zero dropped-below-deadline admissions and one
  lowering per program. A ``telemetry`` section (telemetry_overhead)
  gates the tracing subsystem's cost: a fully traced warm BFS run must
  stay within 1.05x of the untraced run, the disabled null tracer within
  1.01x (measured as per-launch null-path cost scaled by the run's span
  count), and the traced run's Chrome trace is exported to
  ``BENCH_trace.json`` (uploaded as a CI artifact). An ``autotune``
  section (autotune_bfs) runs the repro.autotune search on a deep
  multigraph where frontier compaction is a structural win, and gates
  the tuned Target at >= 1.15x over ``Target.baseline()`` (interleaved
  within-run pairing), zero-trial reuse from a fresh TuningCache,
  manifest round-tripping of the config, and >= 1 serving tuned hit.

* ``--check``: compares a freshly written ``BENCH_ci.json`` against the
  committed ``BENCH_baseline.json`` and exits non-zero when any workload's
  compile+run or steady-state wall time regressed by more than
  ``--threshold`` (default 1.5x), when the pass pipeline's launch
  reduction fell below the acceptance floor of 1.3x, or when a batched
  workload's batched-vs-sequential speedup fell below its recorded floor
  (2x for bfs_batched64 at K=64). Speedups and launch ratios are measured
  within one run, so the batched gates are machine-independent and always
  fatal.

Wall-time comparisons are only meaningful between similar machines, so
the gate self-arms: while the committed baseline's ``meta.source`` is
"local" (measured on a dev machine) wall-time regressions are reported as
advisory warnings; once a baseline produced by a CI run (``meta.source ==
"ci"`` — download the ``bench-ci`` artifact of a green run and commit it)
is in place, they become fatal. A sub-50ms absolute delta is always
treated as runner jitter. The launch-reduction floor is
machine-independent and enforced unconditionally.

Refreshing the baseline after an intentional perf change::

    PYTHONPATH=src python -m benchmarks.ci_bench --out BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
from dataclasses import replace

LAUNCH_REDUCTION_FLOOR = 1.3


def _workloads():
    import numpy as np

    from repro.algorithms import embedded, sources
    from repro.graph import generators

    g_bfs = generators.power_law(2000, 16000, seed=0)
    g_pr = generators.power_law(2000, 16000, seed=1)
    bfs_root = int(np.argmax(g_bfs.out_degree))
    return {
        "bfs": (sources.BFS_ECP, g_bfs, {"root": bfs_root}),
        # same algorithm/graph/params compiled through the embedded Python
        # front-end: gates compile-path wall-time parity with the text
        # parser (to_fir + analyze vs lex + parse + analyze) and that the
        # pass pipeline treats both front-ends identically
        "bfs_embedded": (embedded.build_bfs_ecp(), g_bfs, {"root": bfs_root}),
        "pagerank": (sources.PAGERANK, g_pr, {"iters": 10}),
    }


def _batched_workloads():
    import numpy as np

    from repro.algorithms import sources
    from repro.graph import generators

    g_bfs = generators.power_law(2000, 16000, seed=0)
    g_pr = generators.power_law(2000, 16000, seed=1)
    rng = np.random.default_rng(3)
    bfs_sets = [{"root": int(r)} for r in rng.integers(0, g_bfs.n_vertices, 64)]
    pr_sets = [{"iters": int(i)} for i in rng.integers(8, 14, 8)]
    # name -> (source, graph, param sets, fatal speedup floor or None)
    return {
        "bfs_batched64": (sources.BFS_ECP, g_bfs, bfs_sets, 2.0),
        "pagerank_batched8": (sources.PAGERANK, g_pr, pr_sets, None),
    }


def _time_batched(src, graph, param_sets, floor):
    """Warm sequential-vs-batched wall times for one K-query workload."""
    import repro
    from repro.core.program import clear_program_cache

    clear_program_cache()
    program = repro.compile(src)
    session = program.bind(graph)
    batch = program.bind_batch(graph)
    # warm both paths (jit compilation out of the measurement)
    session.run(**param_sets[0])
    batch.run_many(param_sets)
    t0 = time.perf_counter()
    seq_results = [session.run(**p) for p in param_sets]
    seq_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat_results = batch.run_many(param_sets)
    bat_s = time.perf_counter() - t0
    seq_launches = sum(r.stats.total_launches for r in seq_results)
    bat_launches = bat_results[0].stats.total_launches
    out = {
        "k": len(param_sets),
        "sequential_s": round(seq_s, 4),
        "batched_s": round(bat_s, 4),
        "batched_speedup": round(seq_s / max(bat_s, 1e-9), 3),
        "launches_sequential": seq_launches,
        "launches_batched": bat_launches,
        "launch_ratio": round(bat_launches / max(seq_launches, 1), 4),
    }
    if floor is not None:
        out["speedup_floor"] = floor
    return out


def _time_warm_bind():
    """Artifact warm-start gate: cold ``repro.compile(...).bind(...).run``
    vs warm ``Accelerator.bind(...).run`` on a different graph of the same
    shape bucket. The speedup is measured within one run (same machine for
    both sides), so the >= 3x floor is machine-independent and fatal.

    The accelerator is loaded from the artifact cache directory
    (``$REPRO_ARTIFACT_DIR``, default ``~/.cache/repro-artifacts`` — CI
    persists it across runs via actions/cache) when a matching-fingerprint
    artifact exists, and lowered+saved otherwise.
    """
    import repro
    from repro.algorithms import sources
    from repro.core.accelerator import GraphShape, load_or_lower
    from repro.core.program import clear_program_cache
    from repro.core.target import Target
    from repro.graph import generators

    g_cold = generators.power_law(2000, 16000, seed=7)
    g_warm = generators.power_law(2000, 16000, seed=8)  # same bucket
    root = 1
    # cold: front-end + passes + per-bind jit compilation + first run
    clear_program_cache()
    t0 = time.perf_counter()
    repro.compile(sources.BFS_ECP).bind(g_cold).run(root=root)
    cold_s = time.perf_counter() - t0

    prog = repro.compile(sources.BFS_ECP)
    art_dir = os.environ.get(
        "REPRO_ARTIFACT_DIR", os.path.expanduser("~/.cache/repro-artifacts")
    )
    acc, loaded, lower_s = load_or_lower(
        prog, Target.from_options(prog.options), GraphShape.of(g_warm), art_dir
    )
    # prime the library's shared compacted-frontier pad buckets (the AOT
    # executables cover the full-stream path; subset buckets are lazy and
    # frontier-size dependent, so serving traffic warms them once per
    # bucket) — then time what a warm server pays per fresh bind: a shape
    # check plus ready-compiled execution
    acc.bind(g_cold).run(root=root)
    acc.bind(g_warm).run(root=root)
    t0 = time.perf_counter()
    res_w = acc.bind(g_warm).run(root=root)
    warm_s = time.perf_counter() - t0
    return {
        "cold_compile_bind_run_s": round(cold_s, 4),
        "warm_bind_run_s": round(warm_s, 4),
        "lower_or_load_s": round(lower_s, 4),
        "artifact_loaded": loaded,
        "warm_speedup": round(cold_s / max(warm_s, 1e-9), 3),
        "speedup_floor": 3.0,
        "warm_compile_time_s": round(res_w.stats.compile_time_s, 4),
    }


def _time_streaming():
    """Streaming incremental-recompute gate: after an additions-only delta
    of ~1% of |E|, a repeated BFS query answered by incremental repair must
    beat a warm full recompute by >= 3x — and must perform **zero**
    re-lowering (``stats.compile_time_s == 0``: in-bucket updates rebind
    the Accelerator's AOT executables, never recompile). Both sides are
    measured within one run on the same machine, so the floor is
    machine-independent and fatal.
    """
    import numpy as np

    import repro
    from repro.algorithms import sources
    from repro.core.program import clear_program_cache
    from repro.graph import generators
    from repro.graph.storage import GraphDelta
    from repro.streaming import StreamingSession

    clear_program_cache()
    base = generators.power_law(2000, 16000, seed=0)
    root = int(np.argmax(base.out_degree))
    program = repro.compile(sources.BFS_ECP)
    acc = program.lower(graph=base, bucket=True)
    graph = base.pad_to(acc.shape.n_vertices, acc.shape.n_edges)
    rng = np.random.default_rng(9)
    n_add = max(1, base.n_edges // 100)  # 1% edge delta
    session = StreamingSession(program, graph, accelerator=acc)
    session.run(root=root)  # warm-up: AOT executables touched, result cached

    delta = GraphDelta(added_edges=rng.integers(
        0, base.n_vertices, size=(n_add, 2)).astype(np.int32))
    t0 = time.perf_counter()
    session.update(delta)
    update_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    inc_res = session.run(root=root)  # incremental repair of the cached result
    inc_s = time.perf_counter() - t0
    assert session.incremental_runs == 1, "repair path was not taken"

    # referee: warm full recompute on the SAME updated graph (steady-state
    # best-of-3 through the same warm accelerator library)
    full_session = acc.bind(session.graph)
    full_res = full_session.run(root=root)
    full_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        full_res = full_session.run(root=root)
        full_s = min(full_s, time.perf_counter() - t0)
    identical = all(
        np.array_equal(inc_res.properties[p], full_res.properties[p])
        for p in full_res.properties
    )
    session.close()
    return {
        "n_added": n_add,
        "update_apply_s": round(update_s, 4),
        "incremental_s": round(inc_s, 4),
        "full_recompute_s": round(full_s, 4),
        "incremental_speedup": round(full_s / max(inc_s, 1e-9), 3),
        "speedup_floor": 3.0,
        "repair_compile_time_s": round(inc_res.stats.compile_time_s, 4),
        "bit_identical": identical,
    }


def _time_serving():
    """Serving-tier SLO gate (serve_mixed_slo): sustained mixed traffic —
    BFS roots, PPR seeds, and SSSP queries interleaved across two weighted
    tenants — through one ``repro.serve()`` GraphService.

    Warm-up traffic runs under a separate ``warmup`` tenant (cold
    lowerings and per-batch-size trace compilation land on its histogram,
    not the measured tenants'), then 90 deadline-carrying queries are
    submitted for tenants ``alpha`` (weight 1) and ``beta`` (weight 2) in
    closed-loop waves of 8 outstanding requests — bounded client
    concurrency keeps the measured latency about service time plus
    scheduling, not backlog wait, while per-program runs of same-group
    requests still exercise batch formation. Gates, all
    machine-independent invariants except
    the deliberately generous absolute SLO: per-tenant p99 latency must
    stay under ``slo_p99_ms``, zero queries dropped below their deadline
    (no ``DeadlineExceeded``/``Overloaded`` rejections, no misses, no
    errors), every admission completed, and exactly one lowering per
    program (the registry served all repeat traffic warm)."""
    import numpy as np

    import repro
    from repro.core.program import clear_program_cache
    from repro.graph import generators

    clear_program_cache()
    g = generators.power_law(2000, 16000, seed=4, weighted=True)
    rng = np.random.default_rng(11)
    max_batch = 2
    programs = {
        "bfs": lambda: {"root": int(rng.integers(0, g.n_vertices))},
        "ppr": lambda: {"source": int(rng.integers(0, g.n_vertices)),
                        "max_iters": 8},
        "sssp": lambda: {"root": int(rng.integers(0, g.n_vertices))},
    }
    per_burst = 15  # x 3 programs x 2 tenants = 90 measured queries
    deadline_s = 15.0
    # ~4x the locally measured tail (bfs waves tail at ~2s: K=2 bit-packed
    # multi-source batches process full edge streams per level, ~0.45s per
    # batch) — generous enough for slower CI runners, tight enough that a
    # backlog pathology (p99 ~= total elapsed, ~9s+) or a cold compile
    # leaking onto serving traffic still trips it
    slo_p99_ms = 8000.0
    with repro.serve(False, workers=2, max_batch=max_batch, max_queue=256,
                     tenant_weights={"alpha": 1.0, "beta": 2.0}) as svc:
        # warm every (program, batch-size) execution trace: BatchSession
        # compiles one XLA trace per K, so serve K=1..max_batch up front
        for name, mk in programs.items():
            svc.run(name, g, tenant="warmup", **mk())
            futs = [svc.submit(name, g, tenant="warmup", **mk())
                    for _ in range(max_batch)]
            for f in futs:
                f.result()
        jobs = [
            (name, tenant, mk())
            for name, mk in programs.items()
            for tenant in ("alpha", "beta")
            for _ in range(per_burst)
        ]
        t0 = time.perf_counter()
        done = 0
        for i in range(0, len(jobs), 8):  # closed-loop waves of 8
            wave = [
                svc.submit(name, g, tenant=tenant,
                           deadline_s=deadline_s, **params)
                for name, tenant, params in jobs[i:i + 8]
            ]
            for f in wave:
                f.result()
                done += 1
        elapsed = time.perf_counter() - t0
        snap = svc.stats()
        lowerings = svc.registry.lowerings
    tenants = {t: snap["tenants"][t] for t in ("alpha", "beta")}
    q = snap["queries"]
    return {
        "programs": sorted(programs),
        "queries": done,
        "completed_measured": sum(t["completed"] for t in tenants.values()),
        "errors": q["errors"],
        "rejected_overloaded": q["rejected_overloaded"],
        "rejected_deadline": q["rejected_deadline"],
        "deadline_misses": q["deadline_misses"],
        "deadline_s": deadline_s,
        "p99_ms": round(max(t["latency_ms"]["p99_ms"]
                            for t in tenants.values()), 3),
        "p50_ms": round(max(t["latency_ms"]["p50_ms"]
                            for t in tenants.values()), 3),
        "slo_p99_ms": slo_p99_ms,
        "throughput_qps": round(done / max(elapsed, 1e-9), 1),
        "batch_occupancy": snap["batches"]["occupancy"],
        "lowerings": lowerings,
        "expected_lowerings": len(programs),
    }


def _time_telemetry():
    """Tracing-overhead gate (telemetry_overhead): the telemetry subsystem
    must be effectively free. Three measurements on one warm BFS session:

    * **untraced**: best-of-5 warm runs with the default null tracer.
    * **traced**: best-of-5 warm runs under ``repro.telemetry.enable()``
      — full span capture (run + per-launch spans with frontier
      occupancy attributes). Gated at <= 1.05x untraced (with the usual
      absolute-delta jitter guard); the final traced run is exported as
      a Chrome ``trace_event`` file (``BENCH_trace.json``, uploaded as a
      CI artifact).
    * **null path**: the disabled hot path is one tracer lookup plus an
      ``enabled`` check per launch site — measured directly over 200k
      iterations and scaled by the traced run's span count, it must
      imply <= 1.01x overhead on the untraced wall time. Measuring the
      per-op cost instead of differencing two noisy wall times keeps
      this sub-percent gate deterministic.
    """
    import numpy as np

    import repro
    from repro import telemetry as tel
    from repro.algorithms import sources
    from repro.core.program import clear_program_cache
    from repro.graph import generators

    clear_program_cache()
    tel.disable()
    g = generators.power_law(2000, 16000, seed=0)
    root = int(np.argmax(g.out_degree))
    session = repro.compile(sources.BFS_ECP).bind(g)
    session.run(root=root)  # warm: jit compilation out of the measurement

    reps = 5
    untraced_s = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        session.run(root=root)
        untraced_s = min(untraced_s, time.perf_counter() - t0)

    trace_path = os.environ.get("REPRO_BENCH_TRACE", "BENCH_trace.json")
    tel.enable()
    try:
        traced_s = float("inf")
        spans_per_run = 0
        for _ in range(reps):
            tr = tel.get()
            tr.reset()
            t0 = time.perf_counter()
            session.run(root=root)
            traced_s = min(traced_s, time.perf_counter() - t0)
            spans_per_run = max(spans_per_run, len(tr.spans()))
        # the last traced run's spans become the CI trace artifact
        trace_events = tel.get().export_chrome(trace_path)
    finally:
        tel.disable()

    # null-path microbench: what every traced call site pays when tracing
    # is off. Differencing two wall-time runs cannot resolve a <= 1% gate
    # through runner noise; per-op cost x span count can.
    n_ops = 200_000
    t0 = time.perf_counter()
    for _ in range(n_ops):
        if tel.get().enabled:
            raise AssertionError("tracer must be disabled here")
    null_op_s = (time.perf_counter() - t0) / n_ops
    null_ratio = 1.0 + spans_per_run * null_op_s / max(untraced_s, 1e-9)

    return {
        "untraced_s": round(untraced_s, 4),
        "traced_s": round(traced_s, 4),
        "traced_ratio": round(traced_s / max(untraced_s, 1e-9), 4),
        "overhead_ceiling": 1.05,
        "spans_per_run": spans_per_run,
        "null_op_ns": round(null_op_s * 1e9, 1),
        "null_ratio": round(null_ratio, 6),
        "null_ceiling": 1.01,
        "trace_events": trace_events,
        "trace_path": trace_path,
    }


def _time_autotune():
    """Autotuning gate (autotune_bfs): the full repro.autotune story on
    one workload where the knob choice is structural, not noise.

    The probe is BFS on a deep multigraph (200-level chain, 1000 parallel
    edges per hop): frontiers stay single-vertex while full-edge streaming
    pays ~400k edges per level, so ``compact_frontier`` Targets win by a
    wide, machine-independent margin (~200x fewer edges traversed).
    Measures and gates:

    * the search finds a tuned Target whose interleaved best-of-5 warm
      wall time beats ``Target.baseline()`` by >= 1.15x (fatal, within-run
      paired comparison);
    * a fresh TuningCache over the same store (the fresh-process
      analogue) resolves the config with **zero** search trials and >= 1
      cache hit (fatal);
    * the winner's accelerator stamps the config into its artifact
      manifest and ``load_accelerator`` restores it bit-identically
      (fatal);
    * a ``repro.serve()`` service over the same store resolves the tuned
      Target on submission — ``programs.bfs.tuned_hits >= 1`` (fatal).
    """
    import shutil
    import tempfile

    import repro
    from repro.autotune import AutoTuner, TuningCache, tuning_dir_for
    from repro.core.accelerator import load_accelerator
    from repro.core.program import clear_program_cache
    from repro.core.target import Target
    from repro.graph import generators
    from repro.serving.service import NAMED_ALGORITHMS

    clear_program_cache()
    store = tempfile.mkdtemp(prefix="repro-bench-autotune-")
    try:
        g = generators.deep_chain(200, multiplicity=1000)
        program = repro.compile(NAMED_ALGORITHMS["bfs"])
        params = {"root": 0}

        tuner = AutoTuner(TuningCache(tuning_dir_for(store)),
                          reps=2, max_candidates=6)
        t0 = time.perf_counter()
        report = tuner.tune(program, g, params=params)
        search_s = time.perf_counter() - t0

        # fresh-process analogue: a new cache instance over the same
        # store must resolve the config from disk with zero trials
        warm_cache = TuningCache(tuning_dir_for(store))
        warm = AutoTuner(warm_cache).tune(program, g, params=params)

        # paired steady-state: tuned vs Target.baseline(), interleaved
        # best-of-5 warm wall times (interleaving cancels runner drift)
        base_target = replace(
            Target.baseline(), kind=report.config.target.kind
        )
        tuned_acc = report.accelerator
        if tuned_acc is None:  # pragma: no cover - search always sets it
            tuned_acc = program.lower(report.config.target, graph=g)
        base_acc = program.lower(base_target, graph=g)
        tuned_sess = tuned_acc.bind(g)
        base_sess = base_acc.bind(g)
        tuned_res = tuned_sess.run(**params)   # warm both paths
        base_res = base_sess.run(**params)
        tuned_s = base_s = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            tuned_sess.run(**params)
            tuned_s = min(tuned_s, time.perf_counter() - t0)
            t0 = time.perf_counter()
            base_sess.run(**params)
            base_s = min(base_s, time.perf_counter() - t0)
        tuned_sess.close()
        base_sess.close()

        # artifact manifest round trip
        art_dir = tuned_acc.save(os.path.join(store, "bfs-tuned"))
        loaded = load_accelerator(art_dir)
        manifest_roundtrip = loaded.tuned == report.config.to_dict()

        # serving resolves the tuned Target by lookup on every submit
        with repro.serve(store, workers=1) as svc:
            svc.run("bfs", g, **params)
            snap = svc.stats()
        service_tuned_hits = snap["programs"]["bfs"]["tuned_hits"]

        return {
            "tuned_target": report.config.target.describe(),
            "search_s": round(search_s, 3),
            "trials_search": report.trials,
            "candidates": report.candidates,
            "objective_s": round(report.config.objective_s, 4),
            "tuned_steady_s": round(tuned_s, 4),
            "baseline_steady_s": round(base_s, 4),
            "tuned_speedup": round(base_s / max(tuned_s, 1e-9), 3),
            "speedup_floor": 1.15,
            "edges_tuned": int(tuned_res.stats.edges_traversed),
            "edges_baseline": int(base_res.stats.edges_traversed),
            "trials_cached": warm.trials,
            "cache_hits": warm_cache.hits,
            "manifest_roundtrip": manifest_roundtrip,
            "service_tuned_hits": service_tuned_hits,
        }
    finally:
        shutil.rmtree(store, ignore_errors=True)


def _time_workload(src, graph, params, options):
    """(cold compile+bind+first-run seconds, warm best-of-3 seconds, stats)."""
    import repro
    from repro.core.program import clear_program_cache

    clear_program_cache()
    t0 = time.perf_counter()
    session = repro.compile(src, options).bind(graph)
    res = session.run(**params)
    compile_run_s = time.perf_counter() - t0

    steady = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        res = session.run(**params)
        steady = min(steady, time.perf_counter() - t0)
    return compile_run_s, steady, res.stats


def measure() -> dict:
    from repro.core import CompileOptions

    opts_on = CompileOptions.full()
    opts_off = replace(opts_on, passes="none")
    out = {
        "meta": {
            "python": platform.python_version(),
            "machine": platform.machine(),
            # wall times only gate hard against a baseline measured on the
            # same runner class; "local" baselines make them advisory
            "source": "ci" if os.environ.get("GITHUB_ACTIONS") else "local",
        },
        "workloads": {},
    }
    for name, (src, graph, params) in _workloads().items():
        compile_run_s, steady_s, stats_on = _time_workload(src, graph, params, opts_on)
        _, _, stats_off = _time_workload(src, graph, params, opts_off)
        launches_on = stats_on.total_launches
        launches_off = stats_off.total_launches
        out["workloads"][name] = {
            "compile_run_s": round(compile_run_s, 4),
            "steady_s": round(steady_s, 4),
            "launches_passes_on": launches_on,
            "launches_passes_off": launches_off,
            "launch_reduction": round(launches_off / max(launches_on, 1), 3),
            "fused_launches": stats_on.fused_launches,
        }
    out["batched"] = {}
    for name, (src, graph, sets, floor) in _batched_workloads().items():
        out["batched"][name] = _time_batched(src, graph, sets, floor)
    out["warm_bind"] = {"bfs_warm_bind": _time_warm_bind()}
    out["streaming"] = {"bfs_incremental": _time_streaming()}
    out["serving"] = {"serve_mixed_slo": _time_serving()}
    out["telemetry"] = {"telemetry_overhead": _time_telemetry()}
    out["autotune"] = {"autotune_bfs": _time_autotune()}
    return out


# a wall-time "regression" below this absolute delta is runner jitter, not
# a signal — millisecond-scale steady-state times on shared CI runners can
# easily move 1.5x without any code change
MIN_REGRESSION_DELTA_S = 0.05


def check(ci: dict, baseline: dict, threshold: float) -> int:
    failures = []
    base_wl = baseline.get("workloads", {})
    ci_wl = ci.get("workloads", {})
    # absolute wall times are only comparable within one runner class: a
    # baseline not measured on CI (source != "ci") arms the wall-time gate
    # in advisory mode — regressions are reported but non-fatal — until a
    # CI-produced bench-ci artifact replaces the committed baseline; the
    # machine-independent launch-reduction floor is always fatal
    walltime_fatal = baseline.get("meta", {}).get("source") == "ci"
    warnings = []
    # every measured workload must be gated: a workload added to
    # _workloads() without refreshing the committed baseline fails loudly
    # instead of silently shipping ungated
    for name in sorted(set(ci_wl) - set(base_wl)):
        failures.append(
            f"{name}: measured but absent from the baseline — refresh "
            f"BENCH_baseline.json to gate it"
        )
    for name, base in base_wl.items():
        got = ci_wl.get(name)
        if got is None:
            failures.append(f"{name}: missing from current run")
            continue
        for key in ("compile_run_s", "steady_s"):
            if key not in got or key not in base:
                failures.append(f"{name}.{key}: metric missing "
                                f"(ci={key in got}, baseline={key in base})")
                continue
            ratio = got[key] / max(base[key], 1e-9)
            delta = got[key] - base[key]
            line = (f"{name}.{key}: {got[key]:.4f}s vs baseline "
                    f"{base[key]:.4f}s ({ratio:.2f}x)")
            if ratio > threshold and delta > MIN_REGRESSION_DELTA_S:
                if walltime_fatal:
                    failures.append(f"REGRESSION {line} > {threshold}x")
                else:
                    warnings.append(
                        f"WARNING {line} > {threshold}x (advisory: baseline "
                        f"was not measured on a CI runner)"
                    )
            else:
                print(f"ok   {line}")
        lr = got.get("launch_reduction", 0.0)
        if lr < LAUNCH_REDUCTION_FLOOR:
            failures.append(
                f"REGRESSION {name}.launch_reduction: {lr:.2f}x < "
                f"{LAUNCH_REDUCTION_FLOOR}x acceptance floor"
            )
        else:
            print(f"ok   {name}.launch_reduction: {lr:.2f}x "
                  f"(floor {LAUNCH_REDUCTION_FLOOR}x)")
    # batched execution gates: the speedup and launch ratios are measured
    # within one run (same machine for both sides), so floors are fatal
    # regardless of where the baseline came from
    base_batched = baseline.get("batched", {})
    ci_batched = ci.get("batched", {})
    for name in sorted(set(ci_batched) - set(base_batched)):
        failures.append(
            f"{name}: batched workload measured but absent from the baseline "
            f"— refresh BENCH_baseline.json to gate it"
        )
    for name in sorted(base_batched):
        got = ci_batched.get(name)
        if got is None:
            failures.append(f"{name}: batched workload missing from current run")
            continue
        speedup = got.get("batched_speedup", 0.0)
        floor = got.get("speedup_floor") or base_batched[name].get("speedup_floor")
        line = (f"{name}.batched_speedup: {speedup:.2f}x over sequential "
                f"(K={got.get('k')}, launch_ratio={got.get('launch_ratio')})")
        if floor is not None and speedup < floor:
            failures.append(f"REGRESSION {line} < {floor}x acceptance floor")
        else:
            print(f"ok   {line}")
    # accelerator warm-start gates: within-run speedups, floors always fatal
    base_warm = baseline.get("warm_bind", {})
    ci_warm = ci.get("warm_bind", {})
    for name in sorted(set(ci_warm) - set(base_warm)):
        failures.append(
            f"{name}: warm-bind workload measured but absent from the "
            f"baseline — refresh BENCH_baseline.json to gate it"
        )
    for name in sorted(base_warm):
        got = ci_warm.get(name)
        if got is None:
            failures.append(f"{name}: warm-bind workload missing from current run")
            continue
        speedup = got.get("warm_speedup", 0.0)
        floor = got.get("speedup_floor") or base_warm[name].get("speedup_floor")
        line = (f"{name}.warm_speedup: {speedup:.2f}x "
                f"(cold {got.get('cold_compile_bind_run_s')}s vs warm bind+run "
                f"{got.get('warm_bind_run_s')}s, artifact_loaded="
                f"{got.get('artifact_loaded')})")
        if floor is not None and speedup < floor:
            failures.append(f"REGRESSION {line} < {floor}x acceptance floor")
        else:
            print(f"ok   {line}")
    # streaming incremental gates: within-run speedup + the zero-re-lowering
    # and bit-identity invariants; all machine-independent, always fatal
    base_stream = baseline.get("streaming", {})
    ci_stream = ci.get("streaming", {})
    for name in sorted(set(ci_stream) - set(base_stream)):
        failures.append(
            f"{name}: streaming workload measured but absent from the "
            f"baseline — refresh BENCH_baseline.json to gate it"
        )
    for name in sorted(base_stream):
        got = ci_stream.get(name)
        if got is None:
            failures.append(f"{name}: streaming workload missing from current run")
            continue
        speedup = got.get("incremental_speedup", 0.0)
        floor = got.get("speedup_floor") or base_stream[name].get("speedup_floor")
        line = (f"{name}.incremental_speedup: {speedup:.2f}x over full "
                f"recompute (repair {got.get('incremental_s')}s vs "
                f"{got.get('full_recompute_s')}s after "
                f"{got.get('n_added')} added edges)")
        if floor is not None and speedup < floor:
            failures.append(f"REGRESSION {line} < {floor}x acceptance floor")
        else:
            print(f"ok   {line}")
        if got.get("repair_compile_time_s", 0.0) != 0.0:
            failures.append(
                f"REGRESSION {name}: incremental repair re-lowered kernels "
                f"(compile_time_s={got.get('repair_compile_time_s')}, "
                f"expected 0 — in-bucket updates must be rebind-only)"
            )
        else:
            print(f"ok   {name}.repair_compile_time_s: 0 (rebind-only)")
        if not got.get("bit_identical", False):
            failures.append(
                f"REGRESSION {name}: incremental result diverged from "
                f"full recompute"
            )
        else:
            print(f"ok   {name}.bit_identical: true")
    # serving-tier SLO gates: admission/deadline/error invariants are exact
    # and always fatal; the p99 SLO ceiling is deliberately generous (orders
    # of magnitude above warm per-query latency) so it gates pathologies —
    # cold compiles leaking onto serving traffic, scheduler stalls — not
    # runner speed
    base_serve = baseline.get("serving", {})
    ci_serve = ci.get("serving", {})
    for name in sorted(set(ci_serve) - set(base_serve)):
        failures.append(
            f"{name}: serving workload measured but absent from the "
            f"baseline — refresh BENCH_baseline.json to gate it"
        )
    for name in sorted(base_serve):
        got = ci_serve.get(name)
        if got is None:
            failures.append(f"{name}: serving workload missing from current run")
            continue
        p99 = got.get("p99_ms", float("inf"))
        slo = got.get("slo_p99_ms") or base_serve[name].get("slo_p99_ms")
        line = (f"{name}.p99_ms: {p99:.1f}ms "
                f"(p50 {got.get('p50_ms')}ms, "
                f"{got.get('throughput_qps')} qps, "
                f"occupancy {got.get('batch_occupancy')})")
        if slo is not None and p99 > slo:
            failures.append(f"REGRESSION {line} > {slo}ms SLO ceiling")
        else:
            print(f"ok   {line} (SLO {slo}ms)")
        dropped = (
            got.get("rejected_deadline", 0) + got.get("rejected_overloaded", 0)
            + got.get("deadline_misses", 0) + got.get("errors", 0)
        )
        if dropped:
            failures.append(
                f"REGRESSION {name}: {dropped} queries dropped/late "
                f"(rejected_deadline={got.get('rejected_deadline')}, "
                f"rejected_overloaded={got.get('rejected_overloaded')}, "
                f"deadline_misses={got.get('deadline_misses')}, "
                f"errors={got.get('errors')}) — expected 0 under this load"
            )
        else:
            print(f"ok   {name}: zero rejections, misses, and errors")
        if got.get("completed_measured") != got.get("queries"):
            failures.append(
                f"REGRESSION {name}: {got.get('completed_measured')}/"
                f"{got.get('queries')} admitted queries completed"
            )
        else:
            print(f"ok   {name}.completed: {got.get('completed_measured')}"
                  f"/{got.get('queries')}")
        if got.get("lowerings") != got.get("expected_lowerings"):
            failures.append(
                f"REGRESSION {name}: {got.get('lowerings')} lowerings for "
                f"{got.get('expected_lowerings')} programs — repeat serving "
                f"traffic must reuse resident sessions, not re-lower"
            )
        else:
            print(f"ok   {name}.lowerings: {got.get('lowerings')} "
                  f"(one per program)")
    # telemetry overhead gates: traced-vs-untraced is a within-run ratio
    # (same machine, same warm session) with the absolute-delta jitter
    # guard; the null-tracer ratio is derived from a per-op microbench and
    # is deterministic — both always fatal
    base_tel = baseline.get("telemetry", {})
    ci_tel = ci.get("telemetry", {})
    for name in sorted(set(ci_tel) - set(base_tel)):
        failures.append(
            f"{name}: telemetry workload measured but absent from the "
            f"baseline — refresh BENCH_baseline.json to gate it"
        )
    for name in sorted(base_tel):
        got = ci_tel.get(name)
        if got is None:
            failures.append(f"{name}: telemetry workload missing from current run")
            continue
        ratio = got.get("traced_ratio", float("inf"))
        ceiling = got.get("overhead_ceiling") or base_tel[name].get("overhead_ceiling")
        delta = got.get("traced_s", 0.0) - got.get("untraced_s", 0.0)
        line = (f"{name}.traced_ratio: {ratio:.3f}x "
                f"(traced {got.get('traced_s')}s vs untraced "
                f"{got.get('untraced_s')}s, {got.get('spans_per_run')} "
                f"spans/run)")
        if ceiling is not None and ratio > ceiling and delta > MIN_REGRESSION_DELTA_S:
            failures.append(f"REGRESSION {line} > {ceiling}x ceiling")
        else:
            print(f"ok   {line} (ceiling {ceiling}x)")
        null_ratio = got.get("null_ratio", float("inf"))
        null_ceiling = got.get("null_ceiling") or base_tel[name].get("null_ceiling")
        nline = (f"{name}.null_ratio: {null_ratio:.6f}x "
                 f"({got.get('null_op_ns')}ns per disabled call site)")
        if null_ceiling is not None and null_ratio > null_ceiling:
            failures.append(f"REGRESSION {nline} > {null_ceiling}x ceiling")
        else:
            print(f"ok   {nline} (ceiling {null_ceiling}x)")
        if not got.get("trace_events"):
            failures.append(
                f"REGRESSION {name}: traced run exported no Chrome trace "
                f"events (expected a non-empty {got.get('trace_path')})"
            )
        else:
            print(f"ok   {name}.trace_events: {got.get('trace_events')} "
                  f"-> {got.get('trace_path')}")
    # autotuning gates: the tuned-vs-baseline speedup is a within-run
    # interleaved paired measurement on a structurally-differentiated
    # workload, and the cache/manifest/serving reuse checks are exact
    # invariants — all always fatal
    base_tune = baseline.get("autotune", {})
    ci_tune = ci.get("autotune", {})
    for name in sorted(set(ci_tune) - set(base_tune)):
        failures.append(
            f"{name}: autotune workload measured but absent from the "
            f"baseline — refresh BENCH_baseline.json to gate it"
        )
    for name in sorted(base_tune):
        got = ci_tune.get(name)
        if got is None:
            failures.append(f"{name}: autotune workload missing from current run")
            continue
        speedup = got.get("tuned_speedup", 0.0)
        floor = got.get("speedup_floor") or base_tune[name].get("speedup_floor")
        line = (f"{name}.tuned_speedup: {speedup:.2f}x over Target.baseline() "
                f"(tuned {got.get('tuned_steady_s')}s [{got.get('tuned_target')}] "
                f"vs baseline {got.get('baseline_steady_s')}s, "
                f"{got.get('edges_tuned')} vs {got.get('edges_baseline')} "
                f"edges traversed)")
        if floor is not None and speedup < floor:
            failures.append(f"REGRESSION {line} < {floor}x acceptance floor")
        else:
            print(f"ok   {line} (floor {floor}x)")
        if got.get("trials_cached", -1) != 0 or got.get("cache_hits", 0) < 1:
            failures.append(
                f"REGRESSION {name}: fresh TuningCache re-resolution ran "
                f"{got.get('trials_cached')} trial(s) with "
                f"{got.get('cache_hits')} hit(s) — a persisted config must "
                f"reuse with zero search"
            )
        else:
            print(f"ok   {name}: warm re-resolution trials=0, "
                  f"cache_hits={got.get('cache_hits')} "
                  f"(search was {got.get('trials_search')} trial(s) in "
                  f"{got.get('search_s')}s)")
        if not got.get("manifest_roundtrip", False):
            failures.append(
                f"REGRESSION {name}: tuned config did not survive "
                f"Accelerator.save/load_accelerator (manifest stamp "
                f"mismatch)"
            )
        else:
            print(f"ok   {name}.manifest_roundtrip: true")
        if got.get("service_tuned_hits", 0) < 1:
            failures.append(
                f"REGRESSION {name}: serving resolved "
                f"{got.get('service_tuned_hits')} tuned Target(s) — "
                f"GraphService must pick persisted configs on submission"
            )
        else:
            print(f"ok   {name}.service_tuned_hits: "
                  f"{got.get('service_tuned_hits')}")
    for w in warnings:
        print(w)
    for f in failures:
        print(f, file=sys.stderr)
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_ci.json", help="measurement output path")
    ap.add_argument("--check", action="store_true",
                    help="compare --ci against --baseline instead of measuring")
    ap.add_argument("--ci", default="BENCH_ci.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="max allowed wall-time regression ratio")
    args = ap.parse_args(argv)

    if args.check:
        with open(args.ci) as f:
            ci = json.load(f)
        with open(args.baseline) as f:
            baseline = json.load(f)
        return check(ci, baseline, args.threshold)

    results = measure()
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(results, indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
