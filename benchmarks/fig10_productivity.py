"""Paper §IV-C / Fig. 10: design productivity — lines of code and
compilation time (code generation + kernel synthesis analogue)."""
from __future__ import annotations

import inspect
import time

from repro.core import CompileOptions, clear_program_cache, compile_program
from repro.graph.datasets import make_dataset
from repro.algorithms import sources
from repro.baselines import thundergp

from .common import csv_line


def _loc(text: str) -> int:
    return sum(
        1
        for ln in text.splitlines()
        if ln.strip() and not ln.strip().startswith("%") and not ln.strip().startswith("#")
    )


def main() -> list:
    lines = []
    # code length: one self-contained DSL file per algorithm vs the
    # template-side code a ThunderGP user must own (our faithful template
    # module stands in for the >=5 ThunderGP application files)
    tgp_loc = _loc(inspect.getsource(thundergp))
    for name in ("BFS_ECP", "PAGERANK", "SSSP", "PPR", "CGAW"):
        src = getattr(sources, name)
        lines.append(
            csv_line(
                f"fig10.loc.{name}", 0.0,
                f"dsl_loc={_loc(src)};template_engine_loc={tgp_loc};files=1_vs_5+",
            )
        )
    # code generation time: source -> Program (the paper reports 0.115 s);
    # drop the content-hash cache so each compile is a real front-end run
    clear_program_cache()
    t0 = time.perf_counter()
    for name in ("BFS_ECP", "PAGERANK", "SSSP", "PPR", "CGAW"):
        compile_program(getattr(sources, name))
    gen_s = (time.perf_counter() - t0) / 5
    lines.append(csv_line("fig10.codegen", gen_s * 1e6, f"per_algorithm_s={gen_s:.4f}"))
    # "synthesis" analogue: bind + jit compilation of every kernel launch
    # path — exactly what the first session.run() pays
    g = make_dataset("AM", scale=0.002, seed=0)
    t0 = time.perf_counter()
    session = compile_program(sources.BFS_ECP, CompileOptions.full()).bind(g)
    session.run(root=0)  # triggers jit compilation of every launch path
    synth_s = time.perf_counter() - t0
    lines.append(csv_line("fig10.synthesis.BFS", synth_s * 1e6, f"end_to_end_s={synth_s:.2f}"))
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
