"""Compile and run a standalone .gt file against a Table II dataset.

    PYTHONPATH=src python examples/run_gt_file.py examples/algos/pagerank.gt
"""
import sys

import numpy as np

import repro
from repro.graph.datasets import make_dataset


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "examples/algos/pagerank.gt"
    weighted = any(w in path for w in ("sssp", "cgaw"))
    program = repro.compile(open(path).read())
    g = make_dataset("AM", scale=0.01, seed=0, weighted=weighted)
    session = program.bind(g, argv=["prog", "AM"])
    res = session.run()
    print(f"{path}: ran on |V|={g.n_vertices} |E|={g.n_edges} "
          f"in {res.stats.wall_time_s:.3f}s, launches={res.stats.kernel_launches}")
    for name, arr in list(res.properties.items())[:4]:
        arr = np.asarray(arr)
        print(f"  {name}: shape={arr.shape} min={arr.min():.4g} max={arr.max():.4g}")


if __name__ == "__main__":
    main()
