"""Multi-chip graph processing through the Program/Session API: the same
compiled PageRank program bound to the local and distributed backends,
with the paper's shuffle network generalized to cross-chip all_to_all.

    PYTHONPATH=src python examples/distributed_graph.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

import repro
from repro.algorithms import sources
from repro.graph import generators


def main():
    g = generators.power_law(20_000, 300_000, seed=0)
    program = repro.compile(sources.PAGERANK)
    print(f"|V|={g.n_vertices} |E|={g.n_edges}; "
          f"params: {', '.join(p.describe() for p in program.params.values())}")

    # one Program, two backends — the algorithm text never changes
    local = program.bind(g, backend="local")
    dist = program.bind(g, backend="distributed")

    r_local = local.run(iters=20)
    r_dist = dist.run(iters=20)
    a = r_local.properties["rank"]
    b = r_dist.properties["rank"]
    err = np.abs(a - b).max() / a.max()
    print(f"20 PageRank supersteps across {len(jax.devices())} chips: "
          f"max rel err local vs distributed = {err:.2e}")
    assert err < 1e-3

    # independent numpy oracle (not sharing any engine code with the above)
    deg = g.out_degree.astype(np.float64)
    want = np.full(g.n_vertices, 1.0 / g.n_vertices)
    for _ in range(20):
        c = np.zeros(g.n_vertices)
        ok = deg[g.src] > 0
        np.add.at(c, g.dst,
                  np.where(ok, want[g.src] / np.maximum(deg[g.src], 1), 0.0))
        want = 0.15 / g.n_vertices + 0.85 * c
    oracle_err = np.abs(b - want).max() / want.max()
    print(f"max rel err vs independent numpy oracle = {oracle_err:.2e}")
    assert oracle_err < 1e-3
    print(f"distributed supersteps: {r_dist.stats.dist_supersteps} "
          f"(edge kernel launches routed through the cross-chip shuffle)")
    top = np.argsort(-b)[:5]
    print("top-5 vertices:", top.tolist())


if __name__ == "__main__":
    main()
