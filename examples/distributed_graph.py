"""Multi-chip graph processing: PageRank over 8 (emulated) devices with the
paper's shuffle network generalized to cross-chip all_to_all.

    PYTHONPATH=src python examples/distributed_graph.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import generators
from repro.core.dist_engine import partition_graph, make_push_step


def main():
    g = generators.power_law(20_000, 300_000, seed=0)
    mesh = jax.make_mesh((8,), ("data",))
    dg = partition_graph(g, mesh)
    print(f"|V|={g.n_vertices} |E|={g.n_edges} on {dg.n_devices} devices "
          f"(bucket pad {dg.src_local.shape[-1]})")

    deg = np.maximum(g.out_degree, 1).astype(np.float32)
    n = dg.n_vertices_padded
    step = make_push_step(dg, lambda sv, w: sv, "+")

    rank = np.full(n, 0.0, np.float32)
    rank[: g.n_vertices] = 1.0 / g.n_vertices
    damp = 0.85
    degp = np.ones(n, np.float32)
    degp[: g.n_vertices] = deg

    with mesh:
        r = jnp.asarray(rank)
        dp = jnp.asarray(degp)
        for it in range(20):
            contrib = step(r / dp)
            r = 0.15 / g.n_vertices + damp * contrib
        out = np.asarray(r)[: g.n_vertices]

    # verify against the single-device oracle
    want = np.full(g.n_vertices, 1.0 / g.n_vertices)
    for _ in range(20):
        c = np.zeros(g.n_vertices)
        np.add.at(c, g.dst, want[g.src] / deg[g.src])
        want = 0.15 / g.n_vertices + damp * c
    err = np.abs(out - want).max() / want.max()
    print(f"20 PageRank supersteps across 8 chips: max rel err vs oracle = {err:.2e}")
    assert err < 1e-3
    top = np.argsort(-out)[:5]
    print("top-5 vertices:", top.tolist())


if __name__ == "__main__":
    main()
