"""Tracing & profiling quickstart: one switch turns every stage of the
pipeline into a span tree you can open in chrome://tracing or Perfetto.

    PYTHONPATH=src python examples/trace_profile.py

The workflow is:

    repro.telemetry.enable()          # process-wide tracer (off by default)
    ... compile / lower / bind / run  # stages emit nested spans
    tracer.export_chrome("trace.json")  # open in chrome://tracing

Every traced run also feeds the accelerator's persistent profile:
`accelerator.report().profile` accumulates per-kernel wall time across
runs and is saved with the artifact, so a warm-started process inherits
the profiling baseline of the process that built it.
"""
import os
import tempfile

import repro
from repro import telemetry
from repro.algorithms import sources
from repro.graph import generators


def main():
    telemetry.enable()

    graph = generators.power_law(5_000, 60_000, seed=0)

    # compile -> lower -> bind -> run, all under the tracer
    program = repro.compile(sources.BFS_ECP)
    target = repro.Target()
    acc = program.lower(target, shape=repro.GraphShape.of(graph))
    session = acc.bind(graph)
    result = session.run(root=3)

    # 1. per-run summary rides on the result itself
    trace = result.trace
    print("=== per-run trace summary (result.trace) ===")
    print(f"spans in this run: {trace['span_count']}, "
          f"wall: {trace['total_s'] * 1e3:.1f}ms")

    # 2. top-5 hottest kernels by traced wall time
    launches = {
        name[len("launch:"):]: agg
        for name, agg in trace["spans"].items()
        if name.startswith("launch:")
    }
    print("\n=== top-5 hottest kernels ===")
    ranked = sorted(launches.items(), key=lambda kv: -kv[1]["total_s"])
    for name, agg in ranked[:5]:
        print(f"  {name:>24}: {agg['total_s'] * 1e3:8.1f}ms "
              f"over {agg['count']} launch(es) "
              f"(max {agg['max_s'] * 1e3:.1f}ms)")

    # 3. the accelerator's profile section accumulates across runs and is
    #    persisted with the artifact (warm starts inherit it)
    session.run(root=17)
    report = acc.report()
    print(f"\nprofile: {report.profile['runs']} traced run(s) folded into "
          f"accelerator {acc.fingerprint[:12]}")

    with tempfile.TemporaryDirectory() as d:
        acc.save(f"{d}/bfs")
        loaded = repro.load_accelerator(f"{d}/bfs")
        inherited = loaded.report().profile
        print(f"warm-started profile baseline: {inherited['runs']} run(s), "
              f"{len(inherited['spans'])} span name(s) inherited")

        # 4. export the whole session as a Chrome trace
        out = os.path.join(d, "trace.json")
        n = telemetry.get().export_chrome(out)
        size = os.path.getsize(out)
        print(f"\nexported {n} events ({size} bytes) -> {out}")
        print("open chrome://tracing or https://ui.perfetto.dev and load it")

    # 5. Prometheus-style exposition of the same histograms
    text = telemetry.get().prometheus_text()
    print("\n=== prometheus exposition (first 6 lines) ===")
    print("\n".join(text.splitlines()[:6]))

    telemetry.disable()  # back to the zero-overhead null tracer


if __name__ == "__main__":
    main()
