"""Quickstart for the embedded Python front-end: author BFS with
@vertex_kernel/@edge_kernel decorators instead of a `.gt` source string.

    PYTHONPATH=src python examples/embedded_bfs.py

Two front-ends, one compiler: the decorated functions below are lowered
from the Python AST into the exact MIR the text parser produces, so the
embedded program and its textual twin share one compiled-Program cache
entry and produce bit-identical results. You get IDE completion, linting
over real names, and host-language composition (the `INF` constant is a
captured Python value, inlined at lowering time) — with zero string
templating.
"""
import numpy as np

import repro
from repro.frontend import GraphProgram
from repro.graph import generators

# every handle is an ordinary Python object: rename them, pass them to
# helper functions, build programs in loops — it is all just Python
p = GraphProgram("bfs")
edges = p.edgeset("edges")
vertices = p.vertexset("vertices")
old_level = p.vertex_prop("old_level", int)
new_level = p.vertex_prop("new_level", int)
tuple_ = p.vertex_prop("tuple", int)  # Python name != DSL name is fine
level = p.scalar("level", int, init=1)
activeVertex = p.vertex_prop("activeVertex", int)
root = p.scalar("root", int, init=0)  # a declared run-time parameter

INF = 2147483647  # captured Python constant, inlined as a literal


@p.vertex_kernel
def reset(v):
    old_level[v] = -1
    new_level[v] = -1
    tuple_[v] = INF


@p.edge_kernel
def EdgeTraversal(src, dst):
    if old_level[src] == level:
        # the Pythonic spelling of the DSL's `tuple[dst] min= level + 1;`
        tuple_[dst] = min(tuple_[dst], level + 1)


@p.vertex_kernel
def VertexUpdate(v):
    if (tuple_[v] == level + 1) and (old_level[v] == -1):
        new_level[v] = tuple_[v]
        activeVertex[0] = activeVertex[0] + 1


@p.vertex_kernel
def VertexApply(v):
    old_level[v] = new_level[v]


@p.main
def main_loop():
    vertices.init(reset)
    old_level[root] = 1
    new_level[root] = 1
    frontier_size: int = 1
    while frontier_size:
        edges.process(EdgeTraversal)
        vertices.process(VertexUpdate)
        vertices.process(VertexApply)
        frontier_size = activeVertex[0]
        activeVertex[0] = 0
        level += 1


def main():
    graph = generators.power_law(5_000, 60_000, seed=0)

    # 1. compile — same pipeline, same cache as repro.compile(".gt text")
    program = repro.compile(p)  # default options: full optimization
    print("=== MIR (identical to the text front-end's) ===")
    print(program.describe())
    print("\ndeclared parameters:",
          ", ".join(s.describe() for s in program.params.values()))

    # 2. the embedded program also emits its own `.gt` text...
    print("\n=== to_source() round-trip ===")
    print("\n".join(program.source.splitlines()[:6]) + "\n...")
    twin = repro.compile(p.to_source())
    print("text twin shares the cache entry:", twin is program)

    # 3. bind + run exactly like any Program
    hub = int(np.argmax(graph.out_degree))
    result = program.bind(graph).run(root=hub)
    levels = result.properties["old_level"]
    reached = int((levels > 0).sum())
    print(f"\nBFS from hub {hub}: reached {reached}/{graph.n_vertices} "
          f"vertices, max level {int(levels.max())}")
    assert levels[hub] == 1 and reached > 1

    # different root, same warm session semantics
    r2 = program.bind(graph).run(root=0)
    print(f"BFS from 0: reached {int((r2.properties['old_level'] > 0).sum())} "
          f"vertices")


if __name__ == "__main__":
    main()
