"""Quickstart for batched multi-query execution: one compiled program, one
resident graph, K parameterized queries per launch set.

    PYTHONPATH=src python examples/batched_queries.py

`Session.run` answers one query per execution; `program.bind_batch(graph)`
returns a `BatchSession` whose `run_many` answers a whole list of
parameter bindings at once — properties gain a leading batch axis, host
control flow runs with per-query active masks (queries that converge early
stop contributing work), and BFS-like frontier programs automatically take
the bit-packed multi-source path (up to 64 roots per traversal word).
Results are bit-identical to sequential runs; only the launch count and
wall time change. `Session.run_many` reroutes batch-eligible lists through
the same machinery automatically.
"""
import time

import numpy as np

import repro
from repro.algorithms import sources
from repro.graph import generators

graph = generators.power_law(2000, 16000, seed=0)
rng = np.random.default_rng(7)

# ---- 64-root BFS: the bit-packed multi-source fast path ------------------
bfs = repro.compile(sources.BFS_ECP)
roots = [{"root": int(r)} for r in rng.integers(0, graph.n_vertices, 64)]

session = bfs.bind(graph)
session.run(**roots[0])  # warm the sequential path (jit compile)
t0 = time.perf_counter()
seq = [session.run(**p) for p in roots]
seq_s = time.perf_counter() - t0

batch = bfs.bind_batch(graph)
batch.run_many(roots)  # warm the batched path
t0 = time.perf_counter()
bat = batch.run_many(roots)
bat_s = time.perf_counter() - t0

assert all(
    np.array_equal(a.properties["old_level"], b.properties["old_level"])
    for a, b in zip(seq, bat)
), "batched results must be bit-identical to sequential runs"
seq_launches = sum(r.stats.total_launches for r in seq)
print(f"BFS x64 roots: sequential {seq_s:.3f}s ({seq_launches} launches) "
      f"-> batched {bat_s:.3f}s ({bat[0].stats.total_launches} launches, "
      f"{seq_s / bat_s:.1f}x faster, batch_size={bat[0].stats.batch_size})")

# ---- 8-seed personalized PageRank: the generic vmapped path --------------
ppr = repro.compile(sources.PPR)
seeds = [{"source": int(s)} for s in rng.integers(0, graph.n_vertices, 8)]

session = ppr.bind(graph)
session.run(**seeds[0])
t0 = time.perf_counter()
seq = [session.run(**p) for p in seeds]
seq_s = time.perf_counter() - t0

batch = ppr.bind_batch(graph)
batch.run_many(seeds)
t0 = time.perf_counter()
bat = batch.run_many(seeds)
bat_s = time.perf_counter() - t0

assert all(
    np.array_equal(a.properties["PR_old"], b.properties["PR_old"])
    for a, b in zip(seq, bat)
), "batched PPR must match sequential runs bit-for-bit"
top = int(np.argmax(bat[0].properties["PR_old"]))
print(f"PPR x8 seeds: sequential {seq_s:.3f}s -> batched {bat_s:.3f}s "
      f"({seq_s / bat_s:.1f}x faster); top vertex for seed "
      f"{seeds[0]['source']}: {top}")
