"""Direction-switching BFS (paper Fig. 2) on a Table II dataset, comparing
the unoptimized baseline / ThunderGP-style template / Graphitron engines.

One Program per configuration, each bound once; the timing loop is pure
``session.run(root=...)`` — the "post-synthesis accelerator execution"
timing mode.

    PYTHONPATH=src python examples/bfs_direction_switching.py
"""
import time

import numpy as np

import repro
from repro.graph.datasets import make_dataset
from repro.algorithms import sources
from repro.baselines import thundergp as tg


def main():
    g = make_dataset("R19", scale=0.01, seed=0)
    root = int(np.argmax(g.out_degree))
    print(f"rmat graph: |V|={g.n_vertices} |E|={g.n_edges}, root={root}")

    # substrate ablations live on Target; CompileOptions carries only the
    # MIR pass pipeline (the baseline disables both)
    sessions = {
        "baseline (no optimizations)": repro.compile(
            sources.BFS_ECP, repro.CompileOptions(passes="none")
        ).bind(g, target=repro.Target.baseline()),
        "graphitron ECP (full opts)": repro.compile(sources.BFS_ECP).bind(g),
        "graphitron hybrid (Fig. 2)": repro.compile(sources.BFS_HYBRID).bind(g),
    }

    ref = None
    for name, session in sessions.items():
        session.run(root=root)  # warm: jit-compile every kernel launch path
        t0 = time.perf_counter()
        res = session.run(root=root)
        dt = time.perf_counter() - t0
        lvl = res.properties["old_level"]
        if ref is None:
            ref = lvl
        else:
            assert (lvl == ref).all(), "engines disagree!"
        sweeps = g.n_edges * res.stats.host_iterations
        reduction = (
            f"{sweeps / max(res.stats.edges_traversed, 1):.1f}x vs full sweeps"
            if res.stats.edges_traversed
            else ""
        )
        print(f"{name:32s} {dt * 1e3:8.1f} ms  "
              f"edges_traversed={res.stats.edges_traversed:>9d} "
              f"(work reduction {reduction})")
    lt, st = tg.bfs_run(g, root)
    print(f"{'thundergp template (GAS/ECP)':32s} {st.wall_time_s * 1e3:8.1f} ms  "
          f"edges_traversed={st.edges_traversed:>9d}")
    reached = int((ref >= 0).sum())
    print(f"reached {reached}/{g.n_vertices} vertices")


if __name__ == "__main__":
    main()
