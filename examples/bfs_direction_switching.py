"""Direction-switching BFS (paper Fig. 2) on a Table II dataset, comparing
the unoptimized baseline / ThunderGP-style template / Graphitron engines.

    PYTHONPATH=src python examples/bfs_direction_switching.py
"""
import numpy as np

from repro.core import CompileOptions
from repro.graph.datasets import make_dataset
from repro.algorithms import sources
from repro.algorithms.runners import make_warm_runner
from repro.baselines import thundergp as tg


def main():
    g = make_dataset("R19", scale=0.01, seed=0)
    root = int(np.argmax(g.out_degree))
    print(f"rmat graph: |V|={g.n_vertices} |E|={g.n_edges}, root={root}")

    runs = {
        "baseline (no optimizations)": make_warm_runner(
            sources.BFS_ECP, g, CompileOptions.baseline(), {"root": root}
        ),
        "graphitron ECP (full opts)": make_warm_runner(
            sources.BFS_ECP, g, CompileOptions.full(), {"root": root}
        ),
        "graphitron hybrid (Fig. 2)": make_warm_runner(
            sources.BFS_HYBRID, g, CompileOptions.full(), {"root": root}
        ),
    }
    import time

    ref = None
    for name, run in runs.items():
        t0 = time.perf_counter()
        res = run()
        dt = time.perf_counter() - t0
        lvl = res.properties["old_level"]
        if ref is None:
            ref = lvl
        else:
            assert (lvl == ref).all(), "engines disagree!"
        print(
            f"{name:32s} {dt * 1e3:8.1f} ms  edges_traversed={res.stats.edges_traversed:>9d} "
            f"(work reduction {runs and ''}{'' if res.stats.edges_traversed == 0 else f'{g.n_edges * res.stats.host_iterations / max(res.stats.edges_traversed, 1):.1f}x vs full sweeps'})"
        )
    lt, st = tg.bfs_run(g, root)
    print(f"{'thundergp template (GAS/ECP)':32s} {st.wall_time_s * 1e3:8.1f} ms  edges_traversed={st.edges_traversed:>9d}")
    reached = int((ref >= 0).sum())
    print(f"reached {reached}/{g.n_vertices} vertices")


if __name__ == "__main__":
    main()
