"""Quickstart: author a graph algorithm in the Graphitron DSL, compile it,
and run it on a synthetic social graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CompileOptions, compile_source, Engine
from repro.graph import generators

# Degree counting + a one-line "who is popular" query, written in the
# paper's language (Fig. 1 syntax). The compiler classifies initDeg as a
# vertex kernel and countIn as an edge kernel, detects that `indeg` is
# scatter-written (shuffle path) and `total` is a global accumulator.
SRC = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const indeg: vector{Vertex}(int);
const popular: vector{Vertex}(int);
const total: vector{Vertex}(int);
const threshold: int = 16;

func initDeg(v: Vertex)
    indeg[v] = 0;
    popular[v] = 0;
end
func countIn(src: Vertex, dst: Vertex)
    indeg[dst] += 1;
    total[0] = total[0] + 1;
end
func markPopular(v: Vertex)
    if (indeg[v] >= threshold)
        popular[v] = 1;
    end
end
func main()
    vertices.init(initDeg);
    edges.process(countIn);
    vertices.process(markPopular);
end
"""


def main():
    graph = generators.power_law(5_000, 60_000, seed=0)
    module = compile_source(SRC)
    print("=== MIR (the compiler's view of your program) ===")
    print(module.describe())

    engine = Engine(module, graph, CompileOptions.full(), argv=["prog", "social"])
    result = engine.run()

    indeg = result.properties["indeg"]
    popular = result.properties["popular"]
    assert (indeg == graph.in_degree).all()
    assert result.properties["total"][0] == graph.n_edges
    print("\n=== results ===")
    print(f"vertices: {graph.n_vertices}, edges: {graph.n_edges}")
    print(f"popular vertices (indeg >= 16): {int(popular.sum())}")
    print(f"max in-degree: {int(indeg.max())}")
    print(f"kernel launches: {result.stats.kernel_launches}")


if __name__ == "__main__":
    main()
