"""Quickstart: author a graph algorithm in the Graphitron DSL, then
compile once, bind to a graph, and run it with explicit parameters.

    PYTHONPATH=src python examples/quickstart.py

The whole public workflow is three calls:

    program = repro.compile(src)      # compile once (content-hash cached)
    session = program.bind(graph)     # bind to a graph + backend
    result  = session.run(...)        # parameterized, validated run

For deployment there is a fourth: AOT-lower into an `Accelerator` per
shape bucket, save it, and warm-start any process with zero compile cost:

    acc = program.lower(repro.Target(), shape=repro.GraphShape.of(graph))
    acc.save("artifacts/popular"); ...; repro.load_accelerator(...)
"""
import tempfile

import numpy as np

import repro
from repro.graph import generators

# Degree counting + a one-line "who is popular" query, written in the
# paper's language (Fig. 1 syntax). The compiler classifies initDeg as a
# vertex kernel and countIn as an edge kernel, detects that `indeg` is
# scatter-written (shuffle path) and `total` is a global accumulator.
# `threshold` is a host scalar — which makes it a declared run-time
# parameter of the compiled Program.
SRC = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const indeg: vector{Vertex}(int);
const popular: vector{Vertex}(int);
const total: vector{Vertex}(int);
const threshold: int = 16;

func initDeg(v: Vertex)
    indeg[v] = 0;
    popular[v] = 0;
end
func countIn(src: Vertex, dst: Vertex)
    indeg[dst] += 1;
    total[0] = total[0] + 1;
end
func markPopular(v: Vertex)
    if (indeg[v] >= threshold)
        popular[v] = 1;
    end
end
func main()
    vertices.init(initDeg);
    edges.process(countIn);
    vertices.process(markPopular);
end
"""


def main():
    graph = generators.power_law(5_000, 60_000, seed=0)

    # 1. compile once — the Program is cached on a content hash of
    #    (source, options) and knows its declared run-time parameters
    program = repro.compile(SRC)  # default options: full optimization
    print("=== MIR (the compiler's view of your program) ===")
    print(program.describe())
    print("\ndeclared parameters:",
          ", ".join(p.describe() for p in program.params.values()))

    # 2. bind to a graph — the Session owns lowered kernels + device state
    session = program.bind(graph, argv=["prog", "social"])

    # 3. run with explicit parameters, as many times as you like
    result = session.run()  # threshold defaults to 16
    indeg = result.properties["indeg"]
    popular = result.properties["popular"]
    assert (indeg == graph.in_degree).all()
    assert result.properties["total"][0] == graph.n_edges

    print("\n=== results ===")
    print(f"vertices: {graph.n_vertices}, edges: {graph.n_edges}")
    print(f"popular vertices (indeg >= 16): {int(popular.sum())}")
    print(f"max in-degree: {int(indeg.max())}")
    print(f"kernel launches: {result.stats.kernel_launches}")

    # same session, different parameter — no recompilation, state reset
    lax = session.run(threshold=4)
    print(f"popular vertices (indeg >= 4): {int(lax.properties['popular'].sum())}")

    # the same Program binds to any number of graphs
    small = generators.power_law(500, 4_000, seed=1)
    r_small = repro.compile(SRC).bind(small).run()
    assert (r_small.properties["indeg"] == small.in_degree).all()
    print(f"re-bound to |V|={small.n_vertices}: "
          f"max in-degree {int(r_small.properties['indeg'].max())}")

    # 4. deployment: AOT-lower once per (target, shape bucket), save the
    #    artifact, and warm-start from it — the generated-accelerator flow
    target = repro.Target()  # local substrate, all memory optimizations
    acc = program.lower(target, shape=repro.GraphShape.of(graph))
    print("\n=== accelerator report (the HLS-resource-report analogue) ===")
    print(acc.report().describe())

    with tempfile.TemporaryDirectory() as d:
        acc.save(f"{d}/popular")  # canonical MIR + target + executables
        loaded = repro.load_accelerator(f"{d}/popular")
        warm = loaded.bind(graph).run()  # shape check only — no compile
        np.testing.assert_array_equal(warm.properties["indeg"], indeg)
        print(f"\nsave/load round-trip OK: warm run compile_time="
              f"{warm.stats.compile_time_s:.3f}s "
              f"run_time={warm.stats.run_time_s * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
