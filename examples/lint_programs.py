"""Static analysis: lint a program, read its determinism certificate,
and see admission gating reject a racy kernel.

    PYTHONPATH=src python examples/lint_programs.py

The analysis surface is one call — `repro.analyze` accepts `.gt` text,
an embedded GraphProgram, or a compiled Program, and never raises on a
bad input (front-end failures become GT001–GT004 diagnostics):

    result = repro.analyze(src)       # AnalysisResult
    result.errors                     # GT1xx races, GT502 overflow, ...
    result.certificate                # deterministic / reduction-
                                      #   deterministic / racy

The same verdicts gate the rest of the stack: `repro.compile(src,
strict=True)` raises on error-level findings, `GraphService.submit`
rejects them with typed `ProgramRejected` before registry admission, and
`accelerator.report()` carries the certificate. The CLI twin is

    python -m repro.lint [--json] file.gt | module:attr | --builtins
"""
import repro
from repro.algorithms import sources
from repro.graph.storage import GraphData

# A deliberately racy edge kernel: plain `=` scatter to P[dst] with an
# edge-varying value. Two edges sharing one dst race; the analysis flags
# GT101 with a caret pointing at the exact line and column.
RACY = """
element Vertex end
const edges: edgeset{Vertex}(Vertex, Vertex) = load(argv(1));
const vertices: vertexset{Vertex};
const P: vector{Vertex}(int);
func initP(v: Vertex)
    P[v] = 0;
end
func upd(src: Vertex, dst: Vertex)
    P[dst] = P[src] + 1;
end
func main()
    vertices.init(initP);
    edges.process(upd);
end
"""

print("=== lint the racy program ===")
result = repro.analyze(RACY)
print(result.render())

print("\n=== built-in algorithms carry certificates ===")
for name in ("BFS_ECP", "PAGERANK"):
    res = repro.analyze(getattr(sources, name))
    print(f"{name:10s} -> {res.certificate} "
          f"({len(res.errors)} errors, {len(res.warnings)} warnings)")

print("\n=== strict compile raises; serving rejects before admission ===")
try:
    repro.compile(RACY, strict=True)
except repro.ProgramError as e:
    print("strict compile:", str(e).splitlines()[0])

graph = GraphData(4, src=[0, 1, 2, 0], dst=[1, 2, 0, 2])
with repro.serve(registry_dir=False) as service:
    try:
        service.submit(RACY, graph, tenant="alice")
    except repro.ProgramRejected as e:
        print("service.submit:", str(e).splitlines()[0])
    stats = service.stats()
    print("rejections_analysis (tenant alice):",
          stats["tenants"]["alice"]["rejections_analysis"])

    # the fix: make the scatter a reduction — min= commits race-free
    fixed = RACY.replace("P[dst] = P[src] + 1;", "P[dst] min= P[src] + 1;")
    print("fixed certificate:", repro.analyze(fixed).certificate)
    result = service.run(fixed, graph, tenant="alice")
    print("fixed program served; P =", list(result.properties["P"]))
