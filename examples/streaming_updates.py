"""Quickstart for streaming graph updates + incremental recomputation.

    PYTHONPATH=src python examples/streaming_updates.py

Static sessions bind an immutable graph; a `StreamingSession` serves a
graph that keeps changing. Edge additions/removals arrive as `GraphDelta`s
and are applied **in place** into the padding slack of the graph's shape
bucket (`GraphShape.bucket_for` + `pad_to`), so the physical buffers — and
the lowered kernels — never change: an update is a shape-check-only rebind,
not a recompile. Monotone programs (BFS / SSSP / connected components,
detected from the MIR's min=/max= reductions) answer repeated queries after
an update by *incrementally repairing* the cached result from the delta's
endpoints, bit-identical to a from-scratch run; non-monotone programs
(PageRank) transparently fall back to a full re-run.
"""
import time

import numpy as np

import repro
from repro.algorithms import sources
from repro.graph import generators

rng = np.random.default_rng(7)

# ---- bind a bucket-padded graph so updates have slack to land in ---------
base = generators.power_law(2000, 16000, seed=0)
program = repro.compile(sources.BFS_ECP)
accelerator = program.lower(graph=base, bucket=True)  # geometric bucket
graph = base.pad_to(accelerator.shape.n_vertices, accelerator.shape.n_edges)
print(f"graph |V|={base.n_vertices} |E|={base.n_edges} padded into bucket "
      f"{accelerator.shape.n_vertices}x{accelerator.shape.n_edges}")

session = repro.StreamingSession(program, graph, accelerator=accelerator)
first = session.run(root=3)
print(f"version 0: BFS from root=3 reached "
      f"{int((np.asarray(first.properties['old_level']) >= 0).sum())} vertices")

# ---- stream additions: in-place apply, zero re-lowering ------------------
for step in range(3):
    delta = repro.GraphDelta(
        added_edges=rng.integers(0, base.n_vertices, size=(160, 2)).astype(np.int32)
    )
    t0 = time.perf_counter()
    version = session.update(delta)
    apply_ms = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    repaired = session.run(root=3)  # incremental repair of the cached result
    repair_ms = (time.perf_counter() - t0) * 1e3

    scratch = program.bind(session.graph).run(root=3)  # independent referee
    assert all(
        np.array_equal(repaired.properties[p], scratch.properties[p])
        for p in scratch.properties
    ), "incremental result must be bit-identical to from-scratch"
    assert repaired.stats.compile_time_s == 0.0, "updates must not re-lower"
    print(f"version {version}: +{delta.n_added} edges applied in "
          f"{apply_ms:.1f}ms, query repaired in {repair_ms:.2f}ms "
          f"(bit-identical to from-scratch)")

print(f"paths taken: {session.cache_hits} cache hits, "
      f"{session.incremental_runs} incremental repairs, "
      f"{session.full_runs} full runs")
session.close()
