"""End-to-end LM training: a ~100M-param qwen3-family model trained for a
few hundred steps on the synthetic Markov-Zipf corpus, with async atomic
checkpoints and auto-resume. Kill it mid-run and re-launch: it resumes
from the last valid checkpoint and regenerates exactly the batches it
would have seen (restart-safe data).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import Model
from repro.train import OptConfig, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60,
                    help="few hundred (e.g. 300) for the full run; 60 fits a CPU demo")
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_train_lm")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    # ~100M-param member of the qwen3 family (same features: qk-norm, GQA,
    # tied embeddings); the full-size assigned config is qwen3-0.6b
    cfg = get_config("qwen3-0.6b").scaled(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32000,  # ~100M params
    )
    print(f"# model: {cfg.param_count() / 1e6:.0f}M params ({cfg.name} family)")

    model = Model(cfg, dtype=jnp.float32, remat=True)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_state(params, opt_cfg)

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start, state = mgr.restore_latest({"params": params, "opt": opt_state})
    if start is not None:
        params, opt_state = state["params"], state["opt"]
        print(f"# resumed from step {start}")
    start = start or 0

    step_fn = jax.jit(make_train_step(model, opt_cfg, n_microbatches=2),
                      donate_argnums=(0, 1))
    data = SyntheticLM(cfg, args.seq_len, args.batch, seed=0)
    print("step,loss,grad_norm,tokens_per_s")
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            tps = (step - start + 1) * args.batch * args.seq_len / max(dt, 1e-9)
            print(f"{step},{float(m['loss']):.4f},{float(m['grad_norm']):.3f},{tps:.0f}")
        if (step + 1) % 50 == 0:
            mgr.save_async(step + 1, {"params": params, "opt": opt_state})
    mgr.save(args.steps, {"params": params, "opt": opt_state})
    print(f"# final loss {float(m['loss']):.4f} (init ~{np.log(cfg.vocab_size):.2f})")


if __name__ == "__main__":
    main()
