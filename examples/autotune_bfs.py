"""Autotuning quickstart: search the Target knob space once, reuse the
winner everywhere — lowering, saved artifacts, and serving.

    PYTHONPATH=src python examples/autotune_bfs.py

The workflow is:

    report = repro.autotune.autotune(program, graph, params={"root": 0})
    acc = program.lower(graph=graph, tuned=True)   # lookup, zero trials
    service.run("bfs", graph, root=0)              # tuned_hits in stats()

The probe graph is a deep multigraph (200-level chain, 1000 parallel
edges per hop): BFS frontiers stay tiny while full-edge streaming pays
the whole edge list at every level, so the tuner measurably prefers
``compact_frontier`` Targets — the direction-switching regime of the
paper's Fig. 2, found by search instead of by hand.
"""
import os
import tempfile

import repro
from repro.autotune import AutoTuner, TuningCache, tuning_dir_for
from repro.graph import generators
from repro.serving.service import NAMED_ALGORITHMS


def main():
    store = tempfile.mkdtemp(prefix="repro-autotune-")
    graph = generators.deep_chain(120, multiplicity=600)
    program = repro.compile(NAMED_ALGORITHMS["bfs"])

    # 1. the search: analysis-pruned candidates, cost-model ordering,
    #    telemetry-measured trials (best-of-reps launch totals)
    tuner = AutoTuner(TuningCache(tuning_dir_for(store)),
                      reps=2, max_candidates=6)
    report = tuner.tune(program, graph, params={"root": 0})
    print("=== search ===")
    print(report.describe())

    # 2. the winner persists: a fresh cache instance (a fresh process)
    #    resolves it with zero trials
    warm = AutoTuner(TuningCache(tuning_dir_for(store)))
    hit = warm.tune(program, graph, params={"root": 0})
    print("\n=== warm start ===")
    print(f"cache_hit={hit.cache_hit}, trials={hit.trials}, "
          f"target={hit.config.target.describe()}")

    # 3. tuned lowering + artifact stamping: the manifest records the
    #    config, so warm-started processes know they run a tuned Target
    acc = program.lower(graph=graph, tuned=True,
                        tuning_cache=TuningCache(tuning_dir_for(store)))
    art = acc.save(os.path.join(store, "bfs-tuned"))
    loaded = repro.load_accelerator(art)
    stamp = loaded.tuned or {}
    print("\n=== artifact ===")
    print(f"saved {art}")
    print(f"manifest tuned stamp: target={stamp.get('target', {})}, "
          f"trials={stamp.get('trials')}")

    # 4. serving picks the tuned Target transparently on every submit
    with repro.serve(store, workers=1) as svc:
        res = svc.run("bfs", graph, root=0)
        stats = svc.stats()
        print("\n=== serving ===")
        levels = res.properties["old_level"]
        print(f"result reached {int((levels >= 0).sum())} vertices")
        print(f"programs.bfs.tuned_hits = "
              f"{stats['programs']['bfs']['tuned_hits']}")
        print(f"tuning cache: {stats['tuning']}")


if __name__ == "__main__":
    main()
